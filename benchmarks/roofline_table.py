"""Roofline table (§Roofline) — reads the dry-run artifacts."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.analysis.roofline import roofline_row
from repro.launch.shapes import SHAPES, all_cells


def roofline_table(scale: float = 1.0) -> List[Dict]:
    rows: List[Dict] = []
    for arch, shape in all_cells():
        row = roofline_row(arch, shape.name)
        if row is None:
            rows.append({"arch": arch, "shape": shape.name, "status": "missing"})
            continue
        if row.get("skipped"):
            rows.append(
                {"arch": arch, "shape": shape.name, "status": "skipped",
                 "note": row.get("reason", "")}
            )
            continue
        if row.get("failed"):
            rows.append({"arch": arch, "shape": shape.name, "status": "failed"})
            continue
        rows.append(
            {
                "arch": arch,
                "shape": shape.name,
                "status": "ok",
                "t_compute_s": row["t_compute_s"],
                "t_memory_s": row["t_memory_s"],
                "t_collective_s": row["t_collective_s"],
                "dominant": row["dominant"],
                "model_flops": row["model_flops"],
                "useful_ratio": row["useful_ratio"],
                "roofline_fraction": row["roofline_fraction"],
                "temp_gb_per_device": (row.get("temp_bytes_per_device") or 0) / 1e9,
            }
        )
    emit("roofline_table", rows)
    return rows


def cluster_benchmark(scale: float = 1.0) -> List[Dict]:
    """Cluster-day benchmark: paper's policies on the TPU pod (DESIGN.md §2)."""
    from benchmarks.common import summarize
    from repro.core.metrics import et_table
    from repro.core.simulator import DayNightPolicy, StaticPolicy
    from repro.launch.cluster_sim import queue_heuristic_policy, run_days
    from repro.distributed.fault_tolerance import FailureModel

    iters = max(int(5 * scale), 2)
    per = {
        "static": run_days(lambda: StaticPolicy(3), iterations=iters),
        "daynight": run_days(DayNightPolicy, iterations=iters),
        "dynamic": run_days(queue_heuristic_policy, iterations=iters),
    }
    table, _ = et_table(per)
    rows = []
    for k in per:
        rows.append({"policy": k, "ET": table[k], **summarize(per[k])})
    # fault drill
    fr = run_days(
        queue_heuristic_policy, iterations=max(iters // 2, 1),
        failures=FailureModel(mtbf_minutes=12 * 60.0, seed=7),
    )
    rows.append({"policy": "dynamic+failures", "ET": float("nan"), **summarize(fr)})
    emit("cluster_day", rows)
    return rows
