"""Kernel microbenchmarks (interpret-mode correctness timing is meaningless
on CPU, so we time the pure-jnp oracles as the substrate's CPU path and
report the kernels' VMEM working sets per BlockSpec — the quantity that
matters for the TPU roofline)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ref


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def kernel_bench(scale: float = 1.0) -> List[Dict]:
    rng = np.random.default_rng(0)

    def t(*s, dtype=jnp.float32):
        return jnp.asarray(rng.normal(size=s), dtype)

    rows: List[Dict] = []

    # attention: (B,S,H,D) oracle vs VMEM tile budget of the Pallas kernel
    B, S, H, D, bq, bk = 1, 1024, 4, 128, 128, 128
    q, k, v = t(B, S, H, D), t(B, S, H, D), t(B, S, H, D)
    us = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True)), q, k, v)
    vmem = (bq * D * 2 + 2 * bk * D * 2 + bq * D * 4 + 2 * bq * 4) / 1024
    rows.append(
        {"kernel": "flash_attention", "shape": f"B{B}xS{S}xH{H}xD{D}",
         "cpu_ref_us": us, "vmem_tile_kib": vmem,
         "flops": 4.0 * B * H * S * S * D / 2}
    )

    # mamba scan
    B, T, Di, N = 1, 1024, 512, 16
    x = t(B, T, Di)
    dt = jax.nn.softplus(t(B, T, Di)) * 0.1
    A = -jnp.exp(t(Di, N) * 0.5)
    Bm, Cm, Dv = t(B, T, N), t(B, T, N), t(Di)
    us = _time(jax.jit(ref.mamba_scan_ref), x, dt, A, Bm, Cm, Dv)
    vmem = (128 * 512 * 2 * 3 + 2 * 128 * N * 4 + 512 * N * 4) / 1024
    rows.append(
        {"kernel": "mamba_scan", "shape": f"B{B}xT{T}xDi{Di}xN{N}",
         "cpu_ref_us": us, "vmem_tile_kib": vmem,
         "flops": 6.0 * B * T * Di * N}
    )

    # mlstm chunked
    B, T, H, D = 1, 512, 4, 64
    q, k, v = t(B, T, H, D), t(B, T, H, D), t(B, T, H, D)
    ig, fg = t(B, T, H), t(B, T, H) + 2.0
    us = _time(jax.jit(lambda *a: ref.mlstm_chunked_scan(*a, chunk=128)), q, k, v, ig, fg)
    vmem = (3 * 128 * D * 2 + D * D * 4 + 128 * 128 * 4) / 1024
    rows.append(
        {"kernel": "mlstm_chunkwise", "shape": f"B{B}xT{T}xH{H}xD{D}",
         "cpu_ref_us": us, "vmem_tile_kib": vmem,
         "flops": 2.0 * B * H * T * 128 * D * 2}
    )

    # gmm
    G, rows_pg, K, N = 8, 256, 512, 512
    lhs, rhs = t(G * rows_pg, K), t(G, K, N)
    sizes = jnp.full((G,), rows_pg, jnp.int32)
    us = _time(jax.jit(ref.gmm_ref), lhs, rhs, sizes)
    vmem = (128 * 512 * 2 + 512 * 128 * 2 + 128 * 128 * 4) / 1024
    rows.append(
        {"kernel": "gmm", "shape": f"G{G}xM{G*rows_pg}xK{K}xN{N}",
         "cpu_ref_us": us, "vmem_tile_kib": vmem,
         "flops": 2.0 * G * rows_pg * K * N}
    )
    emit("kernels_bench", rows)
    return rows
