"""Paper tables/figures as benchmark functions (DESIGN.md §6 index).

Each function is now a thin wrapper over the declarative grids in
:mod:`repro.sweep.grids`: the grid enumerates the cells (in the same order
the old serial loops did, so the numbers are identical at the same seeds),
the sweep engine runs them — parallel across ``workers`` processes and
memoized on disk — and the wrapper emits the aggregated CSV.

``python -m repro.sweep --grid <name>`` runs the same grids without the
CSV emit; ``--workers``/``--scale`` behave identically.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.sweep import run_grid


def _grid_bench(name: str, scale: float, workers: int) -> List[Dict]:
    rows, _outcome = run_grid(name, scale=scale, workers=workers)
    emit(name, rows)
    return rows


def table2_schedulers(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Table II: ET of the four in-configuration scheduling algorithms."""
    return _grid_bench("table2_schedulers", scale, workers)


def fig4_preemption(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Fig. 4: preemptions, restricted vs unrestricted EDF-SS, per config."""
    return _grid_bench("fig4_preemption", scale, workers)


def fig6_utilization(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Fig. 6: % time per utilization level (busy slots 0..7), per algorithm."""
    return _grid_bench("fig6_utilization", scale, workers)


def fig7_fig8_arrival(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Figs. 7-8: ET across configurations at arrival rates 0.1 and 0.75."""
    return _grid_bench("fig7_fig8_arrival", scale, workers)


def fig9_fig10_split(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Figs. 9-10: ET across configurations at 20% / 80% inference split."""
    return _grid_bench("fig9_fig10_split", scale, workers)


def table3_repartitioning(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Table III: dynamic repartitioning vs the three benchmarks."""
    return _grid_bench("table3_repartitioning", scale, workers)


def fig11_preferences(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Fig. 11: preferred configurations by 4-hour interval (dynamic policy)."""
    return _grid_bench("fig11_preferences", scale, workers)


def fleet_scaling(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Beyond-paper: N heterogeneous GPUs x dispatcher (repro.fleet)."""
    return _grid_bench("fleet_scaling", scale, workers)


def scenario_matrix(scale: float = 1.0, workers: int = 0) -> List[Dict]:
    """Beyond-paper: scenario library x the four schedulers."""
    return _grid_bench("scenario_matrix", scale, workers)
