"""Paper tables/figures as benchmark functions (DESIGN.md §6 index).

Each function reproduces one table/figure and returns CSV rows; iteration
counts are scaled by ``--scale`` in benchmarks.run (1.0 = CI-sized).
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import emit, eval_algo, summarize
from repro.core.metrics import et_table
from repro.core.simulator import DayNightPolicy, NoMIGPolicy, StaticPolicy
from repro.core.workload import WorkloadSpec
from repro.launch.cluster_sim import queue_heuristic_policy

ALGOS = ["EDF-FS", "EDF-SS", "LLF", "LALF"]


def _basket_specs() -> List[WorkloadSpec]:
    return [
        WorkloadSpec(),
        WorkloadSpec(horizon_min=480.0, constant_rate=0.1),
        WorkloadSpec(horizon_min=480.0, constant_rate=0.5),
        WorkloadSpec(inference_split=0.2),
    ]


def table2_schedulers(scale: float = 1.0) -> List[Dict]:
    """Table II: ET of the four in-configuration scheduling algorithms."""
    iters = max(int(2 * scale), 1)
    per = {n: [] for n in ALGOS}
    for si, spec in enumerate(_basket_specs()):
        for cfg in range(1, 13):
            for n in ALGOS:
                per[n].extend(
                    eval_algo(n, spec, cfg, seeds=[9000 * si + 17 * cfg + k for k in range(iters)])
                )
    table, a = et_table(per)
    rows = []
    for n in ALGOS:
        s = summarize(per[n])
        rows.append({"algorithm": n, "ET": table[n], **s})
    emit("table2_schedulers", rows)
    return rows


def fig4_preemption(scale: float = 1.0) -> List[Dict]:
    """Fig. 4: preemptions, restricted vs unrestricted EDF-SS, per config."""
    iters = max(int(2 * scale), 1)
    spec = WorkloadSpec()
    rows = []
    for cfg in range(1, 13):
        rec: Dict = {"config": cfg}
        per = {}
        for n in ("EDF-SS", "EDF-SS-unrestricted"):
            rs = eval_algo(n, spec, cfg, seeds=[100 * cfg + k for k in range(iters)])
            per[n] = rs
            rec[f"preempt_{'restricted' if n == 'EDF-SS' else 'unrestricted'}"] = (
                sum(r.preemptions for r in rs) / len(rs)
            )
        t, _ = et_table(per)
        rec["et_restricted"] = t["EDF-SS"]
        rec["et_unrestricted"] = t["EDF-SS-unrestricted"]
        rec["reduction_pct"] = 100.0 * (
            1 - rec["preempt_restricted"] / max(rec["preempt_unrestricted"], 1e-9)
        )
        rows.append(rec)
    emit("fig4_preemption", rows)
    return rows


def fig6_utilization(scale: float = 1.0) -> List[Dict]:
    """Fig. 6: % time per utilization level (busy slots 0..7), per algorithm."""
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import MIGSimulator
    from repro.core.workload import generate_jobs

    iters = max(int(2 * scale), 1)
    spec = WorkloadSpec(horizon_min=480.0, constant_rate=0.5)
    rows = []
    for n in ALGOS:
        sim = MIGSimulator(make_scheduler(n))
        hist: Dict[int, float] = {}
        total = 0.0
        for s in range(iters):
            sim.run(generate_jobs(spec, seed=600 + s), policy=StaticPolicy(4))
            for k, v in sim.util_histogram.items():
                hist[k] = hist.get(k, 0.0) + v
                total += v
        row = {"algorithm": n}
        for k in range(8):
            row[f"util_{k}"] = 100.0 * hist.get(k, 0.0) / max(total, 1e-9)
        rows.append(row)
    emit("fig6_utilization", rows)
    return rows


def fig7_fig8_arrival(scale: float = 1.0) -> List[Dict]:
    """Figs. 7-8: ET across configurations at arrival rates 0.1 and 0.75."""
    iters = max(int(2 * scale), 1)
    rows = []
    for rate in (0.1, 0.5, 0.75):
        spec = WorkloadSpec(horizon_min=480.0, constant_rate=rate)
        for cfg in range(1, 13):
            per = {
                n: eval_algo(n, spec, cfg, seeds=[300 * cfg + k for k in range(iters)])
                for n in ALGOS
            }
            t, _ = et_table(per)
            rows.append({"rate": rate, "config": cfg, **{n: t[n] for n in ALGOS}})
    emit("fig7_fig8_arrival", rows)
    return rows


def fig9_fig10_split(scale: float = 1.0) -> List[Dict]:
    """Figs. 9-10: ET across configurations at 20% / 80% inference split."""
    iters = max(int(2 * scale), 1)
    rows = []
    for split in (0.2, 0.8):
        spec = WorkloadSpec(inference_split=split)
        for cfg in range(1, 13):
            per = {
                n: eval_algo(n, spec, cfg, seeds=[500 * cfg + k for k in range(iters)])
                for n in ALGOS
            }
            t, _ = et_table(per)
            rows.append({"inference_split": split, "config": cfg, **{n: t[n] for n in ALGOS}})
    emit("fig9_fig10_split", rows)
    return rows


def _dqn_policy_factory(params_path: str = "artifacts/dqn_params.npz"):
    import os

    from repro.core.rl import DQNConfig, DQNLearner, greedy_policy
    from repro.core.rl.env import FEATURE_DIM

    if not os.path.exists(params_path):
        return None
    learner = DQNLearner(DQNConfig(state_dim=FEATURE_DIM))
    learner.load(params_path)
    return lambda: greedy_policy(learner)


def table3_repartitioning(scale: float = 1.0) -> List[Dict]:
    """Table III: dynamic repartitioning vs the three benchmarks."""
    iters = max(int(10 * scale), 2)
    spec = WorkloadSpec()
    seeds = [40_000 + k for k in range(iters)]
    per = {
        "NoMIG": eval_algo("EDF-SS", spec, 1, seeds, NoMIGPolicy, mig_enabled=False),
        "StaticMIG": eval_algo("EDF-SS", spec, 3, seeds),
        "DayNightMIG": eval_algo("EDF-SS", spec, 0, seeds, DayNightPolicy),
        "DynamicMIG-heuristic": eval_algo(
            "EDF-SS", spec, 0, seeds, queue_heuristic_policy
        ),
    }
    dqn = _dqn_policy_factory()
    if dqn is not None:
        per["DynamicMIG-DQN"] = eval_algo("EDF-SS", spec, 0, seeds, dqn)
    table, a = et_table(per)
    rows = []
    base = {k: table[k] for k in per}
    for name in per:
        s = summarize(per[name])
        rows.append(
            {
                "model": name,
                "ET": table[name],
                "improvement_vs_NoMIG_pct": 100 * (1 - table[name] / base["NoMIG"]),
                **s,
            }
        )
    emit("table3_repartitioning", rows)
    return rows


def fig11_preferences(scale: float = 1.0) -> List[Dict]:
    """Fig. 11: preferred configurations by 4-hour interval (dynamic policy)."""
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import MIGSimulator
    from repro.core.workload import generate_jobs

    iters = max(int(6 * scale), 2)
    spec = WorkloadSpec()
    dqn = _dqn_policy_factory()
    factory = dqn if dqn is not None else queue_heuristic_policy
    occupancy: Dict[int, Dict[int, float]] = {b: {} for b in range(6)}
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    for s in range(iters):
        sim.run(generate_jobs(spec, seed=77_000 + s), policy=factory())
        trace = sim.config_trace + [(24 * 60.0, sim.config_trace[-1][1])]
        for (t0, c), (t1, _) in zip(trace, trace[1:]):
            t0c, t1c = min(t0, 1440.0), min(t1, 1440.0)
            while t0c < t1c:
                b = int(t0c // 240) % 6
                upper = min((int(t0c // 240) + 1) * 240.0, t1c)
                occupancy[b][c] = occupancy[b].get(c, 0.0) + (upper - t0c)
                t0c = upper
    rows = []
    for b in range(6):
        tot = sum(occupancy[b].values()) or 1.0
        row = {"interval": f"{b*4:02d}:00-{b*4+4:02d}:00"}
        for c in range(1, 13):
            row[f"cfg{c}_pct"] = 100.0 * occupancy[b].get(c, 0.0) / tot
        rows.append(row)
    emit("fig11_preferences", rows)
    return rows
