"""Benchmark runner: one function per paper table/figure + substrate benches.

``PYTHONPATH=src python -m benchmarks.run [--scale S] [--only NAME] [--workers N]``

Prints ``name,us_per_call,derived`` style CSV blocks per benchmark and saves
them under artifacts/bench/.  --scale grows iteration counts (1.0 = CI-sized;
the EXPERIMENTS.md numbers used --scale 4).  --workers fans the paper-table
sweeps out over worker processes (see repro.sweep); substrate benches stay
single-process.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", default=None)
    ap.add_argument("--workers", type=int, default=0)
    args = ap.parse_args()

    from benchmarks.paper_tables import (
        fig4_preemption,
        fig6_utilization,
        fig7_fig8_arrival,
        fig9_fig10_split,
        fig11_preferences,
        fleet_scaling,
        scenario_matrix,
        table2_schedulers,
        table3_repartitioning,
    )
    from benchmarks.kernels_bench import kernel_bench
    from benchmarks.roofline_table import cluster_benchmark, roofline_table

    sweep_benches = {
        "table2_schedulers": table2_schedulers,
        "fig4_preemption": fig4_preemption,
        "fig6_utilization": fig6_utilization,
        "fig7_fig8_arrival": fig7_fig8_arrival,
        "fig9_fig10_split": fig9_fig10_split,
        "table3_repartitioning": table3_repartitioning,
        "fig11_preferences": fig11_preferences,
        "fleet_scaling": fleet_scaling,
        "scenario_matrix": scenario_matrix,
    }
    benches = {
        **sweep_benches,
        "kernels_bench": kernel_bench,
        "roofline_table": roofline_table,
        "cluster_day": cluster_benchmark,
    }
    failures = 0
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            if name in sweep_benches:
                fn(scale=args.scale, workers=args.workers)
            else:
                fn(scale=args.scale)
            print(f"# {name} done in {time.time()-t0:.1f}s\n")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
