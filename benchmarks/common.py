"""Shared benchmark plumbing: evaluation loops + CSV emit."""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from repro.core.metrics import SimResult, et_table
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator, StaticPolicy
from repro.core.workload import WorkloadSpec, generate_jobs

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def eval_algo(
    scheduler: str,
    spec: WorkloadSpec,
    config_id: int,
    seeds: Iterable[int],
    policy_factory=None,
    mig_enabled: bool = True,
) -> List[SimResult]:
    sim = MIGSimulator(make_scheduler(scheduler), mig_enabled=mig_enabled)
    out = []
    for s in seeds:
        jobs = generate_jobs(spec, seed=s)
        policy = policy_factory() if policy_factory else StaticPolicy(config_id)
        out.append(sim.run(jobs, policy=policy))
    return out


def emit(name: str, rows: Sequence[Dict], keys: Optional[Sequence[str]] = None) -> str:
    """Print CSV to stdout + save under artifacts/bench/<name>.csv."""
    if not rows:
        print(f"# {name}: no rows")
        return ""
    keys = list(keys or rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    csv = "\n".join(lines)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.csv")
    with open(path, "w") as f:
        f.write(csv + "\n")
    print(f"### {name}")
    print(csv)
    print()
    return path


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def summarize(results: List[SimResult]) -> Dict[str, float]:
    n = max(len(results), 1)
    return {
        "energy_wh": sum(r.energy_wh for r in results) / n,
        "avg_tardiness": sum(r.avg_tardiness for r in results) / n,
        "preemptions": sum(r.preemptions for r in results) / n,
        "repartitions": sum(r.repartitions for r in results) / n,
        "deadline_misses": sum(r.deadline_misses for r in results) / n,
    }
