"""Shared benchmark plumbing: CSV emit + a thin shim over ``repro.sweep``.

The evaluation loops that used to live here are now the sweep engine
(:mod:`repro.sweep`); ``eval_algo`` remains as the compatibility surface for
ad-hoc experiments and converts cells/results at the boundary.
"""

from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.metrics import SimResult
from repro.core.workload import WorkloadSpec
from repro.sweep import make_cell, result_to_sim_result, run_cells, summarize_results

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def eval_algo(
    scheduler: str,
    spec: WorkloadSpec,
    config_id: int,
    seeds: Iterable[int],
    policy_factory=None,
    mig_enabled: bool = True,
    workers: int = 0,
) -> List[SimResult]:
    """Evaluate one (scheduler, config, workload) point over ``seeds``.

    With the default static policy the cells go through the sweep engine —
    memoized and parallelizable (``workers``).  An ad-hoc ``policy_factory``
    callable forces the inline, uncached path (closures are neither picklable
    nor content-addressable); pass a registered policy via
    :func:`repro.sweep.run_cells` directly to keep caching.
    """
    cells = [
        make_cell(
            experiment="eval_algo",
            group=scheduler,
            scheduler=scheduler,
            workload=spec,
            seed=s,
            policy="static",
            policy_kwargs={"config_id": config_id},
            mig_enabled=mig_enabled,
        )
        for s in seeds
    ]
    outcome = run_cells(
        "eval_algo",
        cells,
        workers=workers,
        cache=policy_factory is None,
        artifacts_dir=None,
        policy_factory=policy_factory,
    )
    return [result_to_sim_result(r) for r in outcome.results]


def emit(name: str, rows: Sequence[Dict], keys: Optional[Sequence[str]] = None) -> str:
    """Print CSV to stdout + save under artifacts/bench/<name>.csv."""
    if not rows:
        print(f"# {name}: no rows")
        return ""
    keys = list(keys or rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    csv = "\n".join(lines)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.csv")
    with open(path, "w") as f:
        f.write(csv + "\n")
    print(f"### {name}")
    print(csv)
    print()
    return path


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def summarize(results: List[SimResult]) -> Dict[str, float]:
    return summarize_results(results)
