"""repro.lint — the invariant analyzer (docs/LINTING.md).

Every rule family gets at least one catching and one clean fixture, plus
waiver parsing, the git-diff version gate against synthetic repos, the
JSON report schema, and the acceptance pin that the real repo sweeps
clean.  Fixture snippets live in tmp repos (tests/ itself is excluded
from the default sweep precisely because it hosts deliberately bad code).
"""

import json
import os
import subprocess
import textwrap
from pathlib import Path

import pytest

from repro.lint import CATEGORY_BITS, RULES, LintReport, lint_repo
from repro.lint.base import Violation, category_of, exit_code_for
from repro.lint.schema import field_digest
from repro.lint.version_gate import ast_fingerprint
from repro.lint.waivers import parse_waivers

REPO_ROOT = Path(__file__).resolve().parents[1]


# ----------------------------------------------------------------------
# helpers

def make_repo(tmp_path, files):
    """A bare lint-rooted tree: pyproject marker + the given rel->source."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def unwaived_rules(report: LintReport):
    return sorted(v.rule for v in report.violations if not v.waived)


def waived_rules(report: LintReport):
    return sorted(v.rule for v in report.violations if v.waived)


def git(root, *args):
    subprocess.run(
        ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
        cwd=root, check=True, capture_output=True,
    )


def commit_all(root, msg="c"):
    git(root, "add", "-A")
    git(root, "commit", "-q", "-m", msg)


# ----------------------------------------------------------------------
# R1 determinism

def test_dt001_flags_global_state_rng(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import random
            import numpy as np

            def jitter():
                return np.random.rand(3) + random.random()
        """,
    })
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == ["DT001", "DT001"]
    assert report.exit_code == CATEGORY_BITS["R1"]


def test_dt001_clean_generator_api(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import numpy as np

            def jitter(seed):
                rng = np.random.default_rng(seed)
                return rng.normal(size=3)
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == []


def test_dt002_flags_wall_clock_reads(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import time
            import datetime

            def stamp():
                return time.time(), datetime.datetime.now()
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == ["DT002", "DT002"]


def test_dt002_out_of_scope_module_is_clean(tmp_path):
    # R1 only covers modules feeding cell_hash/SimResult/WAL records;
    # the training substrate may read clocks freely
    root = make_repo(tmp_path, {
        "src/repro/launch/foo.py": "import time\n\nT0 = time.time()\n",
    })
    assert unwaived_rules(lint_repo(root=str(root))) == []


def test_dt003_flags_set_iteration(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            def order(xs):
                seen = set(xs)
                return [x for x in seen] + [y for y in {1, 2, 3}]
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == ["DT003", "DT003"]


def test_dt003_clean_sorted_set(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            def order(xs):
                return [x for x in sorted(set(xs))]
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == []


# ----------------------------------------------------------------------
# R2 JAX purity

PURITY_BAD = """
    import jax
    import numpy as np

    @jax.jit
    def bad_print(x):
        print("tracing", x)
        return x + 1

    @jax.jit
    def bad_branch(x):
        if x > 0:
            return x
        return -x

    @jax.jit
    def bad_cast(x):
        return float(x) * 2.0

    @jax.jit
    def bad_np(x):
        return np.sum(x)
"""


def test_jax_purity_rules_fire(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/batched/fix.py": PURITY_BAD})
    rules = unwaived_rules(lint_repo(root=str(root)))
    assert rules == ["JP001", "JP002", "JP003", "JP004"]
    report = lint_repo(root=str(root))
    assert report.exit_code == CATEGORY_BITS["R2"]


def test_jax_purity_transitive_helper(tmp_path):
    # the np call sits in a helper only *reached* from a jitted entry
    root = make_repo(tmp_path, {
        "src/repro/core/batched/fix.py": """
            import jax
            import numpy as np

            def helper(x):
                return np.asarray(x)

            @jax.jit
            def entry(x):
                return helper(x) + 1
        """,
    })
    assert "JP004" in unwaived_rules(lint_repo(root=str(root)))


def test_jax_purity_scan_body_via_factory(tmp_path):
    # the factory idiom: the traced function is *returned*, never decorated
    root = make_repo(tmp_path, {
        "src/repro/core/batched/fix.py": """
            import jax

            def make_step():
                def step(carry, x):
                    print(carry)
                    return carry, x
                return step

            def run(xs):
                step = make_step()
                return jax.lax.scan(step, 0, xs)
        """,
    })
    assert "JP001" in unwaived_rules(lint_repo(root=str(root)))


def test_jax_purity_clean(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/batched/fix.py": """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def good(x, kind: str = "relu"):
                if kind == "relu":  # annotated-static hyperparameter
                    return jnp.maximum(x, 0.0)
                return jnp.where(x > 0, x, 0.0)

            def host_side(a):
                # not reachable from any jit/scan/vmap: host numpy is fine
                import numpy as np
                print("host", a)
                return np.sum(a)
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == []


def test_jax_purity_static_under_trace_tests_allowed(tmp_path):
    # `is None` / isinstance probe pytree *structure*, which is static
    root = make_repo(tmp_path, {
        "src/repro/core/batched/fix.py": """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def good(x, mask=None):
                if mask is not None:
                    x = x * mask
                return jnp.sum(x)
        """,
    })
    assert unwaived_rules(lint_repo(root=str(root))) == []


# ----------------------------------------------------------------------
# waivers

def test_inline_waiver_suppresses_and_reports(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import time

            T0 = time.time()  # lint: waive[DT002] boot stamp for log headers only
        """,
    })
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == []
    assert waived_rules(report) == ["DT002"]
    assert report.exit_code == 0
    (w,) = [v for v in report.violations if v.waived]
    assert w.waive_reason == "boot stamp for log headers only"


def test_comment_above_waiver_covers_next_line(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import time

            # lint: waive[DT002] boot stamp only
            T0 = time.time()
        """,
    })
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == [] and waived_rules(report) == ["DT002"]


def test_file_scope_waiver(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            # lint: waive-file[DT002] this module is legitimately wall-clocked
            import time

            def a():
                return time.time()

            def b():
                return time.monotonic()
        """,
    })
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == []
    assert waived_rules(report) == ["DT002", "DT002"]


def test_reasonless_waiver_is_wv001_and_does_not_waive(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": """
            import time

            T0 = time.time()  # lint: waive[DT002]
        """,
    })
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == ["DT002", "WV001"]
    assert report.exit_code == CATEGORY_BITS["R1"] | CATEGORY_BITS["WV"]


def test_unknown_rule_waiver_is_wv001(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "# lint: waive[XX999] because reasons\nX = 1\n",
    })
    assert unwaived_rules(lint_repo(root=str(root))) == ["WV001"]


def test_waiver_example_in_docstring_is_not_parsed():
    fw = parse_waivers("f.py", '"""Use `# lint: waive[DT002] reason` inline."""\n')
    assert not fw.file_scope and not fw.line_scope and not fw.errors


def test_malformed_waiver_is_flagged():
    fw = parse_waivers("f.py", "X = 1  # lint: waive DT002 forgot brackets\n")
    assert [v.rule for v in fw.errors] == ["WV001"]


def test_unused_waiver_noted(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "X = 1  # lint: waive[DT001] nothing here\n",
    })
    report = lint_repo(root=str(root))
    assert report.exit_code == 0
    assert any("unused waiver" in n for n in report.notes)


# ----------------------------------------------------------------------
# R4 schema drift (static)

SNAP_FIELDS = ("t", "config_id")
SNAP_OK = f"""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class SimSnapshot:
        SCHEMA_VERSION = 1
        _schema_digest = "{field_digest(SNAP_FIELDS)}"

        t: float
        config_id: int

    @dataclasses.dataclass(frozen=True)
    class EngineSnapshot:
        SCHEMA_VERSION = 1
        _schema_digest = "{field_digest(('sim',))}"

        sim: SimSnapshot
"""


def test_sd001_missing_schema_attrs(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/engine.py": """
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class SimSnapshot:
                t: float

            @dataclasses.dataclass(frozen=True)
            class EngineSnapshot:
                sim: SimSnapshot
        """,
    })
    report = lint_repo(root=str(root))
    # each class: missing SCHEMA_VERSION + missing digest
    assert unwaived_rules(report) == ["SD001"] * 4
    assert report.exit_code == CATEGORY_BITS["R4"]


def test_sd001_clean_with_pinned_digest(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/engine.py": SNAP_OK})
    assert unwaived_rules(lint_repo(root=str(root))) == []


def test_sd001_stale_digest_names_expected(tmp_path):
    bad = SNAP_OK.replace(field_digest(SNAP_FIELDS), "deadbeef")
    root = make_repo(tmp_path, {"src/repro/core/engine.py": bad})
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == ["SD001"]
    (v,) = [x for x in report.violations if not x.waived]
    assert field_digest(SNAP_FIELDS) in v.message


def test_field_digest_is_order_sensitive():
    assert field_digest(("a", "b")) != field_digest(("b", "a"))
    assert len(field_digest(("a",))) == 8


# ----------------------------------------------------------------------
# R3 version gate (--diff against synthetic git history)

PHYSICS_V1 = """
    SIM_VERSION = "sim-1"

    def service_rate(slots):
        return 1.0 * slots
"""


def _git_repo(tmp_path, files):
    root = make_repo(tmp_path, files)
    git(root, "init", "-q")
    commit_all(root)
    return root


def test_vg001_physics_change_without_bump(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": PHYSICS_V1})
    (root / "src/repro/core/simulator.py").write_text(
        textwrap.dedent(PHYSICS_V1).replace("1.0 * slots", "1.1 * slots")
    )
    report = lint_repo(root=str(root), diff_base="HEAD")
    assert unwaived_rules(report) == ["VG001"]
    assert report.exit_code == CATEGORY_BITS["R3"]
    (v,) = report.violations
    assert "SIM_VERSION" in v.message


def test_vg001_satisfied_by_version_bump(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": PHYSICS_V1})
    (root / "src/repro/core/simulator.py").write_text(
        textwrap.dedent(PHYSICS_V1)
        .replace("1.0 * slots", "1.1 * slots")
        .replace("sim-1", "sim-2")
    )
    assert unwaived_rules(lint_repo(root=str(root), diff_base="HEAD")) == []


def test_vg001_comment_only_change_is_exempt(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": PHYSICS_V1})
    (root / "src/repro/core/simulator.py").write_text(
        textwrap.dedent(PHYSICS_V1).replace(
            "def service_rate(slots):",
            "def service_rate(slots):\n    # linear speedup model\n",
        )
    )
    assert unwaived_rules(lint_repo(root=str(root), diff_base="HEAD")) == []


def test_vg001_added_line_waiver(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": PHYSICS_V1})
    (root / "src/repro/core/simulator.py").write_text(
        textwrap.dedent(PHYSICS_V1).replace(
            "return 1.0 * slots",
            "# lint: waive[VG001] pure refactor pinned by bit-identity tests\n"
            "    return 1.0 * slots + 0.0",
        )
    )
    report = lint_repo(root=str(root), diff_base="HEAD")
    assert unwaived_rules(report) == []
    assert waived_rules(report) == ["VG001"]


def test_vg001_preexisting_waiver_does_not_carry_over(tmp_path):
    # a waiver committed in an earlier PR must not bless later diffs
    waived_v1 = PHYSICS_V1.replace(
        "    def service_rate",
        "    # lint: waive[VG001] historical waiver\n    def service_rate",
    )
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": waived_v1})
    (root / "src/repro/core/simulator.py").write_text(
        textwrap.dedent(waived_v1).replace("1.0 * slots", "1.2 * slots")
    )
    assert unwaived_rules(lint_repo(root=str(root), diff_base="HEAD")) == ["VG001"]


def test_vg002_wal_change_without_format_bump(tmp_path):
    root = _git_repo(tmp_path, {
        "src/repro/service/records.py": """
            WAL_FORMAT = 1

            def encode(rec):
                return repr(rec)
        """,
    })
    (root / "src/repro/service/records.py").write_text(
        textwrap.dedent("""
            WAL_FORMAT = 1

            def encode(rec):
                return repr(rec) + "\\n"
        """)
    )
    report = lint_repo(root=str(root), diff_base="HEAD")
    assert unwaived_rules(report) == ["VG002"]
    (v,) = report.violations
    assert "WAL_FORMAT" in v.message


def test_sd002_field_change_without_schema_bump(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/engine.py": SNAP_OK})
    grown = SNAP_OK.replace(
        "t: float", "t: float\n        num_slices: int"
    ).replace(
        field_digest(SNAP_FIELDS), field_digest(("t", "num_slices", "config_id"))
    )
    (root / "src/repro/core/engine.py").write_text(textwrap.dedent(grown))
    report = lint_repo(root=str(root), diff_base="HEAD")
    # engine.py is also a physics file, so the no-bump edit trips VG001 too
    assert "SD002" in unwaived_rules(report)
    sd = [v for v in report.violations if v.rule == "SD002"]
    assert "SCHEMA_VERSION" in sd[0].message


def test_diff_gate_unfetchable_base_fails_loudly(tmp_path):
    root = _git_repo(tmp_path, {"src/repro/core/simulator.py": PHYSICS_V1})
    report = lint_repo(root=str(root), diff_base="origin/nonexistent")
    assert unwaived_rules(report) == ["VG001"]
    assert "fetch" in report.violations[0].message


def test_ast_fingerprint_ignores_docstrings():
    a = ast_fingerprint('def f():\n    """doc one."""\n    return 1\n')
    b = ast_fingerprint('def f():\n    """different doc."""\n    return 1\n')
    c = ast_fingerprint("def f():\n    return 2\n")
    assert a == b and a != c
    assert ast_fingerprint("def broken(:\n") is None


# ----------------------------------------------------------------------
# report schema / CLI / exit codes

def test_json_report_schema(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "import time\nT0 = time.time()\n",
    })
    d = lint_repo(root=str(root)).to_dict()
    assert d["version"] == 1
    assert set(d) == {
        "version", "files_checked", "violations", "summary", "notes", "exit_code",
    }
    assert d["summary"]["total"] == d["summary"]["unwaived"] == 1
    assert d["summary"]["by_category"] == {"R1": 1}
    (v,) = d["violations"]
    assert set(v) >= {"rule", "category", "path", "line", "col", "message", "waived"}
    json.dumps(d)  # must be serializable as-is


def test_cli_json_and_exit_code(tmp_path, capsys):
    from repro.lint.__main__ import main

    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "import time\nT0 = time.time()\n",
    })
    code = main(["--root", str(root), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert code == out["exit_code"] == CATEGORY_BITS["R1"]


def test_cli_human_output_and_list_rules(tmp_path, capsys):
    from repro.lint.__main__ import main

    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "import time\nT0 = time.time()\n",
    })
    assert main(["--root", str(root)]) == 1
    out = capsys.readouterr().out
    assert "DT002" in out and "1 violation(s)" in out

    assert main(["--list-rules"]) == 0
    listing = capsys.readouterr().out
    for rule in RULES:
        assert rule in listing


def test_exit_code_is_bitwise_or_of_categories(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/foo.py": "import time\nT0 = time.time()\n",
        "src/repro/core/batched/fix.py": PURITY_BAD,
    })
    report = lint_repo(root=str(root))
    assert report.exit_code == CATEGORY_BITS["R1"] | CATEGORY_BITS["R2"]


def test_exit_code_for_ignores_waived():
    v = Violation("DT001", "f.py", 1, 0, "m", waived=True, waive_reason="r")
    assert exit_code_for([v]) == 0
    assert exit_code_for([Violation("LE001", "f.py", 1, 0, "m")]) == 64


def test_syntax_error_is_le001(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/bad.py": "def broken(:\n"})
    report = lint_repo(root=str(root))
    assert unwaived_rules(report) == ["LE001"]
    assert report.exit_code == CATEGORY_BITS["internal"]


def test_rule_registry_categories_consistent():
    for rule in RULES:
        assert category_of(rule) in CATEGORY_BITS


def test_docs_catalog_in_sync_with_registry():
    doc = (REPO_ROOT / "docs" / "LINTING.md").read_text()
    for rule in RULES:
        assert rule in doc, f"{rule} missing from docs/LINTING.md"


# ----------------------------------------------------------------------
# acceptance: the real repo sweeps clean

def test_repo_sweep_is_clean():
    report = lint_repo(root=str(REPO_ROOT))
    offenders = [v for v in report.violations if not v.waived]
    assert not offenders, "\n".join(
        f"{v.path}:{v.line}: {v.rule} {v.message}" for v in offenders
    )
    assert report.exit_code == 0
    assert report.files_checked > 100
    # every waiver in the tree must carry its justification
    for v in report.violations:
        if v.waived:
            assert v.waive_reason
