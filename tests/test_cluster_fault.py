"""Cluster layer: roofline-derived elasticity, cluster days, fault tolerance."""

import numpy as np
import pytest

from repro.cluster.elasticity import arch_elasticity, classify_elasticity, service_minutes
from repro.cluster.workload import ClusterWorkloadSpec, generate_cluster_jobs
from repro.core.jobs import ElasticityClass
from repro.core.metrics import et_table
from repro.core.simulator import StaticPolicy
from repro.distributed.fault_tolerance import (
    FailureModel,
    HeartbeatMonitor,
    StragglerDetector,
)
from repro.launch.cluster_sim import queue_heuristic_policy, run_days


def test_elasticity_curves_are_valid():
    for arch, shape in [
        ("gemma3-1b", "decode_32k"),
        ("nemotron-4-340b", "train_4k"),
        ("mixtral-8x7b", "decode_32k"),
        ("xlstm-350m", "long_500k"),
    ]:
        e = arch_elasticity(arch, shape)
        assert e.throughput(1) == pytest.approx(1.0, rel=1e-6)
        prev = 0.0
        for k in range(1, 8):
            tp = e.throughput(k)
            assert tp >= prev - 1e-9  # monotone
            assert tp <= k + 1e-9  # never superlinear
            prev = tp


def test_elasticity_classes_emerge_from_roofline():
    # batch-1 recurrent decode cannot scale -> capped, always
    assert arch_elasticity("xlstm-350m", "long_500k").klass == ElasticityClass.CAPPED
    # across the serving mix, at least two distinct classes must emerge
    # (which cell lands in which class depends on whether analytic or
    # compiled-artifact terms are available — e.g. compiled FSDP training
    # is collective-bound and degrades from linear to sublinear)
    classes = {
        arch_elasticity(a, s).klass
        for a, s in [
            ("nemotron-4-340b", "train_4k"),
            ("gemma3-12b", "train_4k"),
            ("mixtral-8x7b", "decode_32k"),
            ("xlstm-350m", "long_500k"),
            ("whisper-base", "decode_32k"),
        ]
    }
    assert len(classes) >= 2, classes


def test_service_minutes_monotone_in_slots():
    for arch, shape in [("gemma3-12b", "train_4k"), ("mixtral-8x7b", "decode_32k")]:
        ts = [service_minutes(arch, shape, k) for k in range(1, 8)]
        assert all(b <= a + 1e-9 for a, b in zip(ts, ts[1:], strict=False))


def test_cluster_jobs_generation():
    jobs = generate_cluster_jobs(ClusterWorkloadSpec(horizon_min=240.0), seed=0)
    assert len(jobs) > 10
    for j in jobs:
        assert j.work > 0 and j.deadline > j.arrival


@pytest.mark.slow
def test_dynamic_beats_static_on_cluster():
    per = {
        "static": run_days(lambda: StaticPolicy(3), iterations=3),
        "dyn": run_days(queue_heuristic_policy, iterations=3),
    }
    table, _ = et_table(per)
    assert table["dyn"] < table["static"]


@pytest.mark.slow
def test_failure_injection_degrades_but_completes():
    fm = FailureModel(mtbf_minutes=8 * 60.0, seed=3)
    ok = run_days(queue_heuristic_policy, iterations=2, seed=5)
    bad = run_days(queue_heuristic_policy, iterations=2, failures=fm, seed=5)
    assert all(r.num_jobs > 0 for r in bad)  # all days complete
    # failures cost tardiness (lost work + degraded config)
    assert sum(r.avg_tardiness for r in bad) >= sum(r.avg_tardiness for r in ok) - 1e-6


def test_failure_model_sampling():
    fm = FailureModel(mtbf_minutes=100.0, repair_minutes=10.0, seed=0)
    ev = fm.sample_failures(7, 1000.0)
    assert ev == sorted(ev)
    assert all(0 <= t < 1000.0 and r == t + 10.0 for t, _, r in ev)
    assert len(ev) > 10  # ~7 slices x 10 expected failures


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(interval_min=1.0, misses_to_fail=3)
    hb.beat(0, t=0.0)
    hb.beat(1, t=0.0)
    assert hb.check(2.0) == []
    hb.beat(1, t=2.0)
    assert hb.check(3.5) == [0]  # slice 0 missed 3 intervals
    assert hb.check(3.6) == []  # reported once
    hb.beat(0, t=4.0)  # recovery
    assert 0 not in hb.failed


def test_straggler_detector():
    sd = StragglerDetector(straggler_factor=0.7, alpha=1.0)
    assert not sd.observe(0, observed_rate=1.0, nominal_rate=1.0)
    assert sd.observe(0, observed_rate=0.5, nominal_rate=1.0)
    sd.reset(0)
    assert not sd.observe(0, observed_rate=1.0, nominal_rate=1.0)
