"""The fused on-device RL trainer vs its host oracles (DESIGN.md §11).

Four layers, mirroring the two-backend discipline of test_batched.py:

* observation parity — ``device_observations`` against the batched env's
  host-side ``_obs`` (the reference implementation),
* the batch-of-1 property — a ``BatchedRepartitionEnv`` rollout driven by
  a fixed action trace must reproduce the host cadence-mode
  ``RepartitionEnv`` (obs layout, reward scale, termination) within the
  documented physics tolerances, across scenarios × repartition modes,
* learner agreement — one scan-embedded jitted TD update equals the host
  ``DQNLearner``'s update on an identical replay batch (1e-5),
* the trainer itself — n-step/replay accounting, a training smoke, and
  the checked-in RL baseline's claim + params probe.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rl.dqn import DQNConfig, DQNLearner, make_td_update
from repro.core.rl.env import FEATURE_DIM, RepartitionEnv, RewardWeights, make_batched_env
from repro.core.rl.batched_train import (
    BatchedTrainConfig,
    device_observations,
    shard_rollouts,
    train_dqn_batched,
)

BASELINES = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "baselines")


def _cfg(**kw):
    kw.setdefault("state_dim", FEATURE_DIM)
    kw.setdefault("seed", 0)
    return DQNConfig(**kw)


def _obs_via_device(env):
    """Run device_observations on the batched env's internals."""
    return np.asarray(
        device_observations(
            env._state,
            jnp.asarray(env._jobs.arrival, jnp.float32),
            jnp.asarray(env._jobs.deadline, jnp.float32),
            jnp.asarray(env._jobs.valid),
            jnp.asarray(env._jobs.edf_order),
            jnp.asarray(env._inv_mean_dur, jnp.float32),
            jnp.asarray(env.tables.config_ids),
            jnp.float32(env._t),
        )
    )


@pytest.mark.parametrize("scenario", ["paper-diurnal", "bursty-mmpp"])
def test_device_observations_match_host_obs(scenario):
    """The jit mirror reproduces ``BatchedRepartitionEnv._obs`` everywhere
    along an episode (float32 bin inputs may flip an exact-edge bin, so a
    tiny mismatch budget is allowed; measured: zero mismatches)."""
    env = make_batched_env(
        scenario=scenario, scenario_kwargs={"load_scale": 0.3}
    )
    host = env.reset(seeds=(11, 12, 13))
    mism, total = 0, 0
    dev = _obs_via_device(env)
    assert dev.shape == host.shape == (3, FEATURE_DIM)
    mism += int((np.abs(dev - host) > 1e-6).sum())
    total += dev.size
    rng = np.random.default_rng(0)
    for _ in range(40):
        if env.done:
            break
        obs, _, _, _, _ = env.step(rng.integers(0, 12, size=3))
        dev = _obs_via_device(env)
        mism += int((np.abs(dev - obs) > 1e-6).sum())
        total += dev.size
    assert total > 3 * FEATURE_DIM  # the episode actually ran
    assert mism / total <= 0.01


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["paper-diurnal", "bursty-mmpp"])
@pytest.mark.parametrize("mode", ["drain", "partial"])
def test_batch_of_one_reproduces_host_env(scenario, mode):
    """Batch-of-1 property (DESIGN.md §11): same seed, same fixed action
    trace -> the batched rollout tracks the host cadence-mode env's obs,
    rewards and termination within the documented physics tolerances
    (docs/BATCHED_SIM.md §4 — dt-grid completion vs exact event times)."""
    seed, interval, load = 21, 15.0, 0.3
    kw = dict(scenario=scenario, scenario_kwargs={"load_scale": load})
    henv = RepartitionEnv(
        scheduler_name="EDF-FS", repartition_mode=mode,
        decision_interval_min=interval, **kw,
    )
    benv = make_batched_env(
        repartition_mode=mode, decision_interval_min=interval, **kw,
    )
    hobs = henv.reset(seed=seed)
    bobs = benv.reset(seeds=(seed,))
    np.testing.assert_allclose(bobs[0], hobs, atol=1e-6)

    rng = np.random.default_rng(3)
    h_cum = b_cum = 0.0
    h_steps = b_steps = 0
    obs_mismatch = obs_total = 0
    h_done = b_done = False
    for _ in range(200):
        if h_done and b_done:
            break
        a = int(rng.integers(0, 12))
        if not h_done:
            hobs, hr, ht, htr, _ = henv.step(a)
            h_cum += hr
            h_steps += 1
            h_done = ht or htr
        if not b_done:
            bobs, br, bt, btr, _ = benv.step([a])
            b_cum += float(br[0])
            b_steps += 1
            b_done = bool((bt | btr)[0])
        if not (h_done or b_done):
            obs_mismatch += int((np.abs(bobs[0] - hobs) > 1e-6).sum())
            obs_total += hobs.size
    # identical decision grid -> near-identical episode length (the dt
    # grid can move the drain across one interval boundary)
    assert abs(h_steps - b_steps) <= 1
    assert h_done and b_done
    # binned features agree except for occasional edge flips
    assert obs_total > 0
    assert obs_mismatch / obs_total <= 0.02
    # reward scale: cumulative returns within the backend tolerance band
    assert b_cum == pytest.approx(h_cum, rel=0.25, abs=0.5)
    # physics accumulators at the end of the day
    hres = henv.result()
    bres = benv.results()[0]
    assert bres.energy_wh == pytest.approx(hres.energy_wh, rel=0.02)
    assert bres.avg_tardiness == pytest.approx(hres.avg_tardiness, abs=0.5)


def test_jitted_training_step_matches_learner():
    """The agreement rule: a scan-embedded ``make_td_update`` step equals
    ``DQNLearner._update`` on an identical batch to 1e-5 (measured 0.0 —
    both jit the same function)."""
    cfg = _cfg(min_buffer=1)
    learner = DQNLearner(cfg)
    rng = np.random.default_rng(7)
    bs, d = cfg.batch_size, cfg.state_dim
    batch = (
        jnp.asarray(rng.normal(size=(bs, d)).astype(np.float32)),
        jnp.asarray(rng.integers(0, cfg.num_actions, bs).astype(np.int32)),
        jnp.asarray(rng.normal(size=bs).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bs, d)).astype(np.float32)),
        jnp.asarray((rng.uniform(size=bs) < 0.1).astype(np.float32)),
        jnp.full((bs,), cfg.gamma**cfg.n_step, jnp.float32),
    )
    host_params, _, host_loss = learner._update(
        learner.params, learner.target, learner.opt_state, *batch
    )
    _, td_update = make_td_update(cfg)

    @jax.jit
    def scan_once(params, target, opt_state):
        def body(carry, _):
            p, o = carry
            p2, o2, loss = td_update(p, target, o, *batch)
            return (p2, o2), loss

        (p, _), losses = jax.lax.scan(body, (params, opt_state), jnp.arange(1))
        return p, losses[0]

    scan_params, scan_loss = scan_once(
        learner.params, learner.target, learner.opt_state
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(host_params),
        jax.tree_util.tree_leaves(scan_params), strict=True,
    ):
        assert float(jnp.max(jnp.abs(a - b))) <= 1e-5
    assert abs(float(host_loss) - float(scan_loss)) <= 1e-5


@pytest.mark.slow
def test_nstep_replay_accounting_one_transition_per_live_step():
    """Replay semantics: with no truncation, every live decision step emits
    exactly one n-step transition (maturation at lag n-1 + the terminal
    flush of the shorter lags) — the same count NStepAccumulator produces.
    Checked through the real round program on a drained round."""
    from repro.core.batched.backend import device_constants, init_state
    from repro.core.batched.state import BatchedJobs
    from repro.core.batched.tables import build_tables
    from repro.core.jobs import ALL_SLICE_SIZES
    from repro.core.rl.batched_train import _make_round_fn
    from repro.core.scenarios import generate_scenario

    cfg = _cfg(n_step=4, min_buffer=10_000_000)  # never train: pure emission
    tcfg = BatchedTrainConfig(batch=3, horizon_decisions=120)
    tables = build_tables()
    consts = device_constants(tables, tcfg.repartition_mode)
    round_fn = _make_round_fn(cfg, tcfg, RewardWeights(), tables, consts)

    chunks = [
        generate_scenario("paper-diurnal", seed=s, load_scale=0.2)
        for s in (1, 2, 3)
    ]
    jobs = BatchedJobs.from_job_lists(chunks, max_slots=tables.max_slots)
    inv = np.zeros(jobs.arrival.shape, np.float32)
    for b, js in enumerate(chunks):
        for j, job in enumerate(js):
            inv[b, j] = sum(
                1.0 / job.rate_on(float(k), True) for k in ALL_SLICE_SIZES
            ) / len(ALL_SLICE_SIZES)

    D, cap = cfg.state_dim, tcfg.replay_capacity
    replay = (
        jnp.zeros((cap, D), jnp.float32), jnp.zeros((cap,), jnp.int32),
        jnp.zeros((cap,), jnp.float32), jnp.zeros((cap, D), jnp.float32),
        jnp.zeros((cap,), jnp.float32), jnp.zeros((cap,), jnp.float32),
        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
    )
    learner = DQNLearner(cfg)
    env0 = init_state(jobs, np.full((3,), tables.index_of(2), np.int32))
    arrays = tuple(
        jnp.asarray(a)
        for a in (jobs.arrival, jobs.deadline, jobs.rate_by_slots,
                  jobs.valid, jobs.edf_order, inv)
    )
    (env, _p, _t, _o, replay, gstep, updates, _k, outs) = round_fn(
        env0, learner.params, learner.target, learner.opt_state, replay,
        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
        jax.random.PRNGKey(5), *arrays,
    )
    live = np.asarray(outs[1])
    assert not live[-1].any(), "episodes must drain inside the horizon"
    size = int(replay[7])
    assert size == int(gstep) == int(live.sum())
    assert int(updates) == 0  # min_buffer gate held


@pytest.mark.slow
def test_train_dqn_batched_smoke_and_stats():
    """End-to-end smoke: two rounds train, update, and report stats whose
    pieces are mutually consistent."""
    cfg = _cfg(min_buffer=64, batch_size=32, eps_decay_steps=500)
    tcfg = BatchedTrainConfig(
        batch=4, horizon_decisions=110,
        scenario_kwargs={"load_scale": 0.2},
    )
    learner, stats = train_dqn_batched(
        num_episodes=8, dqn_config=cfg, train_config=tcfg, seed=3
    )
    assert stats.episodes == 8 and stats.rounds == 2 and stats.batch == 4
    assert len(stats.episode_rewards) == 8
    assert len(stats.episode_et_proxy) == 8
    assert stats.env_steps > 0
    assert stats.env_steps == sum(stats.round_env_steps)
    assert stats.updates > 0 and len(stats.losses) > 0
    assert np.isfinite(stats.losses).all()
    assert 0.0 <= stats.final_epsilon <= 1.0
    for w, b in learner.params:
        assert np.isfinite(np.asarray(w)).all()
        assert np.isfinite(np.asarray(b)).all()
    # the trained learner is a regular host learner: greedy path works
    a = learner.greedy_action(np.zeros(FEATURE_DIM, np.float32))
    assert 0 <= a < cfg.num_actions
    # epsilon advanced along the *global step* schedule
    assert stats.final_epsilon == pytest.approx(
        learner.epsilon_at_step(stats.env_steps)
    )


def test_train_dqn_backend_dispatch_validation():
    from repro.core.rl.train import train_dqn

    with pytest.raises(ValueError, match="EDF-FS"):
        train_dqn(num_episodes=1, backend="batched", scheduler_name="EDF-SS")
    with pytest.raises(ValueError, match="unknown backend"):
        train_dqn(num_episodes=1, backend="nope")
    with pytest.raises(ValueError, match="host-backend only"):
        train_dqn(
            num_episodes=1, backend="batched", scheduler_name="EDF-FS",
            guide=object(),
        )


def test_shard_rollouts_single_device_noop():
    tree = {"a": jnp.zeros((4, 3)), "b": jnp.zeros((7,))}
    out = shard_rollouts(tree, devices=jax.devices()[:1])
    assert out is tree  # identity on one device


def test_rl_baseline_claim_and_params_probe():
    """The checked-in RL baseline: the batch-trained policy beats the
    forecast controller on >=1 scenario family, and the params file still
    produces the greedy actions recorded at train time (probe pin)."""
    path = os.path.join(BASELINES, "rl_batched.json")
    with open(path) as f:
        entry = json.load(f)
    assert entry["families_beaten"], "baseline must record >=1 family win"
    for row in entry["rows"]:
        assert row["dqn_beats_forecast"] == (
            row["scenario"] in entry["families_beaten"]
        )
    probe = entry["params_probe"]
    learner = DQNLearner(_cfg())
    learner.load(os.path.join(BASELINES, "rl_dqn_params.npz"))
    rng = np.random.default_rng(probe["seed"])
    obs = rng.uniform(0.0, 1.0, size=(len(probe["actions"]), FEATURE_DIM))
    acts = [learner.greedy_action(o.astype(np.float32)) for o in obs]
    assert acts == probe["actions"]
