"""Slot-placed partitions, TransitionPlan, and partial repartitioning.

Pins the mig-sim-4 transition model:

* every Fig. 1 / A30 configuration sits on the NVIDIA placement grid, and
  ``validate_config_table`` rejects misaligned/overlapping layouts;
* ``transition`` matches slice instances by placement identity — identity
  transitions survive everything, disjoint layouts are full turnover;
* under ``repartition_mode="partial"`` jobs on surviving instances run
  through the 4 s stall (and may even complete inside it), the stall is
  charged only against the affected slots, and survivors keep their seat
  across the index renumbering without a phantom preemption;
* drain-compat: ``"partial"`` and ``"drain"`` are bit-identical whenever
  every transition a run performs is a full turnover, and across the policy
  family × scenario matrix partial never exceeds drain on preemptions;
* satellites: zero-work jobs complete without ever holding a slice (per
  scheduler family), and an out-of-table initial configuration fails at
  engine construction with a clear error.
"""

import pytest

from repro.core.engine import SimulationEngine
from repro.core.jobs import Job, JobKind, LINEAR
from repro.core.power import A30_165W
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler, remap_assignment
from repro.core.simulator import (
    CallbackPolicy,
    DayNightPolicy,
    MIGSimulator,
    REPARTITION_PENALTY_MIN,
    StaticPolicy,
)
from repro.core.slices import (
    A30_CONFIGS,
    MIG_CONFIGS,
    Partition,
    SliceType,
    auto_starts,
    placement_alignment,
    transition,
    validate_config_table,
)
from repro.core.workload import WorkloadSpec, generate_jobs
from repro.launch.cluster_sim import queue_heuristic_policy

SCHEDULER_NAMES = ("EDF-FS", "EDF-SS", "EDF-SS-unrestricted", "LLF", "LALF")


def _sim(mode="partial", name="EDF-SS", **kw):
    return MIGSimulator(make_scheduler(name), repartition_mode=mode, **kw)


# ----------------------------------------------------------------------
# placement grid


def test_fig1_placements_match_nvidia_grid():
    """Auto-layout reproduces the documented A100 placements for all 12."""
    expected_starts = {
        1: (0,),
        2: (0, 4),
        3: (0, 4, 6),
        4: (0, 4, 5, 6),
        5: (0, 4),  # 1-slot hole at 3: the second 3g aligns to 4
        6: (0, 2, 4),
        7: (0, 2, 3, 4),
        8: (0, 1, 2, 3, 4),
        9: (0, 2, 4, 6),
        10: (0, 2, 4, 5, 6),
        11: (0, 2, 3, 4, 5, 6),
        12: (0, 1, 2, 3, 4, 5, 6),
    }
    for cid, part in MIG_CONFIGS.items():
        assert part.starts == expected_starts[cid], cid
    assert A30_CONFIGS[3].starts == (0, 2, 3)


def test_placement_alignment_rule():
    assert placement_alignment(1) == 1
    assert placement_alignment(2) == 2
    assert placement_alignment(3) == 4
    assert placement_alignment(4) == 4
    # left-packed layout skips to the alignment boundary
    assert auto_starts((3, 3)) == (0, 4)
    assert auto_starts((1, 2)) == (0, 2)
    assert auto_starts((1, 3)) == (0, 4)


def test_validate_config_table_rejects_bad_placements():
    s2, s3 = SliceType(2, 10), SliceType(3, 20)
    with pytest.raises(AssertionError, match="placement alignment"):
        validate_config_table(
            {1: Partition(1, (s2,), starts=(1,))}, 7, 40
        )
    with pytest.raises(AssertionError, match="overlaps"):
        validate_config_table(
            {1: Partition(1, (s3, s2), starts=(0, 2))}, 7, 40
        )
    with pytest.raises(AssertionError, match="grid"):
        validate_config_table(
            {1: Partition(1, (s3,), starts=(4,))}, 6, 40
        )
    with pytest.raises(ValueError, match="starts"):
        Partition(1, (s2, s3), starts=(0,))


# ----------------------------------------------------------------------
# transition plans


def test_transition_identity_and_full_turnover():
    for part in MIG_CONFIGS.values():
        plan = transition(part, part)
        assert not plan.destroyed and not plan.created
        assert plan.stalled_slots == 0
        assert len(plan.surviving) == part.num_slices
        assert not plan.full_turnover or part.num_slices == 0
    # 7g@0 shares nothing with any split layout
    plan = transition(MIG_CONFIGS[1], MIG_CONFIGS[2])
    assert plan.full_turnover
    assert plan.stalled_slots == 7


def test_transition_survivors_are_placement_identical():
    # cfg5 (3g@0 + 3g@4) -> cfg2 (4g@0 + 3g@4): the 3g@4 instance survives
    plan = transition(MIG_CONFIGS[5], MIG_CONFIGS[2])
    assert plan.surviving == ((1, 1),)
    assert plan.destroyed == (0,)
    assert plan.created == (0,)
    assert plan.stalled_slots == 4  # cells 0-3 are rebuilt
    # cfg3 -> cfg2: the 4g@0 survives, 2g@4 + 1g@6 collapse into 3g@4
    plan = transition(MIG_CONFIGS[3], MIG_CONFIGS[2])
    assert plan.survivor_map == {0: 0}
    assert plan.stalled_slots == 3
    # every survivor pair is the identical placed instance
    for old_cid in MIG_CONFIGS:
        for new_cid in MIG_CONFIGS:
            old, new = MIG_CONFIGS[old_cid], MIG_CONFIGS[new_cid]
            plan = transition(old, new)
            for i, j in plan.surviving:
                assert old.slice_instances()[i] == new.slice_instances()[j]


def test_remap_assignment_is_identity_stable():
    assert remap_assignment({7: 1, 9: 0}, {0: 0, 1: 1}) == {7: 1, 9: 0}
    assert remap_assignment({7: 1}, {1: 0}) == {7: 0}
    with pytest.raises(AssertionError, match="non-surviving"):
        remap_assignment({7: 2}, {1: 0})


# ----------------------------------------------------------------------
# partial repartition semantics


class _SwitchOnceAt:
    """Switch to ``target`` at the first decision point at/after ``t_at``."""

    def __init__(self, initial, target, t_at):
        self.initial_config = initial
        self.target = target
        self.t_at = t_at
        self.done = False

    def decide(self, t, sim):
        if not self.done and t >= self.t_at:
            self.done = True
            return self.target
        return None

    def next_timer(self, t):
        return None if self.done else max(self.t_at, t + 1e-3)


def test_survivor_runs_through_stall_and_busy_slots_are_charged():
    # one job on the 4g@0 of cfg3 (EDF-FS: fastest slice); switch cfg3 ->
    # cfg2 mid-run: the 4g instance survives, the job keeps depleting
    # through the 4 s window, and the busy-slot accounting never stalls
    job = Job(0, JobKind.TRAINING, 0.0, work=30.0, deadline=100.0, elasticity=LINEAR)
    sim = _sim("partial", "EDF-FS")
    engine = SimulationEngine(
        sim, policy=_SwitchOnceAt(3, 2, 1.0), jobs=[job]
    )
    engine.run_until(1.0)
    assert sim.assignment[0] == 0  # seated on the surviving 4g@0
    engine.drain()
    res = engine.result()
    assert res.repartitions == 1
    assert res.preemptions == 0  # survivor never preempted, even renumbered
    assert job.completion == pytest.approx(7.5)  # 30 1g-min on 4g, no stall
    assert res.busy_slot_minutes == pytest.approx(30.0)


def test_survivor_can_complete_inside_the_stall_window():
    # job finishes 2 s into the 4 s stall: its completion event must fire
    # inside the window, not be deferred to REPART_DONE
    job = Job(0, JobKind.INFERENCE, 0.0, work=3.0, deadline=50.0, elasticity=LINEAR)
    switch_at = 0.75 - REPARTITION_PENALTY_MIN / 2.0
    sim = _sim("partial", "EDF-FS")
    engine = SimulationEngine(sim, policy=_SwitchOnceAt(3, 2, switch_at), jobs=[job])
    engine.drain()
    res = engine.result()
    assert res.repartitions == 1
    assert job.completion == pytest.approx(0.75)  # 3 1g-min on 4g
    assert res.preemptions == 0


def test_stalled_slots_in_snapshot_partial_vs_drain():
    job = Job(0, JobKind.TRAINING, 0.0, work=30.0, deadline=100.0, elasticity=LINEAR)
    for mode, expected in (("partial", 4), ("drain", 6)):
        sim = _sim(mode)
        engine = SimulationEngine(sim, policy=_SwitchOnceAt(5, 2, 1.0), jobs=[job])
        engine.run_until(1.0 + REPARTITION_PENALTY_MIN / 2.0)
        snap = sim.snapshot()
        assert snap.repartitioning
        assert snap.stalled_slots == expected, mode
        engine.drain()
        assert sim.snapshot().stalled_slots == 0


def test_occupied_slices_snapshot_field():
    job = Job(0, JobKind.TRAINING, 0.0, work=30.0, deadline=100.0, elasticity=LINEAR)
    sim = _sim("partial")
    engine = SimulationEngine(sim, policy=StaticPolicy(5), jobs=[job])
    engine.run_until(1.0)
    assert sim.snapshot().occupied_slices == tuple(sorted(set(sim.assignment.values())))
    engine.drain()
    assert sim.snapshot().occupied_slices == ()


# ----------------------------------------------------------------------
# drain-compat properties (satellite)

#: policies whose every transition is a full turnover on the A100 grid
#: (cfg1's 7g@0 shares no instance with cfg6's 2+2+3 layout)
_FULL_TURNOVER_POLICIES = {
    "daynight-1-6": lambda: DayNightPolicy(day_config=6, night_config=1),
    "switch-once-5-1": lambda: _SwitchOnceAt(5, 1, 60.0),
}

_PROPERTY_SCENARIOS = (
    ("trace-scaled", 3),
    ("bursty-mmpp", 5),
    ("weekend-flat", 11),
)
_SCENARIO_KW = {"horizon_min": 180.0}


@pytest.mark.parametrize("policy_name", sorted(_FULL_TURNOVER_POLICIES))
@pytest.mark.parametrize("scheduler", ("EDF-FS", "EDF-SS", "LLF", "LALF"))
def test_partial_equals_drain_on_full_turnover(policy_name, scheduler):
    """Property: when no transition shares a slice instance, the partial
    model degenerates to the drain model bit for bit."""
    factory = _FULL_TURNOVER_POLICIES[policy_name]
    for scenario, seed in _PROPERTY_SCENARIOS:
        results = {}
        for mode in ("partial", "drain"):
            jobs = generate_scenario(scenario, seed=seed, **_SCENARIO_KW)
            sim = _sim(mode, scheduler)
            results[mode] = (
                sim.run(jobs, policy=factory()),
                sim.config_trace,
                sim.util_histogram,
            )
        assert results["partial"] == results["drain"], (
            policy_name, scheduler, scenario, seed,
        )


@pytest.mark.slow
def test_partial_never_exceeds_drain_preemptions_across_matrix():
    """Across the policy-family × scenario matrix on identical job streams,
    the partial transition model's preemption total never exceeds drain's
    (per-family, summed over the scenario/seed matrix: single-run ties can
    go either way through trajectory divergence, the family totals must
    not)."""
    families = {
        "daynight": lambda: DayNightPolicy(),
        "heuristic": lambda: queue_heuristic_policy(),
    }
    for fname, factory in families.items():
        totals = {"partial": 0, "drain": 0}
        for scenario, seed in _PROPERTY_SCENARIOS:
            for mode in totals:
                jobs = generate_scenario(scenario, seed=seed, **_SCENARIO_KW)
                sim = _sim(mode)
                totals[mode] += sim.run(jobs, policy=factory()).preemptions
        assert totals["partial"] <= totals["drain"], (fname, totals)


# ----------------------------------------------------------------------
# forecast controller under the partial transition model


def test_forecast_partial_defers_displacing_switches(monkeypatch):
    """Opportunistic switch timing: a wanted transition that would tear a
    slice out from under a running job is deferred (bounded), and lands
    immediately at a displacement-free instant."""
    from repro.forecast import ForecastPolicy

    def rigged(policy):
        monkeypatch.setattr(
            policy, "_best_config", lambda *a, **k: (2, {2: 0.0, 3: 1.0})
        )
        return policy

    job = Job(0, JobKind.TRAINING, 0.0, work=30.0, deadline=100.0, elasticity=LINEAR)

    # job on cfg3's 2g@4 (destroyed by 3 -> 2): defer, then force after the
    # window expires
    policy = rigged(ForecastPolicy(
        repartition_mode="partial", min_dwell_min=0.0, eval_interval_min=0.0,
    ))
    sim = MIGSimulator(make_scheduler("EDF-FS"))
    sim.reset(3)
    sim.active[0] = job
    sim.assignment = {0: 1}
    assert policy.decide(1.0, sim) is None  # displaced runner: deferred
    assert policy.decide(1.0 + policy.max_defer_min + 0.1, sim) == 2

    # same state but the job sits on the surviving 4g@0: switch immediately
    policy2 = rigged(ForecastPolicy(
        repartition_mode="partial", min_dwell_min=0.0, eval_interval_min=0.0,
    ))
    sim.assignment = {0: 0}
    assert policy2.decide(1.0, sim) == 2

    # drain pricing never defers (legacy decision sequence preserved)
    policy3 = rigged(ForecastPolicy(
        repartition_mode="drain", min_dwell_min=0.0, eval_interval_min=0.0,
    ))
    sim.assignment = {0: 1}
    assert policy3.decide(1.0, sim) == 2


def test_legacy_cell_without_mode_key_replays_as_drain():
    """A pre-mig-sim-4 cell (no repartition_mode anywhere) must replay
    bit-identically to an explicit drain cell with drain pricing — the
    compatibility rule behind the checked-in-baseline reproducibility."""
    from repro.sweep.cells import make_scenario_cell, run_cell

    explicit = make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-SS",
        scenario="weekend-flat", scenario_kwargs={"horizon_min": 240.0},
        seed=5, policy="forecast",
        policy_kwargs={"scenario": "weekend-flat", "repartition_mode": "drain"},
        repartition_mode="drain",
    )
    legacy = {k: v for k, v in explicit.items() if k != "repartition_mode"}
    legacy["policy_kwargs"] = {
        k: v for k, v in explicit["policy_kwargs"].items()
        if k != "repartition_mode"
    }
    out_explicit = {k: v for k, v in run_cell(explicit).items() if k != "elapsed_s"}
    out_legacy = {k: v for k, v in run_cell(legacy).items() if k != "elapsed_s"}
    assert out_explicit == out_legacy


def test_baseline_partial_beats_drain_for_forecast_on_paper_diurnal():
    """The PR's acceptance row, pinned against the checked-in baseline: on
    paper-diurnal the forecast policy under partial strictly reduces
    preemptions at an equal-or-better ET vs drain."""
    import json
    import os

    from repro.sweep.grids import GRIDS

    baseline = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "baselines",
        "repartition_modes.jsonl",
    )
    assert os.path.exists(baseline), "repartition_modes baseline missing"
    cells, results = [], []
    with open(baseline) as f:
        for line in f:
            if line.strip():
                rec = json.loads(line)
                cells.append(rec["cell"])
                results.append(rec["result"])
    rows = GRIDS["repartition_modes"].aggregate(cells, results)
    by_key = {(r["scenario"], r["family"]): r for r in rows}
    fc = by_key[("paper-diurnal", "Forecast")]
    assert fc["partial_cuts_preemptions"], fc
    assert fc["preemptions_partial"] < fc["preemptions_drain"]
    assert fc["ET_partial"] <= fc["ET_drain"], fc
    # the heuristic family shows the raw physics win (hundreds of switches)
    hr = by_key[("paper-diurnal", "Heuristic")]
    assert hr["preemptions_partial"] < hr["preemptions_drain"]


# ----------------------------------------------------------------------
# zero-work jobs complete without ever holding a slice (satellite)


@pytest.mark.parametrize("scheduler", SCHEDULER_NAMES)
def test_zero_work_job_completes_at_arrival(scheduler):
    jobs = [
        Job(0, JobKind.TRAINING, 0.0, work=10.0, deadline=40.0, elasticity=LINEAR),
        Job(1, JobKind.INFERENCE, 2.0, work=0.0, deadline=5.0, elasticity=LINEAR),
    ]
    sim = MIGSimulator(make_scheduler(scheduler))
    res = sim.run(jobs, policy=StaticPolicy(1))
    assert res.num_jobs == 2
    assert jobs[1].completion == pytest.approx(2.0)
    assert jobs[1].tardiness() == 0.0
    assert not sim.active


@pytest.mark.parametrize("scheduler", SCHEDULER_NAMES)
def test_zero_work_job_injected_into_open_stream_drains(scheduler):
    """Regression: an injected zero-work arrival used to leak in ``active``
    forever and drain() on the closed stream never terminated."""
    sim = MIGSimulator(make_scheduler(scheduler))
    engine = SimulationEngine(sim, policy=StaticPolicy(3), stream_open=True)
    engine.inject(Job(0, JobKind.INFERENCE, 1.0, 1.0, 10.0, LINEAR))
    engine.run_until(5.0)
    engine.inject(Job(1, JobKind.INFERENCE, 6.0, 0.0, 7.0, LINEAR))
    engine.close_stream()
    engine.drain()
    assert engine.finished
    res = engine.result()
    assert res.num_jobs == 2
    assert res.deadline_misses == 0


# ----------------------------------------------------------------------
# initial-config validation (satellite)


def test_out_of_table_initial_config_fails_at_construction():
    """CallbackPolicy's hard-coded initial_config=2 on a table lacking id 2
    must produce a clear construction-time error, not a bare KeyError."""
    table = {1: A30_CONFIGS[1]}  # a device exposing only the full layout
    sim = MIGSimulator(
        make_scheduler("EDF-SS"), power_model=A30_165W, config_table=table
    )
    policy = CallbackPolicy(lambda t, s: None)  # initial_config=2 default
    with pytest.raises(ValueError, match="CallbackPolicy.*valid ids \\[1\\]"):
        SimulationEngine(sim, policy=policy, jobs=[])
    # the explicit override path is validated identically
    with pytest.raises(ValueError, match="initial_config override"):
        SimulationEngine(sim, policy=StaticPolicy(1), initial_config=9, jobs=[])


def test_device_adapted_policy_maps_initial_config_onto_a30():
    """DeviceAdaptedPolicy translation keeps an A100-space policy usable on
    the A30 table end to end (the PR-3 guard's mirror for initial configs)."""
    from repro.fleet import DeviceAdaptedPolicy

    inner = CallbackPolicy(lambda t, s: None, initial_config=12)
    adapted = DeviceAdaptedPolicy(inner, A30_CONFIGS)
    assert adapted.initial_config in A30_CONFIGS
    sim = MIGSimulator(
        make_scheduler("EDF-SS"), power_model=A30_165W, config_table=A30_CONFIGS
    )
    jobs = generate_jobs(WorkloadSpec(horizon_min=120.0, constant_rate=0.3), 4)
    res = sim.run(jobs, policy=adapted)
    assert res.num_jobs == len(jobs)
