"""Launch-layer tests: training driver end-to-end, serve driver, dry-run
utilities that don't need the 512-device process."""

import numpy as np
import pytest

from repro.launch.shapes import SHAPES, accum_steps_for, all_cells, cell_applicable


@pytest.mark.slow
def test_train_driver_reduces_loss(tmp_path):
    from repro.launch.train import train

    _, losses = train(
        "gemma3_1b",
        steps=40,
        smoke=True,
        global_batch=4,
        seq_len=128,
        lr=2e-3,
        ckpt_dir=str(tmp_path),
        ckpt_every=20,
        verbose=False,
    )
    assert len(losses) == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
    # checkpoint was written and resume picks it up
    from repro.checkpoint import latest_step

    assert latest_step(str(tmp_path)) == 40


@pytest.mark.slow
def test_serve_driver_runs():
    from repro.launch.serve import serve

    tps = serve("stablelm_3b", smoke=True, batch=2, steps=6, max_len=32, verbose=False)
    assert tps > 0


def test_all_cells_enumerates_40():
    cells = list(all_cells())
    assert len(cells) == 40
    skips = [c for c in cells if not cell_applicable(c[0], c[1].name)[0]]
    assert len(skips) == 5  # DESIGN.md §4


def test_accum_steps_divide_batch():
    for arch, shape in all_cells():
        if shape.kind != "train":
            continue
        a = accum_steps_for(arch, shape, data_parallel=16)
        assert shape.global_batch % a == 0
        assert (shape.global_batch // a) % 16 == 0 or shape.global_batch // a < 16


def test_collective_parser():
    from repro.launch import dryrun  # noqa: F401  (sets XLA flags; 1-proc ok)

    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather(bf16[4,64]{1,0} %y), dimensions={1}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z)
  %notacoll = f32[2,2]{1,0} add(f32[2,2] %a, f32[2,2] %b)
"""
    out = dryrun.parse_collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 4 * 256 * 2
    assert out["collective-permute"] == 8 * 4
    assert "add" not in out and len(out) == 3
