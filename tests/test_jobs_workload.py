"""Job elasticity + §V-A workload generation (unit + hypothesis)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.jobs import (
    SUBLINEAR_CURVES,
    ElasticityClass,
    Job,
    JobKind,
    LINEAR,
    capped,
)
from repro.core.workload import DIURNAL_RATE_PER_MIN, WorkloadSpec, arrival_rate, generate_jobs


def test_linear_elasticity():
    for k in (1, 2, 3, 4, 7):
        assert LINEAR.throughput(k) == k


def test_capped_elasticity():
    e = capped(3)
    assert e.throughput(1) == 1
    assert e.throughput(3) == 3
    assert e.throughput(7) == 3
    with pytest.raises(ValueError):
        capped(5)


@given(st.sampled_from(list(SUBLINEAR_CURVES)), st.floats(1.0, 7.0), st.floats(1.0, 7.0))
@settings(max_examples=60, deadline=None)
def test_sublinear_properties(label, k1, k2):
    e = SUBLINEAR_CURVES[label]
    assert e.throughput(1.0) == pytest.approx(1.0, abs=1e-9)
    lo, hi = min(k1, k2), max(k1, k2)
    # monotone nondecreasing, but never superlinear
    assert e.throughput(hi) >= e.throughput(lo) - 1e-9
    assert e.throughput(hi) <= hi + 1e-9


def test_job_duration_and_deadline_math():
    j = Job(0, JobKind.TRAINING, arrival=0.0, work=12.0, deadline=10.0, elasticity=LINEAR)
    assert j.duration_on(4) == pytest.approx(3.0)
    assert j.meets_deadline_on(t=0.0, slots=4)
    assert not j.meets_deadline_on(t=8.0, slots=4)
    j.remaining = 6.0
    assert j.duration_on(2) == pytest.approx(3.0)


def test_no_mig_speedup_applies_to_linear_only():
    spec = WorkloadSpec()
    jobs = generate_jobs(spec, seed=1)
    for j in jobs:
        if j.elasticity is LINEAR:
            assert j.speedup_no_mig == pytest.approx(1.06)
            assert j.rate_on(7, mig_enabled=False) == pytest.approx(7 * 1.06)
        else:
            assert j.speedup_no_mig == 1.0


def test_diurnal_rate_peaks_and_troughs():
    # Fig. 5: peak plateau 5:00-17:00, overnight trough
    assert arrival_rate(11 * 60.0) > 0.5
    assert arrival_rate(2 * 60.0) <= 0.12
    assert max(DIURNAL_RATE_PER_MIN) <= 0.6


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_workload_determinism_and_validity(seed):
    spec = WorkloadSpec(horizon_min=240.0)
    a = generate_jobs(spec, seed=seed)
    b = generate_jobs(spec, seed=seed)
    assert len(a) == len(b)
    for ja, jb in zip(a, b, strict=True):
        assert ja.arrival == jb.arrival and ja.work == jb.work
        assert ja.deadline > ja.arrival
        assert ja.work > 0
        assert 0.0 <= ja.arrival < 240.0


def test_inference_training_split():
    spec = WorkloadSpec(horizon_min=24 * 60.0, inference_split=0.8)
    jobs = generate_jobs(spec, seed=3)
    inf = sum(1 for j in jobs if j.kind == JobKind.INFERENCE)
    assert 0.7 < inf / len(jobs) < 0.9
    # training durations in U(10, 40)
    for j in jobs:
        if j.kind == JobKind.TRAINING:
            assert 10.0 <= j.work <= 40.0


def test_elasticity_class_mix():
    jobs = generate_jobs(WorkloadSpec(horizon_min=24 * 60.0), seed=5)
    frac = {
        k: sum(1 for j in jobs if j.elasticity.klass == k) / len(jobs)
        for k in ElasticityClass
    }
    for k, f in frac.items():
        assert 0.2 < f < 0.47, (k, f)  # ~1/3 each (§V-A)
