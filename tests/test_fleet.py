"""Fleet layer: dispatch, heterogeneous devices, aggregation, sweep cells.

The headline invariant — a 1-GPU fleet on the paper-diurnal scenario is
bit-identical to the single-MIG path — is pinned here and by the
``fleet_scaling`` baseline gate in CI.
"""

import dataclasses

import pytest

from repro.core.power import A30_165W
from repro.core.rl.env import FEATURE_DIM, FLEET_FEATURE_DIM, fleet_state_features
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator, StaticPolicy
from repro.core.slices import A30_CONFIGS
from repro.core.workload import WorkloadSpec, generate_jobs
from repro.fleet import (
    DEVICE_PROFILES,
    DISPATCHERS,
    DispatchContext,
    FleetSimulator,
    FleetSpec,
    aggregate_sim_results,
    device_profile,
    dispatch_jobs,
    make_dispatcher,
)
from repro.sweep import cell_hash, make_cell, make_fleet_cell, run_cell

DAY = WorkloadSpec()
SHORT = WorkloadSpec(horizon_min=180.0, constant_rate=0.4)


def _static_factory(cfg):
    return lambda i, prof: StaticPolicy(cfg)


# ----------------------------------------------------------------------
# devices


def test_device_profiles_registry():
    assert {"a100-250w", "a30-165w"} <= set(DEVICE_PROFILES)
    a100 = device_profile("a100-250w")
    a30 = device_profile("a30-165w")
    assert a100.total_slots == 7
    assert a30.total_slots == 4
    assert a30.power is A30_165W
    assert a30.configs is A30_CONFIGS or dict(a30.configs) == dict(A30_CONFIGS)
    assert a30.default_config in a30.configs
    with pytest.raises(KeyError):
        device_profile("h100-apocryphal")


def test_a30_table_is_valid_for_the_simulator():
    jobs = generate_jobs(SHORT, 1)
    prof = device_profile("a30-165w")
    sim = MIGSimulator(
        make_scheduler("EDF-SS"), power_model=prof.power, config_table=prof.configs
    )
    res = sim.run(jobs, policy=StaticPolicy(prof.default_config))
    assert res.num_jobs == len(jobs)
    # choosing an A100-only config id on an A30 must fail loudly — at
    # engine construction, with the policy named (not a bare KeyError
    # deep inside the first _config lookup)
    sim2 = MIGSimulator(make_scheduler("EDF-SS"), config_table=prof.configs)
    with pytest.raises(ValueError, match="StaticPolicy.*not in this device's"):
        sim2.run(generate_jobs(SHORT, 2), policy=StaticPolicy(12))


# ----------------------------------------------------------------------
# dispatch


def test_round_robin_cycles():
    jobs = generate_jobs(SHORT, 3)
    profiles = [device_profile("a100-250w")] * 3
    assignments, trace = dispatch_jobs(jobs, profiles, make_dispatcher("round-robin"))
    assert assignments == [i % 3 for i in range(len(jobs))]
    assert len(trace) == len(jobs)


def test_least_loaded_balances():
    jobs = generate_jobs(DAY, 4)
    profiles = [device_profile("a100-250w")] * 2
    assignments, _ = dispatch_jobs(jobs, profiles, make_dispatcher("least-loaded"))
    counts = [assignments.count(i) for i in range(2)]
    assert all(c > 0 for c in counts)
    assert abs(counts[0] - counts[1]) < 0.5 * len(jobs)


def test_energy_greedy_packs_when_idle_fleet():
    """On a lightly loaded fleet the marginal-power rule keeps reusing the
    already-hot device instead of spreading (concave Fig. 3 curve)."""
    jobs = generate_jobs(WorkloadSpec(horizon_min=120.0, constant_rate=0.1), 5)
    profiles = [device_profile("a100-250w")] * 3
    assignments, _ = dispatch_jobs(jobs, profiles, make_dispatcher("energy-greedy"))
    assert len(set(assignments)) == 1


def test_energy_greedy_spills_under_overload():
    """Packing must not starve the fleet: once a device's estimated backlog
    crosses the spill threshold, work flows to the other devices instead of
    queueing unboundedly on one GPU."""
    jobs = generate_jobs(WorkloadSpec(horizon_min=240.0, constant_rate=2.0), 8)
    profiles = [device_profile("a100-250w")] * 3
    assignments, _ = dispatch_jobs(jobs, profiles, make_dispatcher("energy-greedy"))
    assert len(set(assignments)) == 3, "overload must reach every device"


def test_dispatch_requires_sorted_arrivals():
    jobs = generate_jobs(SHORT, 6)[:4]
    jobs = [jobs[1], jobs[0], *jobs[2:]]
    with pytest.raises(ValueError, match="sorted"):
        dispatch_jobs(jobs, [device_profile("a100-250w")], make_dispatcher("round-robin"))


def test_dispatcher_registry():
    assert set(DISPATCHERS) == {
        "round-robin", "least-loaded", "energy-greedy", "state-aware",
        "fragmentation-aware",
    }
    with pytest.raises(KeyError):
        make_dispatcher("clairvoyant")


# ----------------------------------------------------------------------
# fleet simulation


@pytest.mark.parametrize("info", ["online", "fluid"])
def test_one_gpu_fleet_bit_identical_to_single_path(info):
    single = MIGSimulator(make_scheduler("EDF-SS")).run(
        generate_jobs(DAY, 42), policy=StaticPolicy(3)
    )
    fleet = FleetSimulator(FleetSpec.of(["a100-250w"], dispatch_info=info)).run(
        generate_jobs(DAY, 42), policy_factory=_static_factory(3)
    )
    agg = fleet.aggregate
    for field in dataclasses.fields(type(single)):
        if field.name == "extra":
            continue
        assert getattr(agg, field.name) == getattr(single, field.name), field.name
    assert agg.extra["makespan_min"] == single.extra["makespan_min"]
    assert agg.extra["tardiness_integral"] == single.extra["tardiness_integral"]


def test_one_gpu_fleet_online_bit_identical_with_timer_policy():
    """The online co-advance must replay the exact event sequence even for
    policies that keep a timer chain alive (Day/Night boundaries)."""
    from repro.core.simulator import DayNightPolicy

    single = MIGSimulator(make_scheduler("EDF-SS")).run(
        generate_jobs(DAY, 7), policy=DayNightPolicy()
    )
    fleet = FleetSimulator(FleetSpec.of(["a100-250w"])).run(
        generate_jobs(DAY, 7), policy_factory=lambda i, p: DayNightPolicy()
    )
    assert fleet.aggregate == single
    assert fleet.aggregate.repartitions >= 2


def test_online_dispatch_observes_real_state():
    """Online mode exposes per-device engines whose snapshots carry real
    queue/partition state at dispatch time (the fluid path has neither)."""
    fs = FleetSimulator(
        FleetSpec.of(["a100-250w", "a30-165w"], dispatcher="least-loaded")
    )
    fs.run(generate_jobs(SHORT, 21), policy_factory=lambda i, p: StaticPolicy(p.default_config))
    assert len(fs.engines) == 2
    for engine in fs.engines:
        assert engine.finished
        snap = engine.snapshot()
        assert snap.sim.backlog_1g_min == 0.0  # drained
        assert snap.events_processed > 0


def test_state_aware_requires_online_mode():
    fs = FleetSimulator(
        FleetSpec.of(["a100-250w"] * 2, dispatcher="state-aware", dispatch_info="fluid")
    )
    with pytest.raises(ValueError, match="cannot run in fluid mode"):
        fs.run(generate_jobs(SHORT, 3), policy_factory=_static_factory(3))
    with pytest.raises(ValueError, match="unknown dispatch_info"):
        FleetSimulator(FleetSpec.of(["a100-250w"], dispatch_info="psychic"))


def test_state_aware_avoids_repartitioning_device():
    """A device mid-repartition (or visibly congested) must not win a
    state-aware pick over an idle device."""
    from repro.fleet import EngineDeviceState, StateAwareDispatcher
    from repro.core.engine import SimulationEngine
    from repro.core.jobs import Job, JobKind, LINEAR

    profiles = [device_profile("a100-250w")] * 2
    engines = []
    for _ in range(2):
        sim = MIGSimulator(make_scheduler("EDF-SS"))
        engines.append(SimulationEngine(sim, policy=StaticPolicy(3), stream_open=True))
    # device 0: force an in-flight repartition right now
    engines[0].sim._start_repartition(6)
    states = [EngineDeviceState(i, p, e) for i, (p, e) in enumerate(zip(profiles, engines, strict=True))]
    job = Job(99, JobKind.INFERENCE, 0.0, 1.0, 10.0, LINEAR)
    ctx = DispatchContext(t=0.0, job=job, devices=states)
    pick = StateAwareDispatcher().pick(ctx)
    assert pick == 1
    assert states[0].repartition_remaining_min > 0.0
    assert states[1].repartition_remaining_min == 0.0


def test_engine_device_state_projects_to_observed_instant():
    """Regression: a device whose clock rests at its last event (e.g. one
    long job, no events for an hour) must be observed as of the *arrival*
    instant — between events the backlog drains linearly, so the view
    projects it instead of reporting the stale last-event number."""
    from repro.core.engine import SimulationEngine
    from repro.core.jobs import Job, JobKind, LINEAR
    from repro.fleet import EngineDeviceState

    prof = device_profile("a100-250w")
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=StaticPolicy(1), stream_open=True)
    # one linear job, work 140 1g-min on the 7g slice: runs 0 -> 20 min
    engine.inject(Job(0, JobKind.TRAINING, 0.0, 140.0, 100.0, LINEAR))
    engine.run_until(10.0, inclusive=False)  # only the arrival processes
    assert sim.t == 0.0  # device clock rests at its last event
    st = EngineDeviceState(0, prof, engine)
    assert st.backlog_1g_min == pytest.approx(140.0)  # unprojected
    st.observe_at(10.0)
    assert st.backlog_1g_min == pytest.approx(140.0 - 7.0 * 10.0)
    st.observe_at(15.0)
    assert st.normalized_load == pytest.approx((140.0 - 7.0 * 15.0) / 7.0)
    # the projection is read-only: the simulation itself is untouched
    assert sim.t == 0.0 and sim.active[0].remaining == pytest.approx(140.0)


def test_online_fleet_dispatch_differs_from_fluid_under_load():
    """The semantics change the mig-sim-3 bump records: with real state,
    least-loaded routing sees actual drain rates (not the fluid peak-rate
    estimate) and makes different choices on a loaded heterogeneous fleet."""
    spec_kw = dict(profiles=["a100-250w", "a30-165w"], dispatcher="least-loaded")
    load = WorkloadSpec(horizon_min=360.0, constant_rate=0.8)
    online = FleetSimulator(FleetSpec.of(**spec_kw)).run(
        generate_jobs(load, 33), policy_factory=_static_factory(3)
    )
    fluid = FleetSimulator(FleetSpec.of(**spec_kw, dispatch_info="fluid")).run(
        generate_jobs(load, 33), policy_factory=_static_factory(3)
    )
    assert sum(online.dispatch_counts) == sum(fluid.dispatch_counts)
    assert online.dispatch_counts != fluid.dispatch_counts


def test_fleet_conservation_and_aggregation():
    jobs = generate_jobs(DAY, 9)
    fleet = FleetSimulator(
        FleetSpec.of(["a100-250w", "a100-250w", "a30-165w"], dispatcher="least-loaded")
    ).run(jobs, policy_factory=lambda i, p: StaticPolicy(p.default_config))
    assert sum(fleet.dispatch_counts) == len(jobs)
    assert fleet.aggregate.num_jobs == len(jobs)
    assert fleet.aggregate.energy_wh == pytest.approx(
        sum(r.energy_wh for r in fleet.per_device)
    )
    assert fleet.aggregate.total_tardiness == pytest.approx(
        sum(r.total_tardiness for r in fleet.per_device)
    )
    assert fleet.aggregate.extra["makespan_min"] == max(
        r.extra["makespan_min"] for r in fleet.per_device
    )
    # starved-device idle power is reported, not silently dropped
    assert "fleet_idle_gap_wh" in fleet.aggregate.extra
    assert fleet.aggregate.extra["fleet_idle_gap_wh"] >= 0.0


def test_more_gpus_cut_tardiness():
    jobs1 = generate_jobs(DAY, 13)
    jobs4 = generate_jobs(DAY, 13)
    one = FleetSimulator(FleetSpec.of(["a100-250w"])).run(
        jobs1, policy_factory=_static_factory(3)
    )
    four = FleetSimulator(FleetSpec.of(["a100-250w"] * 4)).run(
        jobs4, policy_factory=_static_factory(3)
    )
    assert four.aggregate.total_tardiness <= one.aggregate.total_tardiness


def test_aggregate_requires_results():
    with pytest.raises(ValueError):
        aggregate_sim_results([])


def test_policies_are_per_device_instances():
    seen = []

    def factory(i, prof):
        p = StaticPolicy(3)
        seen.append(p)
        return p

    FleetSimulator(FleetSpec.of(["a100-250w"] * 3)).run(
        generate_jobs(SHORT, 21), policy_factory=factory
    )
    assert len(seen) == 3
    assert len({id(p) for p in seen}) == 3


def test_dynamic_policies_adapt_to_a30_table():
    """daynight/heuristic/DQN emit A100 config ids; on a heterogeneous
    fleet the device-adapted wrapper must translate them to the A30 table
    (closest slice count) instead of KeyError-ing mid-run."""
    from repro.core.simulator import DayNightPolicy
    from repro.fleet import DeviceAdaptedPolicy

    adapted = DeviceAdaptedPolicy(DayNightPolicy(), A30_CONFIGS)
    # A100 day config 6 (3 slices) -> A30 config 3 (3 slices);
    # A100 night config 2 (2 slices) -> A30 config 2 (2 slices)
    assert adapted._map(6) == 3
    assert adapted._map(2) == 2
    assert adapted._map(None) is None
    assert adapted.initial_config in A30_CONFIGS

    jobs = generate_jobs(DAY, 17)
    fleet = FleetSimulator(
        FleetSpec.of(["a100-250w", "a30-165w"], dispatcher="least-loaded")
    ).run(jobs, policy_factory=lambda i, p: DayNightPolicy())
    assert fleet.aggregate.num_jobs == len(jobs)
    assert all(r.repartitions > 0 for r in fleet.per_device), (
        "both devices must actually follow the day/night schedule"
    )


# ----------------------------------------------------------------------
# fleet-aware RL observation


def test_fleet_state_features_shape_and_range():
    fs = FleetSimulator(FleetSpec.of(["a100-250w", "a30-165w"], dispatcher="least-loaded"))
    fs.run(generate_jobs(SHORT, 30), policy_factory=lambda i, p: StaticPolicy(p.default_config))
    assert FLEET_FEATURE_DIM == FEATURE_DIM + 2
    for i, sim in enumerate(fs.sims):
        f = fleet_state_features(90.0, sim, i, fs.view)
        assert f.shape == (FLEET_FEATURE_DIM,)
        assert (f >= 0.0).all() and (f <= 1.0).all()
    # shares across the fleet sum to <= 1 (0 when no backlog at t)
    shares = [fs.view.load_share(i, 90.0) for i in range(2)]
    assert sum(shares) <= 1.0 + 1e-9
    # degrades gracefully without fleet context
    f0 = fleet_state_features(90.0, fs.sims[0], 0, None)
    assert f0.shape == (FLEET_FEATURE_DIM,)
    assert f0[-2] == 0.0 and f0[-1] == 0.0


def test_evaluate_policy_fleet_ad_hoc():
    from repro.core.rl.train import evaluate_policy_fleet

    rs = evaluate_policy_fleet(
        lambda: StaticPolicy(3),
        profiles=["a100-250w", "a100-250w"],
        num_iterations=2,
        scenario="weekend-flat",
        scenario_kwargs={"horizon_min": 240.0},
        seed=77,
    )
    assert len(rs) == 2
    assert all(r.num_jobs > 0 for r in rs)


# ----------------------------------------------------------------------
# sweep cells


def test_fleet_cell_roundtrip_and_hash():
    kw = dict(
        experiment="t",
        group="g",
        profiles=["a100-250w", "a30-165w"],
        dispatcher="least-loaded",
        scheduler="EDF-SS",
        scenario="weekend-flat",
        scenario_kwargs={"horizon_min": 240.0},
        seed=5,
        policy="static",
        policy_kwargs={"config_id": 3},
    )
    cell = make_fleet_cell(**kw)
    assert cell["fleet"]["dispatcher"] == "least-loaded"
    # scenario knobs are resolved into the cell (hash captures the values)
    assert cell["scenario"]["kwargs"]["horizon_min"] == 240.0
    assert "rate_per_min" in cell["scenario"]["kwargs"]
    out = run_cell(cell)
    assert out["num_jobs"] > 0
    assert len(out["devices"]) == 2
    assert sum(out["dispatch_counts"]) == out["num_jobs"]

    other = make_fleet_cell(**{**kw, "dispatcher": "round-robin"})
    assert cell_hash(cell) != cell_hash(other)
    bigger = make_fleet_cell(**{**kw, "profiles": ["a100-250w"] * 3})
    assert cell_hash(cell) != cell_hash(bigger)


def test_one_gpu_fleet_cell_matches_single_cell_results():
    """The sweep-level version of the bit-identity invariant: the
    fleet_scaling 1xA100 cells and the plain single-GPU cells must agree
    on every aggregate metric."""
    single = run_cell(
        make_cell(
            experiment="t",
            group="g",
            scheduler="EDF-SS",
            workload=DAY,
            seed=31_000,
            policy="static",
            policy_kwargs={"config_id": 3},
        )
    )
    fleet = run_cell(
        make_fleet_cell(
            experiment="t",
            group="g",
            profiles=["a100-250w"],
            dispatcher="round-robin",
            scheduler="EDF-SS",
            scenario="paper-diurnal",
            seed=31_000,
            policy="static",
            policy_kwargs={"config_id": 3},
        )
    )
    for k in (
        "energy_wh",
        "avg_tardiness",
        "num_jobs",
        "total_tardiness",
        "preemptions",
        "repartitions",
        "max_tardiness",
        "deadline_misses",
        "busy_slot_minutes",
        "extra",
        "util_histogram",
    ):
        assert fleet[k] == single[k], k
