"""ET metric + DQN machinery."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import SimResult, et_metric, et_scale_factor, et_table
from repro.core.rl.dqn import DQNConfig, DQNLearner, ReplayBuffer
from repro.core.rl.env import FEATURE_DIM, RewardWeights, state_features
from repro.core.rl.agent import DQNAgent
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator
from repro.core.workload import WorkloadSpec, generate_jobs


def _res(e, t):
    return SimResult(energy_wh=e, avg_tardiness=t)


def test_et_scale_factor_definition():
    rs = [_res(100.0, 2.0), _res(300.0, 4.0)]
    # s = 200, t = 3 -> a = 3 / 400
    assert et_scale_factor(rs) == pytest.approx(3.0 / 400.0)


def test_et_metric_formula():
    a = 0.5
    rs = [_res(10.0, 2.0)]
    assert et_metric(rs, a) == pytest.approx((0.5 * 10 + 2) / 1.5)


def test_et_table_shared_a_and_ordering():
    per = {
        "good": [_res(100.0, 1.0)] * 3,
        "bad": [_res(200.0, 5.0)] * 3,
    }
    table, a = et_table(per)
    assert table["good"] < table["bad"]
    assert a == pytest.approx(3.0 / (2 * 150.0))


@given(st.lists(st.tuples(st.floats(1, 1e4), st.floats(0, 1e3)), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_et_nonnegative_and_monotone(pairs):
    rs = [_res(e, t) for e, t in pairs]
    a = et_scale_factor(rs)
    assert a >= 0
    v = et_metric(rs, a)
    assert v >= 0
    # adding tardiness can only increase ET
    rs2 = [_res(e, t + 1.0) for e, t in pairs]
    assert et_metric(rs2, a) > v


def test_replay_buffer_wraps():
    rb = ReplayBuffer(8, 3)
    for i in range(20):
        rb.add(np.full(3, i, np.float32), i % 4, float(i), np.zeros(3, np.float32), False, 0.99)
    assert rb.size == 8
    s, a, r, s2, d, g = rb.sample(np.random.default_rng(0), 16)
    assert s.shape == (16, 3) and r.min() >= 12.0  # only recent entries remain


@pytest.mark.slow
def test_dqn_learns_trivial_contextual_bandit():
    """Q-learning sanity: reward = 1 if action == argmax(state) else 0."""
    cfg = DQNConfig(state_dim=4, num_actions=4, hidden=(32, 32), lr=3e-3,
                    min_buffer=64, batch_size=64, target_sync_every=100,
                    gamma=0.0, seed=0)
    learner = DQNLearner(cfg)
    rng = np.random.default_rng(0)
    for _ in range(1500):
        s = rng.random(4).astype(np.float32)
        a = int(rng.integers(0, 4))
        r = 1.0 if a == int(np.argmax(s)) else 0.0
        learner.observe(s, a, r, np.zeros(4, np.float32), True, 0.0)
        learner.maybe_train(1)
    correct = 0
    for _ in range(200):
        s = rng.random(4).astype(np.float32)
        correct += int(learner.greedy_action(s) == int(np.argmax(s)))
    assert correct > 160, correct


def test_state_features_shape_and_bounds():
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    jobs = generate_jobs(WorkloadSpec(horizon_min=60.0, constant_rate=0.5), seed=0)
    sim.run(jobs)
    f = state_features(30.0, sim)
    assert f.shape == (FEATURE_DIM,)
    assert np.all(f >= 0.0) and np.all(f <= 1.0)


def test_agent_collects_transitions_and_penalizes_switch():
    cfg = DQNConfig(state_dim=FEATURE_DIM, min_buffer=10_000)  # no training
    learner = DQNLearner(cfg)
    agent = DQNAgent(learner, train=True)
    agent.begin_episode(epsilon=1.0)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    jobs = generate_jobs(WorkloadSpec(horizon_min=120.0, constant_rate=0.3), seed=1)
    res = sim.run(jobs, policy=agent)
    agent.end_episode(sim)
    assert learner.buffer.size > 10  # n-step transitions recorded
    assert res.repartitions > 0  # epsilon=1: plenty of random switches
    assert agent.episode_reward < 0  # energy+tardiness costs accrue


def test_reward_weights_switch_penalty_positive():
    rw = RewardWeights()
    assert rw.switch_penalty(5) > 0
    assert rw.interval_reward(100.0, 10.0) < 0


# ----------------------------------------------------------------------
# RepartitionEnv — the incremental environment over the steppable engine


def test_env_reset_step_episode_runs_to_completion():
    from repro.core.rl.env import RepartitionEnv

    env = RepartitionEnv(spec=WorkloadSpec(horizon_min=120.0, constant_rate=0.3))
    obs = env.reset(seed=1)
    assert obs.shape == (FEATURE_DIM,)
    assert not env.done
    steps, total = 0, 0.0
    terminated = truncated = False
    while not env.done:
        obs, r, terminated, truncated, info = env.step(2)  # stay on config 3
        total += r
        steps += 1
        assert obs.shape == (FEATURE_DIM,)
        assert info["config_id"] == 3
    assert terminated and not truncated
    res = env.result()
    assert res.num_jobs > 0
    # initial_config defaults to 2, so the constant action 2 (config 3)
    # repartitions exactly once, on the very first decision
    assert res.repartitions == 1
    assert total < 0  # energy/tardiness costs accrue
    # the per-decision rewards sum to the episode's integral deltas
    assert steps > 10


def test_env_reward_charges_switch_penalty():
    """Two identical episodes; the one that repartitions on the first
    decision pays the switch penalty plus the 4 s stall."""
    from repro.core.rl.env import RepartitionEnv

    spec = WorkloadSpec(horizon_min=60.0, constant_rate=0.4)

    def first_reward(action):
        env = RepartitionEnv(spec=spec, initial_config=2)
        env.reset(seed=5)
        _, r, _, _, info = env.step(action)
        return r, info

    r_stay, info_stay = first_reward(1)  # action 1 -> config 2 == current
    r_switch, info_switch = first_reward(11)  # config 12: forces a repartition
    assert info_stay["switched"] is False
    assert info_switch["switched"] is True
    assert r_switch < r_stay


def test_env_truncation_bounds_episode():
    from repro.core.rl.env import RepartitionEnv

    env = RepartitionEnv(
        spec=WorkloadSpec(horizon_min=240.0, constant_rate=0.5), max_decisions=7
    )
    env.reset(seed=2)
    n = 0
    truncated = False
    while not env.done:
        _, _, terminated, truncated, _ = env.step(2)
        n += 1
    assert n == 7 and truncated
    with pytest.raises(RuntimeError, match="episode over"):
        env.step(2)
    env_t = RepartitionEnv(
        spec=WorkloadSpec(horizon_min=240.0, constant_rate=0.5),
        truncate_after_min=30.0,
    )
    env_t.reset(seed=2)
    while not env_t.done:
        _, _, _, tr, info = env_t.step(2)
    assert tr and info["t"] >= 30.0


def test_env_matches_agent_policy_episode():
    """Driving the env with a fixed action sequence equals running the
    simulator one-shot with the equivalent CallbackPolicy — the env is a
    re-sequencing of the same engine, not a different simulation."""
    from repro.core.rl.env import RepartitionEnv
    from repro.core.simulator import CallbackPolicy, MIGSimulator as Sim

    spec = WorkloadSpec(horizon_min=120.0, constant_rate=0.4)
    actions = [2, 2, 5, 5, 1, 2] * 200  # arbitrary deterministic schedule

    env = RepartitionEnv(spec=spec, initial_config=2)
    env.reset(seed=9)
    k = 0
    while not env.done:
        env.step(actions[k])
        k += 1
    res_env = env.result()

    calls = {"k": 0}

    def fn(t, sim):
        a = actions[calls["k"]]
        calls["k"] += 1
        cfg = a + 1
        return cfg if cfg != sim.partition.config_id else None

    sim = Sim(make_scheduler("EDF-SS"))
    res_run = sim.run(
        generate_jobs(spec, seed=9), policy=CallbackPolicy(fn, initial_config=2)
    )
    assert res_env == res_run
    assert calls["k"] == k


def test_host_epsilon_schedule_unchanged():
    """Regression pin: the host per-episode schedule is untouched by the
    step-based parameterization riding alongside it."""
    cfg = DQNConfig(state_dim=4, eps_start=1.0, eps_end=0.05,
                    eps_decay_episodes=100, eps_decay_steps=12_345)
    learner = DQNLearner(cfg)
    assert learner.epsilon(0) == pytest.approx(1.0)
    assert learner.epsilon(50) == pytest.approx(1.0 + (0.05 - 1.0) * 0.5)
    assert learner.epsilon(100) == pytest.approx(0.05)
    assert learner.epsilon(10_000) == pytest.approx(0.05)


def test_epsilon_by_step_endpoints_and_batch_invariance():
    """The global-env-step schedule: linear over eps_decay_steps, and a
    function of the step count alone — B rollouts advancing together see
    exactly the value a single rollout would at the same global step."""
    from repro.core.rl.dqn import epsilon_by_step

    cfg = DQNConfig(state_dim=4, eps_start=1.0, eps_end=0.1,
                    eps_decay_steps=1000)
    assert float(epsilon_by_step(cfg, 0)) == pytest.approx(1.0)
    assert float(epsilon_by_step(cfg, 500)) == pytest.approx(0.55)
    assert float(epsilon_by_step(cfg, 1000)) == pytest.approx(0.1)
    assert float(epsilon_by_step(cfg, 10**6)) == pytest.approx(0.1)
    # batch invariance: global steps reached in chunks of B give the same
    # schedule values as stepping one at a time
    for B in (1, 8, 64):
        steps = np.arange(0, 1200, B)
        vals = np.asarray([float(epsilon_by_step(cfg, s)) for s in steps])
        expect = 1.0 + (0.1 - 1.0) * np.minimum(steps / 1000.0, 1.0)
        np.testing.assert_allclose(vals, expect, atol=1e-6)
    learner = DQNLearner(cfg)
    assert learner.epsilon_at_step(500) == pytest.approx(0.55)


def test_dqn_optimizer_matches_handrolled_adam():
    """The optim-layer swap pin: repro.optim.adamw configured by
    make_optimizer (weight_decay=0, no clipping, b2=0.999) reproduces the
    previously hand-rolled Adam update step-for-step."""
    import jax
    import jax.numpy as jnp
    from repro.core.rl.dqn import make_optimizer

    cfg = DQNConfig(state_dim=4, lr=1e-3)
    opt = make_optimizer(cfg)
    params = [
        (jnp.asarray([[0.5, -0.2], [0.1, 0.4]]), jnp.asarray([0.1, -0.1])),
        (jnp.asarray([[1.0], [-1.0]]), jnp.asarray([0.0])),
    ]
    state = opt.init(params)

    # the reference: classic bias-corrected Adam, as previously inlined
    b1, b2, eps, lr = 0.9, 0.999, 1e-8, cfg.lr
    ref = jax.tree_util.tree_map(jnp.asarray, params)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    rng = np.random.default_rng(0)
    for t in range(1, 6):
        grads = jax.tree_util.tree_map(
            lambda p: jnp.asarray(
                rng.normal(size=p.shape).astype(np.float32)
            ),
            ref,
        )
        params, state = opt.update(grads, state, params)
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g, m, grads
        )
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads
        )
        ref = jax.tree_util.tree_map(
            lambda p, mm, vv, t=t: p
            - lr * (mm / (1 - b1**t)) / (jnp.sqrt(vv / (1 - b2**t)) + eps),
            ref, m, v,
        )
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(ref), strict=True
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
