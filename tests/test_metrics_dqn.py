"""ET metric + DQN machinery."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import SimResult, et_metric, et_scale_factor, et_table
from repro.core.rl.dqn import DQNConfig, DQNLearner, ReplayBuffer
from repro.core.rl.env import FEATURE_DIM, RewardWeights, state_features
from repro.core.rl.agent import DQNAgent
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator
from repro.core.workload import WorkloadSpec, generate_jobs


def _res(e, t):
    return SimResult(energy_wh=e, avg_tardiness=t)


def test_et_scale_factor_definition():
    rs = [_res(100.0, 2.0), _res(300.0, 4.0)]
    # s = 200, t = 3 -> a = 3 / 400
    assert et_scale_factor(rs) == pytest.approx(3.0 / 400.0)


def test_et_metric_formula():
    a = 0.5
    rs = [_res(10.0, 2.0)]
    assert et_metric(rs, a) == pytest.approx((0.5 * 10 + 2) / 1.5)


def test_et_table_shared_a_and_ordering():
    per = {
        "good": [_res(100.0, 1.0)] * 3,
        "bad": [_res(200.0, 5.0)] * 3,
    }
    table, a = et_table(per)
    assert table["good"] < table["bad"]
    assert a == pytest.approx(3.0 / (2 * 150.0))


@given(st.lists(st.tuples(st.floats(1, 1e4), st.floats(0, 1e3)), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_et_nonnegative_and_monotone(pairs):
    rs = [_res(e, t) for e, t in pairs]
    a = et_scale_factor(rs)
    assert a >= 0
    v = et_metric(rs, a)
    assert v >= 0
    # adding tardiness can only increase ET
    rs2 = [_res(e, t + 1.0) for e, t in pairs]
    assert et_metric(rs2, a) > v


def test_replay_buffer_wraps():
    rb = ReplayBuffer(8, 3)
    for i in range(20):
        rb.add(np.full(3, i, np.float32), i % 4, float(i), np.zeros(3, np.float32), False, 0.99)
    assert rb.size == 8
    s, a, r, s2, d, g = rb.sample(np.random.default_rng(0), 16)
    assert s.shape == (16, 3) and r.min() >= 12.0  # only recent entries remain


@pytest.mark.slow
def test_dqn_learns_trivial_contextual_bandit():
    """Q-learning sanity: reward = 1 if action == argmax(state) else 0."""
    cfg = DQNConfig(state_dim=4, num_actions=4, hidden=(32, 32), lr=3e-3,
                    min_buffer=64, batch_size=64, target_sync_every=100,
                    gamma=0.0, seed=0)
    learner = DQNLearner(cfg)
    rng = np.random.default_rng(0)
    for step in range(1500):
        s = rng.random(4).astype(np.float32)
        a = int(rng.integers(0, 4))
        r = 1.0 if a == int(np.argmax(s)) else 0.0
        learner.observe(s, a, r, np.zeros(4, np.float32), True, 0.0)
        learner.maybe_train(1)
    correct = 0
    for _ in range(200):
        s = rng.random(4).astype(np.float32)
        correct += int(learner.greedy_action(s) == int(np.argmax(s)))
    assert correct > 160, correct


def test_state_features_shape_and_bounds():
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    jobs = generate_jobs(WorkloadSpec(horizon_min=60.0, constant_rate=0.5), seed=0)
    sim.run(jobs)
    f = state_features(30.0, sim)
    assert f.shape == (FEATURE_DIM,)
    assert np.all(f >= 0.0) and np.all(f <= 1.0)


def test_agent_collects_transitions_and_penalizes_switch():
    cfg = DQNConfig(state_dim=FEATURE_DIM, min_buffer=10_000)  # no training
    learner = DQNLearner(cfg)
    agent = DQNAgent(learner, train=True)
    agent.begin_episode(epsilon=1.0)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    jobs = generate_jobs(WorkloadSpec(horizon_min=120.0, constant_rate=0.3), seed=1)
    res = sim.run(jobs, policy=agent)
    agent.end_episode(sim)
    assert learner.buffer.size > 10  # n-step transitions recorded
    assert res.repartitions > 0  # epsilon=1: plenty of random switches
    assert agent.episode_reward < 0  # energy+tardiness costs accrue


def test_reward_weights_switch_penalty_positive():
    rw = RewardWeights()
    assert rw.switch_penalty(5) > 0
    assert rw.interval_reward(100.0, 10.0) < 0
