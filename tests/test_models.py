"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

rng = np.random.default_rng(0)


def _batch(cfg, B, S):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.encoder is not None:
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.n_frames, cfg.d_model)), jnp.float32
        )
    if cfg.vision_tokens > 0:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
@pytest.mark.slow
def test_smoke_forward_loss_decode(name):
    cfg = smoke_config(name)
    params = init_params(cfg, seed=0)
    B, S = 2, 64
    batch = _batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b, impl="ref"))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = jax.jit(lambda p, b: loss_fn(cfg, p, b, impl="ref"))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    cache = init_cache(cfg, B, 128)
    lg, cache2 = jax.jit(
        lambda p, c, tk, i: decode_step(cfg, p, c, tk, i, impl="ref")
    )(params, cache, batch["tokens"][:, :1], jnp.asarray(0, jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))


@pytest.mark.parametrize("name", ["stablelm_3b", "gemma3_12b", "mixtral_8x7b", "xlstm_350m", "jamba_v01_52b"])
def test_decode_matches_forward(name):
    """Prefill-by-decode must reproduce full-sequence forward logits.

    Run in fp32: this asserts cache/rope/state LOGIC equivalence; the two
    paths take different bf16 rounding routes (deep stacks drift ~1e-1 on
    tied-embedding logits), which is expected and not under test here.
    """
    cfg = dataclasses.replace(
        smoke_config(name), remat="none", dtype="float32", param_dtype="float32"
    )
    if cfg.vision_tokens:
        cfg = dataclasses.replace(cfg, vision_tokens=0)
    if cfg.moe is not None:
        # ample capacity: forward's capacity truncation is load-dependent and
        # legitimately diverges from per-token decode (no truncation at T=1)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_params(cfg, seed=1)
    B, S = 1, 16
    batch = _batch(cfg, B, S)
    full_logits, _ = forward(cfg, params, batch, impl="ref")

    cache = init_cache(cfg, B, 32)
    step = jax.jit(lambda p, c, tk, i: decode_step(cfg, p, c, tk, i, impl="ref"))
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, batch["tokens"][:, i : i + 1], jnp.asarray(i, jnp.int32))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    ref = np.asarray(full_logits, np.float32)
    np.testing.assert_allclose(dec, ref, atol=2e-2, rtol=2e-2)


def test_param_count_sane():
    # full configs should be in the right ballpark (param_count is the
    # MODEL_FLOPS basis, so order-of-magnitude correctness matters)
    approx = {
        "xlstm-350m": (0.2e9, 0.9e9),
        "gemma3-1b": (0.7e9, 2.0e9),
        "stablelm-3b": (2e9, 5e9),
        "phi-3-vision-4.2b": (3e9, 6e9),
        "mixtral-8x7b": (40e9, 55e9),
        "nemotron-4-340b": (250e9, 400e9),
        "jamba-v0.1-52b": (40e9, 65e9),
        "gemma3-12b": (9e9, 16e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    assert cfg.param_count(active_only=True) < 0.45 * cfg.param_count()


def test_local_global_pattern():
    cfg = get_config("gemma3-12b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 48
    assert kinds[:6] == ("local",) * 5 + ("attn",)
    unit = cfg.pattern_unit()
    assert len(unit) == 6 and cfg.num_pattern_repeats == 8


def test_jamba_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert kinds.count("attn") == 4  # 1:7 attention:mamba over 32 layers
    moes = [cfg.layer_is_moe(i) for i in range(cfg.n_layers)]
    assert sum(moes) == 16  # every other layer
    assert len(cfg.pattern_unit()) == 8 and cfg.num_pattern_repeats == 4


def test_xlstm_pattern():
    cfg = get_config("xlstm-350m")
    kinds = cfg.layer_kinds()
    assert kinds.count("slstm") == 3 and kinds.count("mlstm") == 21
