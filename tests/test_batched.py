"""Batched backend: table/padding units + batched-vs-oracle agreement.

The two-backend contract (docs/BATCHED_SIM.md): the event-driven
:class:`SimulationEngine` is the bit-exact oracle, and the batched
fixed-timestep backend must reproduce its aggregates within the documented
tolerances.  The agreement matrix here *is* that contract's enforcement —
scenario × policy × repartition-mode combos, each batching several seeds
into one vectorized rollout and comparing per-seed against fresh oracle
runs.  Tolerance values mirror BATCHED_SIM.md §4; tightening them requires
re-measuring, loosening them requires a documented divergence source.
"""

import hypothesis
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.batched import (
    PAD_MULTIPLE,
    BatchedJobs,
    BatchedRepartitionEnv,
    UnsupportedPolicyError,
    build_tables,
    compile_policy,
    held_policy,
    simulate_batch,
)
from repro.core.engine import SimulationEngine
from repro.core.power import A100_250W
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    RepartitionPolicy,
    StaticPolicy,
)
from repro.core.slices import MIG_CONFIGS, transition

_SETTINGS = {"max_examples": 6, "deadline": None}
if hasattr(hypothesis, "HealthCheck"):  # the stub has no HealthCheck
    _SETTINGS["suppress_health_check"] = list(hypothesis.HealthCheck)


# ----------------------------------------------------------------------
# agreement tolerances (BATCHED_SIM.md §4; measured at dt=0.5)

ENERGY_RTOL = 0.03
TARDINESS_ATOL_MIN = 0.15  # minutes of avg tardiness, OR ...
TARDINESS_RTOL = 0.5  # ... relative to max(oracle, TARDINESS_FLOOR)
TARDINESS_FLOOR = 0.25
BUSY_RTOL = 0.025
PREEMPTIONS_RTOL = 0.4  # relative to max(oracle, PREEMPTIONS_FLOOR)
PREEMPTIONS_FLOOR = 10.0


def _oracle(jobs, policy, repartition_mode="partial"):
    sim = MIGSimulator(
        make_scheduler("EDF-FS"), repartition_mode=repartition_mode
    )
    engine = SimulationEngine(sim, policy=policy, jobs=jobs)
    engine.drain()
    return engine.result()


def _assert_agreement(b, o, label=""):
    """One rollout's batched aggregates vs its oracle run."""
    assert b.num_jobs == o.num_jobs, label
    assert b.repartitions == o.repartitions, label
    assert b.energy_wh == pytest.approx(o.energy_wh, rel=ENERGY_RTOL), label
    d_tard = abs(b.avg_tardiness - o.avg_tardiness)
    assert (
        d_tard <= TARDINESS_ATOL_MIN
        or d_tard <= TARDINESS_RTOL * max(o.avg_tardiness, TARDINESS_FLOOR)
    ), f"{label}: avg_tardiness {b.avg_tardiness} vs {o.avg_tardiness}"
    assert b.busy_slot_minutes == pytest.approx(
        o.busy_slot_minutes, rel=BUSY_RTOL, abs=1.0
    ), label
    assert abs(b.preemptions - o.preemptions) <= PREEMPTIONS_RTOL * max(
        o.preemptions, PREEMPTIONS_FLOOR
    ), f"{label}: preemptions {b.preemptions} vs {o.preemptions}"


def _policy_of(name):
    return {
        "static": lambda: StaticPolicy(3),
        "nomig": lambda: NoMIGPolicy(),
        "daynight": lambda: DayNightPolicy(),
    }[name]()


# ----------------------------------------------------------------------
# DeviceTables: the flattened slot-placement model


def test_tables_match_partition_model():
    t = build_tables()
    assert t.config_ids.tolist() == sorted(MIG_CONFIGS)
    for c, cid in enumerate(t.config_ids):
        p = MIG_CONFIGS[int(cid)]
        assert t.num_slices[c] == p.num_slices
        assert t.slice_slots[c, : p.num_slices].tolist() == [
            s.slots for s in p.slices
        ]
        assert (t.slice_slots[c, p.num_slices:] == 0).all()
        ranked = p.sorted_indices(descending=True)
        assert t.slice_rank[c, : len(ranked)].tolist() == ranked
        assert (t.slice_rank[c, len(ranked):] == -1).all()


def test_tables_match_transition_survivors():
    t = build_tables()
    for a, ca in enumerate(t.config_ids):
        for b, cb in enumerate(t.config_ids):
            surv = transition(MIG_CONFIGS[int(ca)], MIG_CONFIGS[int(cb)]).survivor_map
            expect = {s: -1 for s in range(int(t.num_slices[a]))}
            expect.update(surv)
            got = {s: int(t.old_to_new[a, b, s]) for s in expect}
            assert got == expect, (ca, cb)


def test_tables_power_curve_and_index():
    t = build_tables()
    for k in range(t.max_slots + 1):
        assert t.watts_by_busy[k] == pytest.approx(
            A100_250W.power_watts(float(k)), rel=1e-6
        )
    for cid in t.config_ids.tolist():
        assert t.config_ids[t.index_of(cid)] == cid
    with pytest.raises(KeyError):
        t.index_of(99)


# ----------------------------------------------------------------------
# BatchedJobs: padding and shape invariants


def test_batched_jobs_padding_and_masks():
    t = build_tables()
    lists = [
        generate_scenario("paper-diurnal", seed=s, load_scale=0.1)
        for s in range(3)
    ]
    jobs = BatchedJobs.from_job_lists(lists, max_slots=t.max_slots)
    B, J = jobs.arrival.shape
    assert B == 3 and J % PAD_MULTIPLE == 0
    assert J >= max(len(js) for js in lists)
    for b, js in enumerate(lists):
        n = len(js)
        assert jobs.num_jobs[b] == n
        assert jobs.valid[b, :n].all() and not jobs.valid[b, n:].any()
        assert np.isinf(jobs.arrival[b, n:]).all()
        assert (jobs.work[b, n:] == 0).all()
    # level 0 depletes nothing; valid rows have positive 1-slot rates
    assert (jobs.rate_by_slots[..., 0] == 0).all()
    assert (jobs.rate_by_slots[jobs.valid, 1] > 0).all()


def test_batched_jobs_edf_order_stable():
    t = build_tables()
    lists = [generate_scenario("paper-diurnal", seed=0, load_scale=0.1)]
    jobs = BatchedJobs.from_job_lists(lists, max_slots=t.max_slots)
    order = jobs.edf_order[0]
    d = jobs.deadline[0][order]
    assert (d[:-1] <= d[1:]).all()  # sorted; +inf padding lands at the end
    # stable tie-break: equal deadlines keep ascending job-id order
    ties = d[:-1] == d[1:]
    assert (order[:-1][ties] < order[1:][ties]).all()


def test_batched_jobs_rejects_partial_and_empty():
    t = build_tables()
    js = generate_scenario("paper-diurnal", seed=0, load_scale=0.05)
    js[0].remaining = js[0].work / 2
    with pytest.raises(ValueError, match="partially-run"):
        BatchedJobs.from_job_lists([js], max_slots=t.max_slots)
    with pytest.raises(ValueError, match="empty"):
        BatchedJobs.from_job_lists([], max_slots=t.max_slots)


# ----------------------------------------------------------------------
# policy compilation


def test_compile_policy_kinds_and_rejection():
    t = build_tables()
    p = compile_policy(StaticPolicy(3), t, batch=2)
    assert p.kind == "static" and p.batch == 2
    assert (p.initial == t.index_of(3)).all()
    p = compile_policy(NoMIGPolicy(), t, batch=1)
    assert p.kind == "static" and p.initial[0] == t.index_of(1)
    p = compile_policy(DayNightPolicy(), t, batch=3, initial_config=4)
    assert p.kind == "daynight"
    assert (p.initial == t.index_of(4)).all()
    assert (p.primary == t.index_of(6)).all()
    assert (p.secondary == t.index_of(2)).all()

    class Stateful(RepartitionPolicy):
        initial_config = 2

    with pytest.raises(UnsupportedPolicyError, match="oracle"):
        compile_policy(Stateful(), t, batch=1)


def test_held_policy_charges_only_real_switches():
    p = held_policy(np.array([2, 3]), np.array([2, 2]))
    assert p.kind == "static"
    assert p.initial.tolist() == [2, 2] and p.primary.tolist() == [2, 3]


# ----------------------------------------------------------------------
# agreement matrix: scenario × policy × mode, seeds batched into one run


@pytest.mark.parametrize(
    "scenario,policy,mode",
    [
        ("paper-diurnal", "daynight", "partial"),
        ("paper-diurnal", "static", "drain"),
        ("bursty-mmpp", "static", "partial"),
        ("bursty-mmpp", "daynight", "drain"),
        ("weekend-flat", "nomig", "partial"),
        ("weekend-flat", "daynight", "drain"),
        ("heavy-tail-lognormal", "static", "drain"),
        ("heavy-tail-lognormal", "nomig", "partial"),
    ],
)
def test_batched_matches_oracle(scenario, policy, mode):
    seeds = range(6)
    tables = build_tables()
    lists = [
        generate_scenario(scenario, seed=s, load_scale=0.2) for s in seeds
    ]
    jobs = BatchedJobs.from_job_lists(lists, max_slots=tables.max_slots)
    res = simulate_batch(
        jobs,
        compile_policy(_policy_of(policy), tables, len(lists)),
        tables=tables,
        repartition_mode=mode,
    )
    batched = res.to_sim_results()
    for s in seeds:
        fresh = generate_scenario(scenario, seed=s, load_scale=0.2)
        oracle = _oracle(fresh, _policy_of(policy), repartition_mode=mode)
        _assert_agreement(
            batched[s], oracle, label=f"{scenario}/{policy}/{mode}/seed{s}"
        )


@hypothesis.settings(**_SETTINGS)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.sampled_from(["static", "nomig", "daynight"]),
    st.booleans(),
)
def test_batched_matches_oracle_property(seed, policy, drain):
    """Random (seed, policy, mode) draws hold the same agreement bounds."""
    mode = "drain" if drain else "partial"
    tables = build_tables()
    lists = [generate_scenario("paper-diurnal", seed=seed, load_scale=0.1)]
    jobs = BatchedJobs.from_job_lists(lists, max_slots=tables.max_slots)
    res = simulate_batch(
        jobs,
        compile_policy(_policy_of(policy), tables, 1),
        tables=tables,
        repartition_mode=mode,
    )
    fresh = generate_scenario("paper-diurnal", seed=seed, load_scale=0.1)
    oracle = _oracle(fresh, _policy_of(policy), repartition_mode=mode)
    _assert_agreement(
        res.to_sim_result(0), oracle, label=f"seed{seed}/{policy}/{mode}"
    )


def test_batched_completion_times_and_makespan():
    tables = build_tables()
    lists = [generate_scenario("paper-diurnal", seed=0, load_scale=0.1)]
    jobs = BatchedJobs.from_job_lists(lists, max_slots=tables.max_slots)
    res = simulate_batch(
        jobs, compile_policy(StaticPolicy(3), tables, 1), tables=tables
    )
    comp = res.completion[0]
    n = int(res.num_jobs[0])
    assert np.isfinite(comp[:n]).all()  # every real job finished
    assert np.isinf(comp[n:]).all()  # padding rows never complete
    assert (comp[:n] >= jobs.arrival[0, :n] - 1e-6).all()
    assert res.makespan_min[0] >= comp[:n].max() - 1e-3


# ----------------------------------------------------------------------
# vectorized RL env smoke


def test_batched_env_steps_and_results():
    env = BatchedRepartitionEnv(
        scenario="paper-diurnal",
        scenario_kwargs={"load_scale": 0.1},
        decision_interval_min=60.0,
        max_decisions=40,
    )
    obs = env.reset(seeds=[0, 1])
    assert obs.shape == (2, 2 + 2 * env.m)
    assert ((obs >= 0.0) & (obs <= 1.0)).all()
    steps = 0
    while not env.done:
        obs, reward, terminated, truncated, info = env.step([2, 5])
        steps += 1
        assert obs.shape == (2, 2 + 2 * env.m)
        assert reward.shape == (2,) and np.isfinite(reward).all()
        assert (info["queue_depth"] >= 0).all()
    assert steps > 1
    results = env.results()
    assert len(results) == 2
    assert all(r.num_jobs > 0 and r.energy_wh > 0 for r in results)
    with pytest.raises(RuntimeError, match="over"):
        env.step([2, 5])


def test_batched_env_rejects_bad_cadence_and_scheduler():
    with pytest.raises(ValueError, match="EDF-FS"):
        BatchedRepartitionEnv(scheduler_name="EDF-SS")
    with pytest.raises(ValueError, match="multiple"):
        BatchedRepartitionEnv(decision_interval_min=0.7)
