"""Fig. 1 partition table + Fig. 3 power model."""

import pytest

from repro.core.power import A100_250W, TPU_V5E_POD, PowerModel, make_saturating_power
from repro.core.slices import MIG_CONFIGS, TOTAL_SLOTS, config, config_ids


def test_twelve_configs():
    assert len(MIG_CONFIGS) == 12
    assert list(config_ids()) == list(range(1, 13))


def test_fig1_slot_and_memory_budgets():
    for part in MIG_CONFIGS.values():
        assert part.total_slots <= TOTAL_SLOTS
        assert part.total_memory_gb <= 40
        assert all(s.slots in (1, 2, 3, 4, 7) for s in part.slices)


def test_fig1_exact_rows():
    assert config(1).slot_sizes() == (7,)
    assert config(2).slot_sizes() == (4, 3)
    assert config(3).slot_sizes() == (4, 2, 1)
    assert config(5).slot_sizes() == (3, 3)  # the "holed" config
    assert config(12).slot_sizes() == (1,) * 7
    # at most one 1g.10gb per config (paper §III-A)
    for part in MIG_CONFIGS.values():
        assert sum(1 for s in part.slices if s.name == "1g.10gb") <= 1


def test_config5_has_hole():
    assert config(5).total_slots == 6  # 1 dead slot


def test_power_monotone_and_saturating():
    w = A100_250W.watts_by_busy_slots
    assert all(b >= a for a, b in zip(w, w[1:], strict=False))
    # steep early, flat late (Fig. 3): marginal power of slot 1 >> slot 7
    assert (w[1] - w[0]) > 10 * (w[7] - w[6])
    # after 4/7 busy, near-peak (paper: "negligible increase")
    assert w[4] > 0.95 * w[7]


def test_power_interpolation_and_energy():
    p = A100_250W
    assert p.power_watts(0) == p.idle_watts
    assert p.power_watts(7) == p.peak_watts
    mid = p.power_watts(1.5)
    assert p.power_watts(1) < mid < p.power_watts(2)
    assert p.energy_wh(7, 60.0) == pytest.approx(p.peak_watts)


def test_saturating_builder_shape():
    m = make_saturating_power("x", 100.0, 300.0, 7)
    assert m.idle_watts == pytest.approx(100.0)
    assert m.peak_watts >= 300.0 - 1e-6
    assert TPU_V5E_POD.total_slots == 7


def test_fastest_slowest_indices():
    part = config(3)  # 4g, 2g, 1g
    assert part.fastest_slice_index() == 0
    assert part.slowest_slice_index() == 2
    assert part.sorted_indices(descending=True)[0] == 0
