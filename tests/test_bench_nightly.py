"""bench_nightly.py: trajectory append/seed robustness + the events gate.

Regression tests for the nightly-trajectory satellite: the append path must
seed a fresh list when the file is missing or empty (instead of dying and
leaving the history stuck at nothing), write atomically so a crash cannot
truncate the trajectory, and gate engine events/sec against the *previous*
trajectory entry rather than only the static CI floor.
"""

import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_nightly",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_nightly.py"),
)
bench_nightly = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_nightly)


def _entry(date, eps=None, grids=None):
    e = {"date": date, "git_sha": "x", "sim_version": "t",
         "grids": grids or {"g": {"wall_s": 1.0}}, "total_wall_s": 1.0}
    if eps is not None:
        e["engine_bench"] = {"events_per_sec": eps}
    return e


# ----------------------------------------------------------------------
# load / seed / save


def test_load_trajectory_seeds_missing_and_empty(tmp_path):
    path = str(tmp_path / "BENCH.json")
    assert bench_nightly.load_trajectory(path) == []
    open(path, "w").write("")
    assert bench_nightly.load_trajectory(path) == []
    open(path, "w").write("   \n")
    assert bench_nightly.load_trajectory(path) == []
    open(path, "w").write("[]\n")
    assert bench_nightly.load_trajectory(path) == []


def test_load_trajectory_refuses_corruption(tmp_path):
    path = str(tmp_path / "BENCH.json")
    open(path, "w").write("{not json")
    with pytest.raises(SystemExit, match="invalid JSON"):
        bench_nightly.load_trajectory(path)
    open(path, "w").write('{"a": 1}')
    with pytest.raises(SystemExit, match="not a JSON list"):
        bench_nightly.load_trajectory(path)


def test_save_and_append_round_trip(tmp_path):
    path = str(tmp_path / "BENCH.json")
    for i in range(3):
        trajectory = bench_nightly.load_trajectory(path)
        assert len(trajectory) == i
        trajectory.append(_entry(f"2026-08-0{i + 1}", eps=1000.0 + i))
        bench_nightly.save_trajectory(path, trajectory)
    final = json.load(open(path))
    assert [e["date"] for e in final] == ["2026-08-01", "2026-08-02", "2026-08-03"]
    assert not os.path.exists(path + ".tmp")  # atomic rename landed


# ----------------------------------------------------------------------
# trajectory-relative events/sec gate


def test_gate_passes_without_history_or_measurements():
    entry = _entry("2026-08-02", eps=5000.0)
    assert bench_nightly.check_events_regression([], entry, 0.5) is None
    # previous entries without an engine bench cannot gate
    hist = [_entry("2026-08-01")]
    assert bench_nightly.check_events_regression(hist, entry, 0.5) is None
    # an entry without a measurement is not a regression
    assert bench_nightly.check_events_regression(
        [_entry("2026-08-01", eps=9000.0)], _entry("2026-08-02"), 0.5
    ) is None


def test_gate_references_best_of_recent_window():
    hist = [
        _entry("2026-07-30", eps=10000.0),
        _entry("2026-07-31"),  # no measurement: skipped, not a zero
        _entry("2026-08-01", eps=8000.0),
    ]
    ok = bench_nightly.check_events_regression(hist, _entry("2026-08-02", eps=5100.0), 0.5)
    assert ok is None  # 5100 >= 0.5 * max(10000, 8000)
    bad = bench_nightly.check_events_regression(hist, _entry("2026-08-02", eps=4900.0), 0.5)
    assert bad is not None and "10000" in bad and "2026-07-30" in bad
    # the window bounds how far back the reference reaches
    far = bench_nightly.check_events_regression(
        hist, _entry("2026-08-02", eps=4900.0), 0.5, window=1
    )
    assert far is None  # only 8000 in window: 4900 >= 0.5 * 8000


def test_gate_does_not_ratchet_onto_its_own_regressed_entries():
    """A persistent regression keeps failing night after night (the
    regressed entries are recorded by design and must not become the new
    reference), and compounding slightly-under-ratio drift cannot slip
    through."""
    hist = [_entry("2026-07-30", eps=6000.0)]
    for day, eps in (("2026-07-31", 2500.0), ("2026-08-01", 2500.0)):
        verdict = bench_nightly.check_events_regression(hist, _entry(day, eps=eps), 0.5)
        assert verdict is not None and "6000" in verdict
        hist.append(_entry(day, eps=eps))  # the failed entry is still recorded
    # 40%-per-night decay: each step passes vs the previous night alone,
    # but fails against the rolling best once cumulative drift crosses 0.5x
    hist2 = [_entry("2026-07-28", eps=10000.0), _entry("2026-07-29", eps=6000.0)]
    assert bench_nightly.check_events_regression(
        hist2, _entry("2026-07-30", eps=3600.0), 0.5
    ) is not None


def test_main_appends_and_gates(tmp_path, monkeypatch, capsys):
    sweeps = tmp_path / "sweeps"
    sweeps.mkdir()
    (sweeps / "g.meta.json").write_text(json.dumps(
        {"name": "g", "cells": 4, "cached": 1, "computed": 3,
         "workers": 2, "wall_s": 1.5}
    ))
    out = str(tmp_path / "BENCH.json")
    args = ["--out", out, "--sweeps-dir", str(sweeps)]
    assert bench_nightly.main(args) == 0
    assert bench_nightly.main(args) == 0  # append accumulates per run
    trajectory = json.load(open(out))
    assert len(trajectory) == 2
    assert trajectory[0]["grids"]["g"]["cache_hit_rate"] == 0.25
    # a gate failure still appends the regressed entry first
    trajectory[-1]["engine_bench"] = {"events_per_sec": 10000.0}
    bench_nightly.save_trajectory(out, trajectory)
    monkeypatch.setattr(
        bench_nightly, "collect_entry",
        lambda sweeps_dir: {**_entry("2026-08-02", eps=100.0)},
    )
    assert bench_nightly.main([*args, "--gate-events-ratio", "0.5"]) == 1
    assert len(json.load(open(out))) == 3
    assert "REGRESSION" in capsys.readouterr().err
    # --dry-run still evaluates the gate (read-only): fails without append
    assert bench_nightly.main(
        [*args, "--gate-events-ratio", "0.5", "--dry-run"]
    ) == 1
    assert len(json.load(open(out))) == 3  # nothing appended

# ----------------------------------------------------------------------
# service_throughput trajectory key (scripts/bench_service.py)


def _svc_entry(date, jpm=None):
    e = {"date": date, "git_sha": "x", "sim_version": "t",
         "grids": {"g": {"wall_s": 1.0}}, "total_wall_s": 1.0}
    if jpm is not None:
        e["service_throughput"] = {"jobs_per_min": jpm, "p99_ms": 1.0}
    return e


def test_gate_service_throughput_key():
    hist = [_svc_entry("2026-08-01", jpm=400000.0)]
    slow = _svc_entry("2026-08-02", jpm=150000.0)
    bad = bench_nightly.check_events_regression(
        hist, slow, 0.5, key="service_throughput", field="jobs_per_min",
        label="SERVICE", unit="jobs/min",
    )
    assert bad is not None and "SERVICE" in bad and "jobs/min" in bad
    ok = bench_nightly.check_events_regression(
        hist, slow, 0.3, key="service_throughput", field="jobs_per_min",
    )
    assert ok is None
    # entries without the key never gate (the bench may not have run)
    assert bench_nightly.check_events_regression(
        [_svc_entry("2026-08-01")], slow, 0.5,
        key="service_throughput", field="jobs_per_min",
    ) is None


def test_collect_entry_picks_up_service_bench(tmp_path, monkeypatch):
    sweeps = tmp_path / "sweeps"
    sweeps.mkdir()
    (sweeps / "g.meta.json").write_text(json.dumps(
        {"name": "g", "cells": 2, "cached": 0, "computed": 2,
         "workers": 1, "wall_s": 0.5}
    ))
    bench = tmp_path / "service_bench.json"
    bench.write_text(json.dumps(
        {"jobs_per_min": 123456.0, "p50_ms": 0.1, "p99_ms": 0.4, "jobs": 6000}
    ))
    monkeypatch.setattr(bench_nightly, "SERVICE_BENCH_PATH", str(bench))
    entry = bench_nightly.collect_entry(str(sweeps))
    assert entry["service_throughput"] == {
        "jobs_per_min": 123456.0, "p50_ms": 0.1, "p99_ms": 0.4, "jobs": 6000,
    }
