"""Sweep integration of the batched backend: hashing, routing, caching.

The backend key is part of the content-hash contract: an oracle cell's hash
must be byte-identical to what it was before the batched backend existed
(no ``backend`` key at all), and a batched cell of the same physics must
hash differently — the two backends agree only within tolerance, so their
results may never alias one cache entry.
"""

import numpy as np
import pytest

from repro.core.batched import UnsupportedPolicyError
from repro.sweep.batched import (
    batched_group_key,
    is_batched_cell,
    run_batched_cells,
    validate_batched_cell,
)
from repro.sweep.cells import (
    cell_hash,
    make_cell,
    make_fleet_cell,
    make_scenario_cell,
    result_to_sim_result,
    run_cell,
)
from repro.sweep.runner import run_cells

_KW = {"load_scale": 0.1}


def _cell(seed=0, backend="batched", policy="daynight", **kw):
    return make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-FS",
        scenario="paper-diurnal", seed=seed, scenario_kwargs=_KW,
        policy=policy, backend=backend, **kw,
    )


# ----------------------------------------------------------------------
# cell construction + hashing


def test_oracle_cells_carry_no_backend_key():
    cell = _cell(backend="oracle")
    assert "backend" not in cell and "backend_kwargs" not in cell
    assert not is_batched_cell(cell)


def test_batched_cells_hash_apart_from_oracle():
    oracle = _cell(backend="oracle")
    batched = _cell(backend="batched")
    assert batched["backend"] == "batched"
    assert batched["backend_kwargs"] == {"dt_min": 0.5}
    assert is_batched_cell(batched)
    assert cell_hash(oracle) != cell_hash(batched)
    # a different timestep is different physics: different hash
    coarse = _cell(backend="batched", backend_kwargs={"dt_min": 1.0})
    assert cell_hash(coarse) != cell_hash(batched)


def test_backend_validation_errors():
    with pytest.raises(ValueError, match="unknown backend"):
        _cell(backend="gpu")
    with pytest.raises(ValueError, match="backend_kwargs"):
        _cell(backend="oracle", backend_kwargs={"dt_min": 1.0})
    # workload-spec cells take the same backend parameters
    from repro.core.workload import WorkloadSpec

    cell = make_cell(
        experiment="t", group="g", scheduler="EDF-FS",
        workload=WorkloadSpec(), seed=0, backend="batched",
    )
    assert cell["backend"] == "batched"


def test_group_key_collapses_seeds_only():
    a, b = _cell(seed=0), _cell(seed=1)
    assert batched_group_key(a) == batched_group_key(b)
    assert batched_group_key(a) != batched_group_key(
        _cell(seed=0, backend_kwargs={"dt_min": 1.0})
    )
    assert batched_group_key(a) != batched_group_key(_cell(seed=0, policy="nomig"))


# ----------------------------------------------------------------------
# routing + rejection


def test_validate_rejects_wrong_scheduler_and_fleet():
    bad = dict(_cell())
    bad["scheduler"] = "EDF-SS"
    with pytest.raises(UnsupportedPolicyError, match="EDF-FS"):
        validate_batched_cell(bad)
    fleet = make_fleet_cell(
        experiment="t", group="g", profiles=["a100"], dispatcher="jsq",
        scheduler="EDF-FS", scenario="paper-diurnal", seed=0,
        scenario_kwargs=_KW,
    )
    fleet["backend"] = "batched"
    with pytest.raises(UnsupportedPolicyError, match="fleet"):
        run_cell(fleet)


def test_stateful_policy_rejected_with_guidance():
    with pytest.raises(UnsupportedPolicyError, match="oracle backend|oracle"):
        run_batched_cells([_cell(policy="heuristic")])


def test_policy_factory_rejected_on_batched_cells():
    with pytest.raises(ValueError, match="policy_factory"):
        run_cell(_cell(), policy_factory=lambda: None)


# ----------------------------------------------------------------------
# execution: result schema, oracle agreement, runner grouping + cache


def test_run_cell_schema_matches_oracle_backend():
    oracle = run_cell(_cell(backend="oracle"))
    batched = run_cell(_cell(backend="batched"))
    assert set(batched) == set(oracle)
    assert batched["config_trace"] == []  # documented: no switch trace
    assert batched["num_jobs"] == oracle["num_jobs"]
    assert batched["repartitions"] == oracle["repartitions"]
    assert batched["energy_wh"] == pytest.approx(oracle["energy_wh"], rel=0.03)
    # the sweep aggregation path reconstructs a SimResult from either
    sr = result_to_sim_result(batched)
    assert sr.energy_wh == batched["energy_wh"]
    assert sr.extra["makespan_min"] > 0


def test_runner_groups_and_caches_batched_cells(tmp_path):
    cells = [_cell(seed=s) for s in range(4)]
    out = run_cells(
        "batched_grid", cells, cache=str(tmp_path / "cache"),
        artifacts_dir=str(tmp_path / "art"),
    )
    assert out.computed_count == 4 and out.cached_count == 0
    assert all(r["num_jobs"] > 0 for r in out.results)
    # per-seed rows must differ (a grouping bug that replays one seed B
    # times would make them identical)
    energies = [r["energy_wh"] for r in out.results]
    assert len(set(energies)) == len(energies)
    # vectorized grouping serves exactly what one-cell run_cell computes
    solo = run_cell(cells[2])
    assert out.results[2]["energy_wh"] == pytest.approx(
        solo["energy_wh"], rel=1e-6
    )
    again = run_cells(
        "batched_grid", cells, cache=str(tmp_path / "cache"),
        artifacts_dir=str(tmp_path / "art"),
    )
    assert again.cached_count == 4 and again.computed_count == 0
    assert again.results == out.results


def test_runner_mixes_backends_in_one_grid(tmp_path):
    cells = [
        _cell(seed=0, backend="oracle"),
        _cell(seed=0, backend="batched"),
        _cell(seed=1, backend="batched"),
    ]
    out = run_cells(
        "mixed_grid", cells, cache=False,
        artifacts_dir=str(tmp_path / "art"),
    )
    assert out.computed_count == 3
    assert out.results[0]["config_trace"] != []  # oracle keeps its trace
    assert out.results[1]["config_trace"] == []
    assert out.results[1]["energy_wh"] == pytest.approx(
        out.results[0]["energy_wh"], rel=0.03
    )


def test_batched_seed_determinism():
    a = run_batched_cells([_cell(seed=3)])[0]
    b = run_batched_cells([_cell(seed=3)])[0]
    for k in ("energy_wh", "avg_tardiness", "busy_slot_minutes",
              "preemptions", "repartitions", "util_histogram"):
        assert a[k] == b[k], k


def test_make_batched_env_factory():
    from repro.core.rl.env import make_batched_env

    env = make_batched_env(
        scenario="paper-diurnal", scenario_kwargs=_KW,
        decision_interval_min=120.0, max_decisions=2,
    )
    obs = env.reset(seeds=[0])
    assert obs.shape == (1, 2 + 2 * env.m)
    _, reward, _, _, _ = env.step([1])
    assert np.isfinite(reward).all()
