"""Deterministic mini property-testing fallback for ``hypothesis``.

The real hypothesis is a declared test dependency (``pip install -e .[test]``)
but is not always present — notably in hermetic containers that only bake in
the runtime toolchain.  Importing it used to break five test files at
collection time; instead, ``tests/conftest.py`` installs this stub into
``sys.modules`` when the real package is unavailable, and the property tests
run against a small deterministic sample set (boundary values first, then
seeded pseudo-random draws) rather than being skipped wholesale.

Only the API surface this suite uses is implemented: ``given``, ``settings``,
and ``strategies.{integers, floats, sampled_from, lists, tuples, booleans,
just, composite}``.  Shrinking, the example database, and stateful testing
are out of scope — install the real hypothesis for those.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib
from typing import Any, List, Sequence

IS_STUB = True
_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def sample(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def boundary(self) -> List[Any]:
        """Edge-case examples to try before random sampling."""
        return []


class _Integers(_Strategy):
    def __init__(self, min_value: int, max_value: int) -> None:
        self.lo, self.hi = int(min_value), int(max_value)

    def sample(self, rng):
        return rng.randint(self.lo, self.hi)

    def boundary(self):
        return [self.lo, self.hi]


class _Floats(_Strategy):
    def __init__(self, min_value: float, max_value: float, **_kw: Any) -> None:
        self.lo, self.hi = float(min_value), float(max_value)

    def sample(self, rng):
        return rng.uniform(self.lo, self.hi)

    def boundary(self):
        return [self.lo, self.hi]


class _SampledFrom(_Strategy):
    def __init__(self, elements: Sequence[Any]) -> None:
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def sample(self, rng):
        return rng.choice(self.elements)

    def boundary(self):
        return [self.elements[0], self.elements[-1]]


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size: int = 0, max_size: int = 10, **_kw):
        self.elements = elements
        self.min_size, self.max_size = int(min_size), int(max_size)

    def sample(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.sample(rng) for _ in range(n)]

    def boundary(self):
        out: List[Any] = []
        rng = random.Random(0)
        out.append([self.elements.sample(rng) for _ in range(self.min_size)])
        out.append([self.elements.sample(rng) for _ in range(self.max_size)])
        return out


class _Tuples(_Strategy):
    def __init__(self, *parts: _Strategy) -> None:
        self.parts = parts

    def sample(self, rng):
        return tuple(p.sample(rng) for p in self.parts)

    def boundary(self):
        firsts = [p.boundary() for p in self.parts]
        if all(firsts):
            return [tuple(b[0] for b in firsts), tuple(b[-1] for b in firsts)]
        return []


class _Just(_Strategy):
    def __init__(self, value: Any) -> None:
        self.value = value

    def sample(self, rng):
        return self.value

    def boundary(self):
        return [self.value]


class _Composite(_Strategy):
    """A user function that builds one example via a ``draw`` callable."""

    def __init__(self, fn, args, kwargs) -> None:
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def sample(self, rng):
        return self.fn(
            lambda strategy: strategy.sample(rng), *self.args, **self.kwargs
        )

    def boundary(self):
        # composite examples have no well-defined edges; a fixed-seed draw
        # keeps the boundary slot deterministic instead of empty (an empty
        # boundary would disable *every* strategy's boundary pass in given())
        return [self.sample(random.Random(0)), self.sample(random.Random(1))]


def composite(fn):
    """``@st.composite`` — the real API: ``fn(draw, *args) -> example``."""

    @functools.wraps(fn)
    def builder(*args: Any, **kwargs: Any) -> _Composite:
        return _Composite(fn, args, kwargs)

    return builder


strategies = types.SimpleNamespace(
    integers=_Integers,
    floats=_Floats,
    sampled_from=_SampledFrom,
    lists=_Lists,
    tuples=_Tuples,
    booleans=lambda: _SampledFrom([False, True]),
    just=_Just,
    composite=composite,
)


def settings(**kwargs: Any):
    """Decorator recording settings; only ``max_examples`` is honored."""

    def deco(fn):
        fn._stub_settings = kwargs
        return fn

    return deco


def given(*strats: _Strategy):
    """Run the test over boundary examples + seeded pseudo-random draws."""

    def deco(fn):
        inner = fn
        max_examples = getattr(fn, "_stub_settings", {}).get(
            "max_examples", _DEFAULT_MAX_EXAMPLES
        )

        @functools.wraps(inner)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            # stable per-test stream: same examples on every run/worker
            rng = random.Random(zlib.crc32(inner.__qualname__.encode()))
            examples: List[tuple] = []
            boundaries = [s.boundary() for s in strats]
            if all(boundaries):
                examples.append(tuple(b[0] for b in boundaries))
                examples.append(tuple(b[-1] for b in boundaries))
            while len(examples) < max_examples:
                examples.append(tuple(s.sample(rng) for s in strats))
            for ex in examples[:max_examples]:
                try:
                    inner(*args, *ex, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{inner.__qualname__} failed on stub-hypothesis "
                        f"example {ex!r}: {e}"
                    ) from e

        # pytest must not mistake the sampled params for fixtures: hide the
        # inner signature (functools.wraps exposes it via __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_stub = True
        return wrapper

    return deco
