"""Service layer: codecs, WAL, checkpoints, engine ops, service lifecycle.

Covers (PR 8):

* the WAL/job codecs and their failure modes (torn tail, mid-file corruption);
* the engine's service ops — ``cancel`` (all three dispositions),
  ``reconfigure``, pickle snapshots, ``harvest_completed`` — and the
  actionable error messages on ``inject`` misuse;
* :class:`SchedulerService` one-shot bit-identity against the plain engine,
  including through checkpoint/harvest cycles;
* :class:`FleetStream` bit-identity against the batch fleet path;
* the property interleaving matrix: random op scripts (inject / run_until /
  cancel / reconfigure / snapshot / pickle-roundtrip / close) agree
  bit-exactly with the unperturbed application of the same ops, across the
  four scheduler families.
"""

import math
import pickle
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import SimulationEngine
from repro.core.jobs import (
    LINEAR,
    Job,
    JobKind,
    capped,
    elasticity_from_label,
    sublinear,
)
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    CallbackPolicy,
    DayNightPolicy,
    MIGSimulator,
    StaticPolicy,
)
from repro.fleet.simulator import FleetSimulator, FleetSpec
from repro.service import (
    CheckpointStore,
    ReplayClock,
    SchedulerService,
    ServiceConfig,
    ServiceStats,
    WriteAheadLog,
    job_from_dict,
    job_to_dict,
    make_policy,
    read_wal,
    sim_result_to_dict,
    validate_record,
)

SCHEDULERS = ("EDF-FS", "EDF-SS", "LLF", "LALF")


def J(jid, arrival, work=10.0, slack=60.0, kind=JobKind.INFERENCE, elast=LINEAR):
    return Job(
        job_id=jid, kind=kind, arrival=arrival, work=work,
        deadline=arrival + slack, elasticity=elast,
    )


def _stream_engine(scheduler="EDF-SS", policy=None, **sim_kw):
    sim = MIGSimulator(make_scheduler(scheduler), **sim_kw)
    return SimulationEngine(
        sim, policy=policy or StaticPolicy(3), stream_open=True
    )


# ---------------------------------------------------------------------------
# codecs


def test_job_codec_round_trips_exactly():
    for job in (
        J(0, 0.1 + 0.2, work=1.0 / 3.0, elast=capped(2)),
        J(7, 123.456789, elast=sublinear("log-0.65"), kind=JobKind.TRAINING),
        Job(job_id=3, kind=JobKind.INFERENCE, arrival=5.5, work=2.0,
            deadline=9.25, elasticity=elasticity_from_label("capped@7g"),
            speedup_no_mig=1.06, tenant="acme", slo_min=4.5),
    ):
        back = job_from_dict(job_to_dict(job))
        # Elasticity holds a lambda, so compare via the codec + the curve
        assert job_to_dict(back) == job_to_dict(job)
        assert back.elasticity.label == job.elasticity.label
        assert back.elasticity.throughput(3.3) == job.elasticity.throughput(3.3)
        assert (back.job_id, back.arrival, back.work, back.deadline) == (
            job.job_id, job.arrival, job.work, job.deadline
        )


def test_job_codec_survives_json(tmp_path):
    import json

    job = J(1, 17.000000001, work=math.pi)
    d = json.loads(json.dumps(job_to_dict(job)))
    assert job_to_dict(job_from_dict(d)) == job_to_dict(job)


def test_validate_record_rejects_malformed():
    with pytest.raises(ValueError, match="unknown op"):
        validate_record({"seq": 1, "t": 0.0, "op": "explode"})
    with pytest.raises(ValueError, match="integer 'seq'"):
        validate_record({"op": "close", "t": 0.0})
    with pytest.raises(ValueError, match="missing field 'job'"):
        validate_record({"seq": 2, "t": 1.0, "op": "submit"})
    with pytest.raises(ValueError, match="numeric 't'"):
        validate_record({"seq": 2, "op": "close"})


# ---------------------------------------------------------------------------
# WAL


def test_wal_append_read_round_trip(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = WriteAheadLog(path)
    recs = [
        {"seq": 1, "op": "submit", "t": 0.5, "job": job_to_dict(J(0, 0.5))},
        {"seq": 2, "op": "cancel", "t": 1.5, "job_id": 0},
        {"seq": 3, "op": "close", "t": 2.0},
    ]
    for r in recs:
        wal.append(r)
    wal.close()
    assert read_wal(path) == recs
    assert read_wal(tmp_path / "missing.jsonl") == []


def test_wal_torn_tail_is_dropped(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = WriteAheadLog(path)
    wal.append({"seq": 1, "op": "close", "t": 0.0})
    wal.append({"seq": 2, "op": "close", "t": 1.0})
    wal.close()
    # simulate a crash mid-append: a truncated final line
    with open(path, "a") as fh:
        fh.write('{"seq": 3, "op": "clo')
    recs = read_wal(path)
    assert [r["seq"] for r in recs] == [1, 2]


def test_wal_mid_file_corruption_raises(tmp_path):
    path = tmp_path / "wal.jsonl"
    path.write_text('{"seq": 1, "op": "close", "t": 0.0}\nGARBAGE\n'
                    '{"seq": 2, "op": "close", "t": 1.0}\n')
    with pytest.raises(ValueError, match="corrupted at line 2"):
        read_wal(path)


def test_wal_rotate_truncates_and_appends_continue(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = WriteAheadLog(path)
    for seq in (1, 2, 3):
        wal.append({"seq": seq, "op": "close", "t": float(seq)})
    wal.rotate(())
    assert wal.size_bytes() == 0
    wal.append({"seq": 4, "op": "close", "t": 4.0})
    wal.close()
    assert [r["seq"] for r in read_wal(path)] == [4]


# ---------------------------------------------------------------------------
# checkpoint store


def test_checkpoint_store_rotation(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    assert store.latest() is None
    for seq in (3, 7, 12):
        store.save(f"blob-{seq}".encode(), seq)
    seq, blob = store.latest()
    assert (seq, blob) == (12, b"blob-12")
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["ckpt-000000000007.pkl", "ckpt-000000000012.pkl"]
    with pytest.raises(ValueError, match="at least one"):
        CheckpointStore(tmp_path, keep=0)


# ---------------------------------------------------------------------------
# replay clock


def test_replay_clock_paced_free_and_resync():
    wall = [100.0]
    clock = ReplayClock(speedup=120.0, time_source=lambda: wall[0])
    assert clock.paced and clock.now() == 0.0
    wall[0] += 30.0  # 30 wall-seconds at 120x -> 60 sim-minutes
    assert clock.now() == pytest.approx(60.0)
    assert clock.wall_seconds_until(90.0) == pytest.approx(15.0)
    clock.resync(10.0)
    assert clock.now() == 10.0

    free = ReplayClock.free()
    assert not free.paced
    assert free.now() == 0.0 and free.wall_seconds_until(1e9) == 0.0
    with pytest.raises(ValueError, match="speedup"):
        ReplayClock(speedup=-1.0)


# ---------------------------------------------------------------------------
# engine: inject error messages (the PR's bugfix satellite)


def test_inject_duplicate_id_error_names_time_id_remedy():
    eng = _stream_engine()
    eng.inject(J(5, 1.0))
    with pytest.raises(ValueError) as ei:
        eng.inject(J(5, 2.0))
    msg = str(ei.value)
    assert "job 5" in msg and "sim time t=" in msg and "unique id" in msg


def test_inject_after_close_stream_error_names_remedy():
    eng = _stream_engine()
    eng.close_stream()
    with pytest.raises(RuntimeError) as ei:
        eng.inject(J(0, 1.0))
    msg = str(ei.value)
    assert "job 0" in msg and "stream is closed" in msg
    assert "stream_open=True" in msg and "close_stream" in msg


def test_inject_past_arrival_error_names_restamp_remedy():
    eng = _stream_engine()
    eng.inject(J(0, 1.0))
    eng.run_until(50.0)
    with pytest.raises(ValueError) as ei:
        eng.inject(J(1, 10.0))
    msg = str(ei.value)
    assert "job 1" in msg and "arrival t=10.0" in msg and "re-stamp" in msg
    assert f"already at sim time t={eng.sim.t}" in msg


# ---------------------------------------------------------------------------
# engine: cancellation


def test_cancel_dispositions_and_charging():
    eng = _stream_engine(policy=StaticPolicy(2))  # 2 slices: 4g + 3g
    sim = eng.sim
    eng.inject(J(0, 0.0, work=50.0))
    eng.inject(J(1, 0.0, work=50.0))
    eng.inject(J(2, 0.0, work=50.0))   # queued (2 slices only)
    eng.inject(J(3, 500.0))            # far-future arrival
    eng.run_until(1.0)
    assert len(sim.assignment) == 2

    pre = sim.preemptions
    running = next(iter(sim.assignment))
    assert eng.cancel(running) == "preempted"
    assert sim.preemptions == pre + 1
    assert sim.active.get(running) is None

    # job 2 got rescheduled onto the freed slice; cancel whichever job is
    # now waiting (none — both remaining run). Inject one more to queue it.
    eng.inject(J(4, sim.t + 0.5, work=50.0))
    eng.run_until(sim.t + 1.0)
    queued = [j for j in sim.active if j not in sim.assignment]
    assert queued
    assert eng.cancel(queued[0]) == "dequeued"

    assert eng.cancel(3) == "unarrived"
    eng.close_stream()
    eng.drain()
    res = eng.result()
    assert res.extra["cancelled_jobs"] == 3.0
    assert res.num_jobs == 2  # the two survivors completed
    assert len(sim.cancelled) == 3


def test_cancel_unarrived_event_is_skipped_without_decision():
    """A cancelled pending arrival must not advance time or trigger policy."""
    eng = _stream_engine(policy=DayNightPolicy())
    eng.inject(J(0, 10.0, work=1.0))
    eng.inject(J(1, 20.0, work=1.0))
    eng.cancel(1)
    eng.close_stream()
    events = []
    while True:
        ev = eng.step()
        if ev is None:
            break
        events.append(ev)
    assert all(ev.job_id != 1 for ev in events)
    assert eng.result().num_jobs == 1


def test_cancel_errors_name_time_id_and_remedy():
    eng = _stream_engine()
    with pytest.raises(ValueError, match="never injected"):
        eng.cancel(42)
    eng.inject(J(0, 0.0, work=1.0))
    eng.run_until(10.0)  # completes
    with pytest.raises(ValueError) as ei:
        eng.cancel(0)
    assert "already completed at t=" in str(ei.value)
    eng.inject(J(1, eng.sim.t + 1.0))
    eng.cancel(1)
    with pytest.raises(ValueError, match="already cancelled"):
        eng.cancel(1)


def test_cancel_running_then_others_complete_identically():
    """Cancelling one job leaves the survivors' outcomes well-defined: the
    engine reschedules immediately and later completions are unaffected by
    the ghost (version bump invalidates its stale prediction)."""
    eng = _stream_engine(policy=StaticPolicy(2))
    eng.inject(J(0, 0.0, work=8.0))
    eng.inject(J(1, 0.0, work=6.0))
    eng.run_until(0.5)
    eng.cancel(0)
    eng.close_stream()
    eng.drain()
    res = eng.result()
    assert res.num_jobs == 1
    assert eng.sim.active == {}


# ---------------------------------------------------------------------------
# engine: manual reconfiguration


def test_reconfigure_manual_switch_and_errors():
    eng = _stream_engine(policy=StaticPolicy(3))
    sim = eng.sim
    eng.inject(J(0, 0.0, work=30.0))
    eng.run_until(1.0)
    assert eng.reconfigure(3) is False  # already there
    with pytest.raises(KeyError, match="not in this"):
        eng.reconfigure(999)
    assert eng.reconfigure(6) is True
    with pytest.raises(RuntimeError, match="in flight until"):
        eng.reconfigure(2)  # the 4 s stall is still running
    eng.close_stream()
    eng.drain()
    assert sim.partition.config_id == 6
    assert sim.repartitions == 1


# ---------------------------------------------------------------------------
# engine: pickle snapshots, harvest, disposition


def _half_run_engine(scheduler="EDF-SS"):
    jobs = generate_scenario("trace-scaled", seed=3, horizon_min=240.0)
    sim = MIGSimulator(make_scheduler(scheduler))
    eng = SimulationEngine(sim, policy=DayNightPolicy(), jobs=jobs)
    eng.run_until(120.0)
    return eng, jobs


def test_pickle_snapshot_resumes_bit_identically():
    eng, jobs = _half_run_engine()
    blob = eng.to_snapshot_bytes()
    restored = SimulationEngine.from_snapshot_bytes(blob)
    eng.drain()
    restored.drain()
    assert restored.result() == eng.result()
    assert restored.sim.config_trace == eng.sim.config_trace

    # oracle: the uninterrupted one-shot run
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    oracle = sim.run(
        generate_scenario("trace-scaled", seed=3, horizon_min=240.0),
        policy=DayNightPolicy(),
    )
    assert restored.result() == oracle


def test_snapshot_reattaches_observers_and_type_checks():
    eng, _ = _half_run_engine()
    seen = []
    restored = SimulationEngine.from_snapshot_bytes(
        eng.to_snapshot_bytes(), trace_sink=seen.append
    )
    restored.drain()
    assert seen and restored.trace_sink is not None
    with pytest.raises(ValueError, match="not a SimulationEngine"):
        SimulationEngine.from_snapshot_bytes(pickle.dumps({"not": "engine"}))


def test_snapshot_unpicklable_policy_raises_actionable():
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    eng = SimulationEngine(
        sim, policy=CallbackPolicy(lambda t, s: None), stream_open=True
    )
    with pytest.raises(ValueError, match="make_policy"):
        eng.to_snapshot_bytes()


def test_harvest_bounds_memory_and_result_refuses():
    eng, _ = _half_run_engine()
    sim = eng.sim
    n_before = len(sim.completed)
    assert n_before > 0
    stats = ServiceStats()
    stats.fold(*eng.harvest_completed())
    assert sim.completed == [] and stats.num_completed == n_before
    eng.drain()
    stats.fold(*eng.harvest_completed())
    with pytest.raises(RuntimeError, match="harvest_completed"):
        eng.result()
    # the stats path reproduces the one-shot result exactly
    sim2 = MIGSimulator(make_scheduler("EDF-SS"))
    oracle = sim2.run(
        generate_scenario("trace-scaled", seed=3, horizon_min=240.0),
        policy=DayNightPolicy(),
    )
    assert stats.result(sim) == oracle


def test_job_disposition_lifecycle():
    eng = _stream_engine(policy=StaticPolicy(2))
    assert eng.job_disposition(0) is None
    eng.inject(J(0, 5.0, work=30.0))
    assert eng.job_disposition(0) == "pending"
    eng.run_until(6.0)
    assert eng.job_disposition(0) == "running"
    eng.inject(J(1, 6.5, work=50.0))
    eng.inject(J(2, 6.5, work=50.0))
    eng.inject(J(3, 6.5, work=50.0))
    eng.run_until(7.0)
    states = {eng.job_disposition(j) for j in (1, 2, 3)}
    assert "queued" in states
    eng.cancel(3)
    assert eng.job_disposition(3) == "cancelled"
    eng.run_until(500.0)
    assert eng.job_disposition(0) == "completed"
    assert eng.job_disposition(3) == "cancelled"


# ---------------------------------------------------------------------------
# the policy registry


def test_make_policy_registry():
    assert make_policy("static").initial_config == 3
    assert make_policy("static:2").initial_config == 2
    dn = make_policy("daynight:6,2")
    assert (dn.day_config, dn.night_config) == (6, 2)
    assert make_policy("nomig").initial_config == 1
    assert make_policy("heuristic").initial_config == 2
    with pytest.raises(ValueError, match="unknown policy spec"):
        make_policy("dqn")
    # fresh instance per call: per-run state must not be shared
    assert make_policy("daynight") is not make_policy("daynight")


def test_service_config_round_trip_and_unknown_key():
    cfg = ServiceConfig(policy="static:2", fleet_profiles=("a100-250w",))
    assert ServiceConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="unknown config keys"):
        ServiceConfig.from_dict({"warp_drive": True})


# ---------------------------------------------------------------------------
# the service, single device


def _submit_all(svc, jobs):
    for j in jobs:
        svc.submit(j)


def test_service_one_shot_equals_engine_through_checkpoints(tmp_path):
    """Feeding a day through the service — with checkpoint/harvest cycles —
    produces the *identical* SimResult as the plain one-shot engine."""
    jobs = generate_scenario("trace-scaled", seed=3, horizon_min=360.0)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    oracle = sim.run(
        generate_scenario("trace-scaled", seed=3, horizon_min=360.0),
        policy=DayNightPolicy(),
    )
    svc = SchedulerService(
        tmp_path / "svc",
        ServiceConfig(policy="daynight", checkpoint_every_min=60.0),
    )
    _submit_all(svc, jobs)
    svc.close()
    assert svc.result() == oracle
    svc.shutdown()
    # checkpoints rotated, WAL truncated
    ckpts = list((tmp_path / "svc").glob("ckpt-*.pkl"))
    assert 1 <= len(ckpts) <= 2
    # a re-opened (recovered) closed service reads the same result
    svc2 = SchedulerService(tmp_path / "svc")
    assert svc2.closed and svc2.result() == oracle


def test_service_submit_validation(tmp_path):
    svc = SchedulerService(tmp_path / "s", ServiceConfig(policy="static"))
    svc.submit(J(0, 10.0))
    with pytest.raises(ValueError, match="already submitted"):
        svc.submit(J(0, 11.0))
    with pytest.raises(ValueError, match="restamp=True"):
        svc.submit(J(1, 5.0))  # before the frontier
    out = svc.submit(J(1, 5.0, slack=60.0), restamp=True)
    assert out["state"] == "submitted"
    st_ = svc.job_status(1)
    assert st_["state"] in ("pending", "queued", "running")
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(J(2, 99.0))
    svc.shutdown()


def test_service_cancel_validation_messages(tmp_path):
    svc = SchedulerService(
        tmp_path / "s", ServiceConfig(policy="static", checkpoint_every_min=0.0)
    )
    with pytest.raises(ValueError, match="never submitted"):
        svc.cancel(9)
    svc.submit(J(0, 0.0, work=1.0))
    svc.tick()  # no clock: no-op, but exercises the path
    svc.submit(J(1, 30.0, work=1.0))  # advances past job 0's completion
    svc.checkpoint()  # harvests job 0 out of the engine
    with pytest.raises(ValueError, match="terminal state 'completed'"):
        svc.cancel(0)
    out = svc.cancel(1)
    assert out["disposition"] in ("unarrived", "dequeued", "preempted")
    with pytest.raises(ValueError, match="terminal state 'cancelled'"):
        svc.cancel(1)
    svc.close()
    svc.shutdown()


def test_service_result_requires_close(tmp_path):
    svc = SchedulerService(tmp_path / "s", ServiceConfig(policy="static"))
    svc.submit(J(0, 0.0, work=1.0))
    with pytest.raises(RuntimeError, match="close"):
        svc.result()
    svc.close()
    assert svc.result().num_jobs == 1
    svc.shutdown()


def test_service_status_summary(tmp_path):
    svc = SchedulerService(tmp_path / "s", ServiceConfig(policy="static"))
    svc.submit(J(0, 0.0, work=500.0))
    svc.submit(J(1, 1.0, work=500.0))
    s = svc.status()
    assert s["submitted"] == 2 and s["devices"] == 1 and not s["closed"]
    assert svc.status(job_id=0)["state"] in ("pending", "queued", "running")
    assert svc.job_status(77)["state"] == "unknown"
    svc.close()
    svc.shutdown()


def test_service_config_mismatch_refused(tmp_path):
    SchedulerService(tmp_path / "s", ServiceConfig(policy="static")).shutdown()
    with pytest.raises(ValueError, match="different config"):
        SchedulerService(tmp_path / "s", ServiceConfig(policy="daynight"))
    with pytest.raises(FileNotFoundError, match="nothing to recover"):
        SchedulerService.recover(tmp_path / "nowhere")


# ---------------------------------------------------------------------------
# fleet stream


def _fleet_oracle(jobs, profiles, dispatcher="least-loaded"):
    fleet = FleetSimulator(FleetSpec.of(profiles, dispatcher=dispatcher))
    return fleet.run(jobs, lambda i, p: make_policy("daynight"))


def test_fleet_stream_bit_identical_to_batch():
    # jobs are stateful (the sim stamps start/completion on them), so each
    # run gets a freshly generated copy of the same scenario
    gen = lambda: generate_scenario("trace-scaled", seed=9, horizon_min=300.0)
    profiles = ("a100-250w", "a30-165w")
    oracle = _fleet_oracle(gen(), profiles)

    jobs = gen()
    fleet = FleetSimulator(FleetSpec.of(profiles, dispatcher="least-loaded"))
    stream = fleet.open_stream(lambda i, p: make_policy("daynight"))
    for k, job in enumerate(jobs):
        if k % 7 == 3:
            stream.run_until(job.arrival)  # interleaved idle ticks
        stream.submit(job)
    stream.close()
    got = stream.result()
    assert got.aggregate == oracle.aggregate
    assert got.per_device == oracle.per_device
    assert got.dispatch_counts == oracle.dispatch_counts


def test_fleet_stream_cancel_routing():
    profiles = ("a100-250w", "a100-250w")
    fleet = FleetSimulator(FleetSpec.of(profiles, dispatcher="round-robin"))
    stream = fleet.open_stream(lambda i, p: make_policy("static"))
    stream.submit(J(0, 0.0, work=200.0))
    stream.submit(J(1, 0.0, work=200.0))
    with pytest.raises(ValueError, match="never dispatched"):
        stream.cancel(5)
    assert stream.cancel(1) in ("unarrived", "dequeued", "preempted")
    stream.close()
    res = stream.result()
    assert res.aggregate.num_jobs == 1
    with pytest.raises(RuntimeError, match="closed"):
        stream.submit(J(2, 1.0))


def test_service_fleet_mode_checkpoint_recovery(tmp_path):
    profiles = ("a100-250w", "a30-165w")
    oracle = _fleet_oracle(
        generate_scenario("trace-scaled", seed=9, horizon_min=240.0), profiles
    )
    jobs = generate_scenario("trace-scaled", seed=9, horizon_min=240.0)

    cfg = ServiceConfig(policy="daynight", fleet_profiles=profiles,
                        checkpoint_every_min=100.0)
    d = tmp_path / "fleet"
    svc = SchedulerService(d, cfg)
    half = len(jobs) // 2
    _submit_all(svc, jobs[:half])
    svc.checkpoint()  # pickles the whole FleetStream
    del svc  # crash (no shutdown)
    svc2 = SchedulerService(d)
    _submit_all(svc2, [j for j in jobs if j.job_id not in svc2.known_jobs])
    svc2.close()
    got = svc2.fleet_result()
    assert got.aggregate == oracle.aggregate
    assert got.per_device == oracle.per_device
    assert svc2.result() == oracle.aggregate
    svc2.shutdown()


# ---------------------------------------------------------------------------
# the socket front end and the CLI


def test_server_round_trip_over_unix_socket(tmp_path):
    import threading

    from repro.service import ServiceServer, wait_for_socket

    sock = tmp_path / "svc.sock"
    svc = SchedulerService(
        tmp_path / "svc",
        ServiceConfig(policy="daynight", checkpoint_every_min=0.0),
    )
    server = ServiceServer(svc, sock, tick_interval_s=0.01)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        wait_for_socket(sock, timeout_s=10.0)
        from repro.service import ServiceClient

        client = ServiceClient(sock)
        assert client.ping()["pong"] is True
        out = client.submit(job_id=0, arrival=0.0, work=1.0,
                            deadline_slack_min=30.0, elasticity="linear")
        assert out["state"] == "submitted"
        out = client.submit(arrival=1.0, work=200.0)  # auto id -> 1
        assert out["job_id"] == 1
        assert client.status()["submitted"] == 2
        assert client.status(job_id=1)["state"] in ("pending", "queued", "running")
        assert client.reconfigure(6)["changed"] in (True, False)
        assert client.cancel(1)["disposition"] in (
            "unarrived", "dequeued", "preempted"
        )
        # errors come back as RuntimeError with the service's message
        with pytest.raises(RuntimeError, match="terminal state"):
            client.cancel(1)
        with pytest.raises(RuntimeError, match="unknown command"):
            client.request({"cmd": "warp"})
        assert client.checkpoint()
        res = client.close_stream()
        assert res["num_jobs"] == 1
        assert client.result() == res
        client.shutdown()
        client.close()
    finally:
        thread.join(timeout=10)
    assert not thread.is_alive()
    assert not sock.exists()  # server cleaned up and checkpointed on exit
    # the workdir recovers to the same closed state
    svc2 = SchedulerService(tmp_path / "svc")
    assert svc2.closed and sim_result_to_dict(svc2.result()) == res


def test_cli_replay_resume_and_flags(tmp_path, capsys):
    import json

    from repro.service.__main__ import main

    d = str(tmp_path / "svc")
    argv = ["replay", "--dir", d, "--scenario", "trace-scaled", "--seed", "7",
            "--max-jobs", "40", "--policy", "daynight"]
    assert main(argv) == 0
    first = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert first["fed"] == 40 and first["skipped"] == 0

    # the workdir is closed now; a second replay skips everything and
    # reads back the identical result — the SIGKILL-resume path's no-op case
    assert main(argv) == 0
    second = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert second["fed"] == 0 and second["skipped"] == 40
    assert second["result"] == first["result"]


# ---------------------------------------------------------------------------
# property: random op interleavings vs the unperturbed oracle


@st.composite
def op_scripts(draw):
    """A random service-op script with nondecreasing op times."""
    n = draw(st.integers(min_value=4, max_value=14))
    ops, t, jid = [], 0.0, 0
    for _ in range(n):
        t += draw(st.floats(min_value=0.0, max_value=25.0))
        kind = draw(st.sampled_from(
            ["submit", "submit", "submit", "cancel", "reconfigure", "tick"]
        ))
        if kind == "submit":
            ops.append((
                "submit", t, jid,
                draw(st.floats(min_value=0.5, max_value=30.0)),
                draw(st.floats(min_value=5.0, max_value=120.0)),
                draw(st.sampled_from(
                    ["linear", "capped@2g", "capped@4g", "exp-0.60", "log-0.65"]
                )),
                draw(st.sampled_from(["inference", "training"])),
            ))
            jid += 1
        elif kind == "cancel":
            ops.append(("cancel", t, draw(st.integers(min_value=0, max_value=max(jid, 1)))))
        elif kind == "reconfigure":
            ops.append(("reconfigure", t, draw(st.sampled_from([1, 2, 3, 6, 9]))))
        else:
            ops.append(("tick", t))
    return ops


def _run_script(scheduler, ops, perturb, seed=0):
    """Apply a script; when ``perturb``, interleave partial advances,
    snapshots, and pickle round-trips — none of which may change the
    outcome."""
    rng = random.Random(seed)
    sim = MIGSimulator(make_scheduler(scheduler))
    eng = SimulationEngine(sim, policy=DayNightPolicy(), stream_open=True)
    outcomes = []
    for idx, op in enumerate(ops):
        t = op[1]
        if perturb:
            if rng.random() < 0.5:
                eng.run_until(t * rng.random(), inclusive=False)
                eng.snapshot()
            if rng.random() < 0.25:
                eng = SimulationEngine.from_snapshot_bytes(eng.to_snapshot_bytes())
        eng.run_until(t, inclusive=False)
        try:
            if op[0] == "submit":
                _, t, jid, work, slack, elast, jk = op
                eng.inject(Job(
                    job_id=jid, kind=JobKind(jk), arrival=t, work=work,
                    deadline=t + slack,
                    elasticity=elasticity_from_label(elast),
                ))
                outcomes.append((idx, "ok"))
            elif op[0] == "cancel":
                outcomes.append((idx, eng.cancel(op[2])))
            elif op[0] == "reconfigure":
                outcomes.append((idx, eng.reconfigure(op[2])))
            else:  # tick: only the perturbed run actually advances here
                if perturb:
                    eng.run_until(t, inclusive=False)
                outcomes.append((idx, "tick"))
        except (ValueError, KeyError, RuntimeError) as e:
            outcomes.append((idx, type(e).__name__))
    eng.close_stream()
    eng.drain()
    return eng.result(), eng.sim.config_trace, outcomes
@settings(max_examples=6)
@given(op_scripts())
def test_interleaving_property_bit_identity(ops):
    """Property: arbitrary interleavings of run_until / snapshot / pickle
    round-trips around the same op sequence are invisible — results, config
    traces, and per-op outcomes (including raised error types) agree
    bit-exactly with the unperturbed application.  Checked across all four
    scheduler families.

    (The schedulers loop lives inside the body because the hypothesis stub
    hides the wrapped signature from pytest.mark.parametrize.)
    """
    for scheduler in SCHEDULERS:
        base = _run_script(scheduler, ops, perturb=False)
        for seed in (1, 2):
            got = _run_script(scheduler, ops, perturb=True, seed=seed)
            assert got[0] == base[0], (scheduler, seed, ops)
            assert got[1] == base[1]
            assert got[2] == base[2]
