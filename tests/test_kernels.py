"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.mlstm import mlstm_chunkwise
from repro.kernels.ref import (
    attention_ref,
    gmm_ref,
    mamba_scan_ref,
    mlstm_chunked_scan,
    mlstm_chunkwise_ref,
)

rng = np.random.default_rng(0)


def t(*s, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=s) * scale, dtype)


# ------------------------------ attention ----------------------------------

ATTN_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, softcap, off, dtype
    (2, 256, 256, 4, 2, 64, True, None, None, 0, jnp.float32),
    (1, 128, 128, 8, 8, 128, True, None, None, 0, jnp.float32),
    (1, 256, 256, 4, 1, 64, True, 128, None, 0, jnp.float32),
    (2, 128, 128, 4, 2, 64, False, None, 50.0, 0, jnp.float32),
    (1, 128, 384, 4, 2, 64, True, None, None, 256, jnp.float32),
    (1, 256, 256, 2, 2, 64, True, None, None, 0, jnp.bfloat16),
    (1, 128, 128, 4, 4, 256, True, 64, None, 0, jnp.float32),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_oracle(case):
    B, Sq, Sk, Hq, Hkv, D, causal, win, cap, off, dtype = case
    q, k, v = t(B, Sq, Hq, D, dtype=dtype), t(B, Sk, Hkv, D, dtype=dtype), t(B, Sk, Hkv, D, dtype=dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=win, softcap=cap, q_offset=off, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal, window=win, softcap=cap, q_offset=off)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_shapes():
    q, k, v = t(1, 512, 2, 64), t(1, 512, 2, 64), t(1, 512, 2, 64)
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 128), (128, 64), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ------------------------------ mamba scan ----------------------------------

MAMBA_CASES = [
    (2, 128, 256, 16, 128, 64, jnp.float32),
    (1, 256, 512, 16, 256, 128, jnp.float32),
    (2, 64, 128, 8, 128, 64, jnp.float32),
    (1, 128, 256, 16, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba_scan_matches_oracle(case):
    B, T, Di, N, bDi, ch, dtype = case
    x = t(B, T, Di, dtype=dtype)
    dt = jax.nn.softplus(t(B, T, Di)) * 0.1
    A = -jnp.exp(t(Di, N) * 0.5)
    Bm, Cm, D = t(B, T, N), t(B, T, N), t(Di)
    out = mamba_scan(
        x, dt.astype(dtype), A, Bm, Cm, D, block_channels=bDi, chunk=ch, interpret=True
    )
    ref = mamba_scan_ref(x, dt.astype(dtype), A, Bm, Cm, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------ mLSTM ---------------------------------------

MLSTM_CASES = [
    (2, 128, 2, 64, 64),
    (1, 256, 4, 64, 128),
    (1, 128, 1, 128, 32),
]


@pytest.mark.parametrize("case", MLSTM_CASES)
def test_mlstm_kernel_matches_oracle(case):
    B, T, H, D, L = case
    q, k, v = t(B, T, H, D), t(B, T, H, D), t(B, T, H, D)
    ig, fg = t(B, T, H), t(B, T, H, scale=2.0) + 2.0
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=L, interpret=True)
    ref = mlstm_chunkwise_ref(q, k, v, ig, fg)
    rel = np.max(np.abs(np.asarray(out) - np.asarray(ref)) / (np.abs(np.asarray(ref)) + 1e-2))
    assert rel < 2e-3, rel


@pytest.mark.parametrize("L", [32, 64, 128])
def test_mlstm_chunked_scan_matches_quadratic(L):
    B, T, H, D = 1, 128, 2, 32
    q, k, v = t(B, T, H, D), t(B, T, H, D), t(B, T, H, D)
    ig, fg = t(B, T, H), t(B, T, H, scale=2.0) + 2.0
    a = mlstm_chunkwise_ref(q, k, v, ig, fg)
    b = mlstm_chunked_scan(q, k, v, ig, fg, chunk=L)
    rel = np.max(np.abs(np.asarray(a) - np.asarray(b)) / (np.abs(np.asarray(a)) + 1e-2))
    assert rel < 2e-3, rel


# ------------------------------ gmm -----------------------------------------


@pytest.mark.parametrize(
    "G,rows,K,N,bm",
    [(4, 256, 256, 128, 128), (8, 128, 512, 256, 128), (2, 128, 128, 128, 64)],
)
def test_gmm_matches_oracle(G, rows, K, N, bm):
    M = G * rows
    lhs, rhs = t(M, K), t(G, K, N)
    sizes = jnp.full((G,), rows, jnp.int32)
    gids = jnp.repeat(jnp.arange(G, dtype=jnp.int32), rows // bm)
    out = gmm(lhs, rhs, gids, block_m=bm, interpret=True)
    ref = gmm_ref(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3, rtol=1e-3)


def test_gmm_uneven_groups():
    G, K, N, bm = 3, 256, 128, 128
    sizes = jnp.array([256, 128, 384], jnp.int32)
    M = int(sizes.sum())
    lhs, rhs = t(M, K), t(G, K, N)
    gids = jnp.asarray(np.repeat(np.arange(G), np.asarray(sizes) // bm), jnp.int32)
    out = gmm(lhs, rhs, gids, block_m=bm, interpret=True)
    ref = gmm_ref(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3, rtol=1e-3)
