"""Predictive repartitioning: forecaster fit, MPC policy, cross-layer wiring.

Pins the properties the forecast subsystem advertises:

* the Fourier day-model recovers the Fig. 5 diurnal rate within tolerance;
* forecaster + policy are deterministic per seed (EWMA state included);
* the controller's repartitions respect the dwell/margin hysteresis, so
  the 4 s penalty amortizes instead of thrash-switching;
* a 1-GPU fleet under the forecast policy is bit-identical to the
  single-MIG path;
* the checked-in ``repartition_policies`` baseline has the predictive
  controller beating static partitioning on ET for the paper's workload.
"""

import dataclasses
import json
import math
import os

import pytest

from repro.core.schedulers import make_scheduler
from repro.core.simulator import REPARTITION_PENALTY_MIN, MIGSimulator
from repro.core.slices import A30_CONFIGS, MIG_CONFIGS
from repro.core.workload import DIURNAL_RATE_PER_MIN, WorkloadSpec, arrival_rate, generate_jobs
from repro.forecast import (
    ArrivalForecaster,
    EWMABiasTracker,
    ForecastPolicy,
    device_forecast_factory,
    expected_throughput,
    fit_fourier_day_model,
    fit_scenario_forecaster,
)
from repro.forecast.policy import DEFAULT_CANDIDATES, erlang_c_wait

BASELINE = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "baselines",
    "repartition_policies.jsonl",
)

DAY = WorkloadSpec()


# ----------------------------------------------------------------------
# forecaster


def test_fourier_fit_recovers_diurnal_rate():
    """Fitted day-model tracks the Fig. 5 pattern within tolerance."""
    model = fit_scenario_forecaster(scenario="paper-diurnal", train_seeds=8)
    errs = [abs(model.rate(h * 60.0) - arrival_rate(h * 60.0)) for h in range(24)]
    rms = math.sqrt(sum(e * e for e in errs) / len(errs))
    assert rms < 0.06, f"RMS fit error {rms:.3f} vs Fig. 5"
    assert max(errs) < 0.12, f"worst-hour error {max(errs):.3f}"
    # rate floor: a thinning sampler / fluid model needs lambda >= 0
    assert all(model.rate(t) >= 0.0 for t in range(0, 1440, 7))


def test_fourier_fit_handles_partial_and_multi_day_observation():
    arrivals = [float(t) for t in range(0, 720, 10)]  # 0.1/min over half a day
    model = fit_fourier_day_model(arrivals, total_minutes=720.0, harmonics=2)
    assert model.rate(360.0) == pytest.approx(0.1, abs=0.05)
    with pytest.raises(ValueError):
        fit_fourier_day_model([], total_minutes=0.0)


def test_ewma_tracker_is_deterministic_and_clipped():
    model = fit_scenario_forecaster()
    t1, t2 = EWMABiasTracker(), EWMABiasTracker()
    obs = [(30.0, 4), (61.0, 9), (95.0, 12), (125.0, 30), (500.0, 31)]
    for t, c in obs:
        t1.update(model, t, c)
        t2.update(model, t, c)
    assert t1.level == t2.level
    assert t1.clip_lo <= t1.bias <= t1.clip_hi
    # a silent stretch cannot zero the forecast
    t1.update(model, 1200.0, 31)
    assert t1.bias >= t1.clip_lo
    # time regression (fresh episode) resets the window state
    t1.update(model, 0.0, 0)
    assert t1.level == 1.0


def test_expected_throughput_and_erlang_shapes():
    # E[tp] interpolates between the elasticity classes: 1 <= tp(k) <= k
    for k in (1, 2, 3, 4, 7):
        assert 1.0 <= expected_throughput(k) <= float(k)
    assert expected_throughput(7) > expected_throughput(2)
    # Erlang-C wait: zero when idle, infinite past saturation, decreasing in c
    assert erlang_c_wait(2, 0.0, 1.0) == 0.0
    assert math.isinf(erlang_c_wait(1, 2.0, 1.0))
    assert erlang_c_wait(4, 0.5, 0.3) < erlang_c_wait(2, 0.5, 0.6)


# ----------------------------------------------------------------------
# ForecastPolicy


def _run_day(seed: int, policy=None):
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    res = sim.run(generate_jobs(DAY, seed), policy=policy or ForecastPolicy())
    return sim, res


def test_policy_deterministic_per_seed():
    _, r1 = _run_day(123)
    _, r2 = _run_day(123)
    assert r1 == r2


def test_policy_respects_dwell_and_amortizes_penalty():
    """Consecutive repartitions are separated by the dwell, and the total
    4 s stall time stays a vanishing fraction of the day — the penalty
    always amortizes (no thrash-switching on queue noise)."""
    policy = ForecastPolicy()
    sim, res = _run_day(7, policy)
    switch_times = [t for t, _ in sim.config_trace[1:]]
    for a, b in zip(switch_times, switch_times[1:], strict=False):
        assert b - a >= policy.min_dwell_min - 1e-6
    assert res.repartitions == len(switch_times)
    stall = res.repartitions * REPARTITION_PENALTY_MIN
    assert stall <= 0.01 * res.extra["makespan_min"], (
        f"{res.repartitions} repartitions stall {stall:.1f} min"
    )


def test_policy_only_chooses_candidate_configs():
    policy = ForecastPolicy()
    assert set(policy.configs) == set(DEFAULT_CANDIDATES)
    sim, _ = _run_day(11, policy)
    assert {cfg for _, cfg in sim.config_trace} <= set(DEFAULT_CANDIDATES)
    assert policy.initial_config in DEFAULT_CANDIDATES


def test_policy_reset_on_reuse():
    """Reusing a policy object for a fresh episode (train_dqn guide runs)
    self-resets on time regression instead of freezing on stale clocks."""
    policy = ForecastPolicy()
    _run_day(5, policy)
    assert policy._last_eval_t > 0.0
    _, r_fresh = _run_day(5, ForecastPolicy())
    _, r_reused = _run_day(5, policy)
    assert r_reused == r_fresh


def test_policy_full_table_and_a30_native():
    # searching the full A100 table stays valid (slower, different choices)
    policy = ForecastPolicy(configs=MIG_CONFIGS)
    assert set(policy.configs) == set(MIG_CONFIGS)
    # native A30 controller evaluates only A30 layouts
    from repro.core.power import A30_165W

    a30 = ForecastPolicy(configs=A30_CONFIGS, power=A30_165W)
    assert set(a30.configs) == set(A30_CONFIGS)
    short = WorkloadSpec(horizon_min=240.0, constant_rate=0.4)
    sim = MIGSimulator(
        make_scheduler("EDF-SS"), power_model=A30_165W, config_table=A30_CONFIGS
    )
    res = sim.run(generate_jobs(short, 3), policy=a30)
    assert res.num_jobs > 0
    assert {cfg for _, cfg in sim.config_trace} <= set(A30_CONFIGS)


# ----------------------------------------------------------------------
# cross-layer wiring


def test_one_gpu_fleet_bit_identical_under_forecast_policy():
    from repro.fleet import FleetSimulator, FleetSpec

    single = MIGSimulator(make_scheduler("EDF-SS")).run(
        generate_jobs(DAY, 42), policy=ForecastPolicy()
    )
    fleet = FleetSimulator(FleetSpec.of(["a100-250w"])).run(
        generate_jobs(DAY, 42), policy_factory=lambda i, prof: ForecastPolicy()
    )
    agg = fleet.aggregate
    for field in dataclasses.fields(type(single)):
        if field.name == "extra":
            continue
        assert getattr(agg, field.name) == getattr(single, field.name), field.name
    assert agg.extra["makespan_min"] == single.extra["makespan_min"]


def test_heterogeneous_fleet_native_and_adapted():
    from repro.fleet import FleetSimulator, FleetSpec

    jobs = generate_jobs(WorkloadSpec(horizon_min=240.0, constant_rate=0.5), 9)
    # native per-device controllers via the factory helper
    res = FleetSimulator(
        FleetSpec.of(["a100-250w", "a30-165w"], dispatcher="least-loaded")
    ).run(jobs, policy_factory=device_forecast_factory())
    assert res.aggregate.num_jobs == len(jobs)
    # registry-path A100-space policy translated by DeviceAdaptedPolicy
    jobs2 = generate_jobs(WorkloadSpec(horizon_min=240.0, constant_rate=0.5), 10)
    res2 = FleetSimulator(
        FleetSpec.of(["a100-250w", "a30-165w"], dispatcher="least-loaded")
    ).run(jobs2, policy_factory=lambda i, p: ForecastPolicy())
    assert res2.aggregate.num_jobs == len(jobs2)


def test_registry_and_scenario_cell():
    from repro.sweep import make_policy, make_scenario_cell, run_cell

    policy = make_policy("forecast", {"scenario": "weekend-flat"})
    assert isinstance(policy, ForecastPolicy)
    cell = make_scenario_cell(
        experiment="t",
        group="g",
        scheduler="EDF-SS",
        scenario="weekend-flat",
        scenario_kwargs={"horizon_min": 240.0},
        seed=4,
        policy="forecast",
        policy_kwargs={"scenario": "weekend-flat"},
    )
    out = run_cell(cell)
    assert out["num_jobs"] > 0


def test_forecaster_guides_arrival_observation():
    model = fit_scenario_forecaster()
    forecaster = ArrivalForecaster(model)
    policy = ForecastPolicy(forecaster)
    _run_day(2, policy)
    # the policy fed realized arrivals to the tracker during the day
    assert forecaster.tracker._window_start > 0.0


# ----------------------------------------------------------------------
# the acceptance claim, pinned against the checked-in baseline


def test_baseline_forecast_beats_static_on_paper_diurnal():
    from repro.sweep import GRIDS

    assert os.path.exists(BASELINE), "repartition_policies baseline missing"
    cells, results = [], []
    with open(BASELINE) as f:
        for line in f:
            if line.strip():
                rec = json.loads(line)
                cells.append(rec["cell"])
                results.append(rec["result"])
    rows = GRIDS["repartition_policies"].aggregate(cells, results)
    by_scenario = {r["scenario"]: r for r in rows}
    paper = by_scenario["paper-diurnal"]
    assert paper["forecast_beats_static"], (
        f"Forecast ET {paper['ET_Forecast']:.4f} must beat "
        f"StaticMIG {paper['ET_StaticMIG']:.4f}"
    )
    # the controller is predictive, not a thrash-switcher: an order of
    # magnitude fewer repartitions than the reactive heuristic
    assert paper["repartitions_Forecast"] < paper["repartitions_Heuristic"] / 10.0
    # every scenario row carries the full family set
    for row in rows:
        for fam in ("NoMIG", "StaticMIG", "DayNightMIG", "Heuristic", "Forecast"):
            assert f"ET_{fam}" in row
