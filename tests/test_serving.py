"""Free-slot geometry, multi-tenant serving, and the serving dispatch stack.

Covers the DESIGN.md §9 layer end-to-end: the fragmentation metric on
``repro.core.slices``, the ``multi-tenant-serving`` scenario family, the
tenant/SLO accounting threaded through ``SimResult`` and the sweep cells,
the ``fragmentation-aware`` dispatcher, and the checked-in
``serving_matrix`` acceptance row.
"""

import itertools
import json
import os
import warnings

import pytest

from repro.core.jobs import Job, JobKind
from repro.core.metrics import TenantSLOStats, merge_tenant_stats, slo_attainment
from repro.core.scenarios import generate_scenario, scenario_names
from repro.core.serving import (
    SERVING_MIXES,
    SLICE_CLASSES,
    generate_serving_jobs,
    model_footprint_gb,
    model_slice_class,
    serving_mix,
)
from repro.core.slices import (
    A30_CONFIGS,
    MIG_CONFIGS,
    TOTAL_SLOTS,
    FreeSlotGeometry,
    Partition,
    SliceType,
    fleet_fragmentation,
    free_slot_geometry,
    table_slice_sizes,
    transition,
    validate_config_table,
)

A100_SIZES = table_slice_sizes(MIG_CONFIGS)
A30_SIZES = table_slice_sizes(A30_CONFIGS)
TABLES = [
    ("A100", MIG_CONFIGS, TOTAL_SLOTS, A100_SIZES),
    ("A30", A30_CONFIGS, 4, A30_SIZES),
]


# ----------------------------------------------------------------------
# free-slot geometry: invariants over every layout and occupancy


@pytest.mark.parametrize("label,configs,slots,sizes", TABLES)
def test_fragmentation_zero_on_empty_and_full_devices(label, configs, slots, sizes):
    for part in configs.values():
        empty = free_slot_geometry(part, (), total_slots=slots, slice_sizes=sizes)
        assert empty.free_slots == slots
        assert empty.max_placeable_slots == max(sizes)
        assert empty.fragmentation == 0.0
        full = free_slot_geometry(
            part, range(part.num_slices), total_slots=slots, slice_sizes=sizes
        )
        # fully occupied: free cells are placement holes only (config 5's
        # slot 3), always placeable as 1g — never counted as fragmented
        assert full.free_slots == slots - part.total_slots
        assert full.fragmentation == 0.0


@pytest.mark.parametrize("label,configs,slots,sizes", TABLES)
def test_geometry_invariants_over_all_occupancies(label, configs, slots, sizes):
    """Exhaustive occupancy sweep: every subset of every layout's slices."""
    for part in configs.values():
        for k in range(part.num_slices + 1):
            for occ in itertools.combinations(range(part.num_slices), k):
                geo = free_slot_geometry(
                    part, occ, total_slots=slots, slice_sizes=sizes
                )
                busy = sum(part.slices[i].slots for i in occ)
                assert geo.free_slots == slots - busy
                assert 0 <= geo.max_placeable_slots <= geo.free_slots
                assert 0.0 <= geo.fragmentation <= 1.0
                # runs are disjoint, ordered, in-grid, and non-empty
                end = -1
                for start, length in geo.runs:
                    assert length > 0
                    assert start > end
                    end = start + length - 1
                    assert end < slots
                # every placeable start is aligned and inside a free run
                free_cells = {
                    c for start, length in geo.runs
                    for c in range(start, start + length)
                }
                for w in sizes:
                    for s in geo.placeable_starts(w):
                        assert all(c in free_cells for c in range(s, s + w))


@pytest.mark.parametrize("label,configs,slots,sizes", TABLES)
def test_transition_created_instances_are_placeable(label, configs, slots, sizes):
    """Geometry is consistent with ``transition()`` over all layout pairs:

    occupy exactly the slices that survive an ``old -> new`` reconfiguration;
    every instance the transition *creates* must then be placeable in the
    free geometry (aligned start, fully inside a free run).
    """
    for old, new in itertools.product(configs.values(), repeat=2):
        plan = transition(old, new)
        survivors = tuple(i for i, _ in plan.surviving)
        geo = free_slot_geometry(
            old, survivors, total_slots=slots, slice_sizes=sizes
        )
        for j in plan.created:
            start, width = new.starts[j], new.slices[j].slots
            assert start in geo.placeable_starts(width), (
                f"{old} -> {new}: created {new.slices[j].name}@{start} "
                f"not placeable in {geo.runs}"
            )
            assert geo.max_placeable_slots >= width


def test_fragmentation_detects_shredded_free_region():
    # cfg 10 = 2g@0 + 2g@2 + 1g@4 + 1g@5 + 1g@6: occupy the two 2g slices
    # and the middle 1g -> free cells {4, 6} are two isolated 1g holes
    part = MIG_CONFIGS[10]
    geo = free_slot_geometry(
        part, (0, 1, 3), total_slots=TOTAL_SLOTS, slice_sizes=A100_SIZES
    )
    assert geo.free_slots == 2
    assert geo.max_placeable_slots == 1
    assert geo.fragmentation == 0.5


def test_fleet_fragmentation_weights_by_free_capacity():
    whole = FreeSlotGeometry(total_slots=7, runs=((0, 7),), slice_sizes=A100_SIZES)
    shredded = FreeSlotGeometry(
        total_slots=7, runs=((0, 1), (2, 1), (4, 1)), slice_sizes=A100_SIZES
    )
    assert fleet_fragmentation([]) == 0.0
    assert fleet_fragmentation([whole]) == 0.0
    assert fleet_fragmentation([shredded]) == pytest.approx(1.0 - 1.0 / 3.0)
    # 7 + 3 free, 7 + 1 placeable
    assert fleet_fragmentation([whole, shredded]) == pytest.approx(1.0 - 8.0 / 10.0)


def test_validate_config_table_errors_name_profile_and_config():
    bad = {1: Partition(config_id=1, slices=(SliceType(4, 20), SliceType(4, 20)))}
    with pytest.raises(AssertionError) as ei:
        validate_config_table(bad, 7, 40, name="test-gpu")
    msg = str(ei.value)
    assert "test-gpu" in msg and "config 1" in msg


# ----------------------------------------------------------------------
# serving workload: model -> slice class mapping and the scenario family


def test_model_slice_class_is_memory_first():
    assert model_slice_class("whisper-base", 1.0) == (1, 5)
    assert model_slice_class("gemma3-1b", 1.0) == (1, 5)
    assert model_slice_class("gemma3-12b", 1.0) == (4, 20)
    assert model_slice_class("gemma3-12b", 0.5) == (2, 10)  # int4 halves it
    assert model_slice_class("mixtral-8x7b", 0.5) == (7, 40)
    with pytest.raises(ValueError):
        model_slice_class("mixtral-8x7b", 2.0)  # bf16 exceeds the device


def test_model_footprint_includes_overhead():
    # overhead multiplier keeps the footprint strictly above raw weights
    raw_gb = 1.0e9 * 1.0 / 1e9
    assert model_footprint_gb("gemma3-1b", 1.0) > raw_gb


def test_serving_mixes_are_well_formed():
    assert set(SERVING_MIXES) == {"balanced", "small-heavy", "large-heavy"}
    for name, tenants in SERVING_MIXES.items():
        assert serving_mix(name) == tenants
        assert len({t.name for t in tenants}) == len(tenants)
        for t in tenants:
            assert t.slice_class in SLICE_CLASSES
            assert t.demand_slots == t.slice_class[0]
    with pytest.raises(KeyError):
        serving_mix("nope")


def test_generate_serving_jobs_deterministic_and_tagged():
    jobs = generate_serving_jobs(7, mix="balanced", horizon_min=360.0)
    again = generate_serving_jobs(7, mix="balanced", horizon_min=360.0)
    assert jobs == again
    assert jobs != generate_serving_jobs(8, mix="balanced", horizon_min=360.0)
    assert jobs
    names = {t.name: t for t in SERVING_MIXES["balanced"]}
    for i, j in enumerate(jobs):
        assert j.job_id == i
        assert j.kind is JobKind.INFERENCE
        assert j.tenant in names
        assert j.slo_min is not None and j.slo_min > 0.0
        assert j.deadline == pytest.approx(j.arrival + j.slo_min)
        spec = names[j.tenant]
        assert j.elasticity.cap == spec.demand_slots
        # work is sized for the demand class: service time x demand slots
        assert j.work == pytest.approx((j.work / spec.demand_slots) * spec.demand_slots)
    arrivals = [j.arrival for j in jobs]
    assert arrivals == sorted(arrivals)


def test_serving_scenario_registered_and_matches_generator():
    assert "multi-tenant-serving" in scenario_names()
    via_registry = generate_scenario(
        "multi-tenant-serving", 3, mix="small-heavy", horizon_min=240.0
    )
    direct = generate_serving_jobs(3, mix="small-heavy", horizon_min=240.0)
    assert via_registry == direct


def test_job_latency_and_slo_attained():
    from repro.core.jobs import LINEAR

    j = Job(0, JobKind.INFERENCE, arrival=10.0, work=1.0, deadline=15.0,
            elasticity=LINEAR, tenant="t", slo_min=5.0)
    assert j.latency() == 0.0 and not j.slo_attained()  # incomplete
    j.completion = 14.0
    assert j.latency() == pytest.approx(4.0)
    assert j.slo_attained()
    j.completion = 15.5
    assert not j.slo_attained()
    # no SLO declared -> vacuously attained once complete
    free = Job(1, JobKind.INFERENCE, arrival=0.0, work=1.0, deadline=9.0,
               elasticity=LINEAR)
    free.completion = 99.0
    assert free.slo_attained()


# ----------------------------------------------------------------------
# tenant accounting: SimResult, cell result dicts, merging


def test_merge_tenant_stats_is_exact():
    a = {"x": TenantSLOStats(jobs=3, attained=2, latency_sum_min=6.0)}
    b = {"x": TenantSLOStats(jobs=1, attained=1, latency_sum_min=2.0),
         "y": TenantSLOStats(jobs=2, attained=0, latency_sum_min=9.0)}
    merged = merge_tenant_stats([a, b])
    assert merged["x"] == TenantSLOStats(jobs=4, attained=3, latency_sum_min=8.0)
    assert merged["y"] == b["y"]
    assert slo_attainment(merged) == pytest.approx(3.0 / 6.0)
    assert slo_attainment({}) == 1.0
    assert merged["x"].attainment == pytest.approx(0.75)
    assert merged["x"].mean_latency_min == pytest.approx(2.0)


def _serving_cell(**overrides):
    from repro.sweep.cells import make_scenario_cell

    kw = dict(
        experiment="t", group="g", scheduler="EDF-SS", seed=11,
        scenario="multi-tenant-serving",
        scenario_kwargs={"horizon_min": 240.0, "load_scale": 0.5},
        policy="static", policy_kwargs={"config_id": 3},
    )
    kw.update(overrides)
    return make_scenario_cell(**kw)


def test_serving_cell_threads_tenants_through_result_dict():
    from repro.sweep.cells import result_to_sim_result, run_cell

    out = run_cell(_serving_cell())
    assert "tenants" in out and "slo_attainment" in out
    res = result_to_sim_result(out)
    assert res.tenants
    assert set(res.tenants) <= {t.name for t in SERVING_MIXES["balanced"]}
    assert 0.0 <= res.slo_attainment <= 1.0
    assert out["slo_attainment"] == pytest.approx(res.slo_attainment)
    for st in res.tenants.values():
        assert isinstance(st, TenantSLOStats)
        assert 0 <= st.attained <= st.jobs


def test_non_serving_cell_emits_no_tenant_keys():
    from repro.sweep.cells import make_scenario_cell, result_to_sim_result, run_cell

    cell = make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-SS", seed=1,
        scenario="weekend-flat", scenario_kwargs={"horizon_min": 120.0},
        policy="static", policy_kwargs={"config_id": 3},
    )
    out = run_cell(cell)
    # absent, not empty: baseline comparison requires exact key equality
    assert "tenants" not in out and "slo_attainment" not in out
    assert result_to_sim_result(out).tenants == {}
    assert result_to_sim_result(out).slo_attainment == 1.0


def test_batched_backend_rejects_serving_cells():
    from repro.core.batched import UnsupportedPolicyError
    from repro.sweep.batched import validate_batched_cell

    cell = _serving_cell(scheduler="EDF-FS", backend="batched")
    with pytest.raises(UnsupportedPolicyError, match="tenant"):
        validate_batched_cell(cell)


# ----------------------------------------------------------------------
# dispatchers: the fragmentation-aware score and the legacy shim


class _FakeState:
    """Minimal structural DeviceState for dispatcher unit tests."""

    def __init__(self, index, profile, geometry, load=0.0):
        self.index = index
        self.profile = profile
        self.dispatched = 0
        self.backlog_1g_min = load * profile.total_slots
        self._geometry = geometry

    @property
    def normalized_load(self):
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self):
        return 0.0

    queue_depth = 0
    repartition_remaining_min = 0.0
    stalled_fraction = 0.0
    free_slices = 1

    def free_geometry(self):
        return self._geometry


def _capped_job(slots, work=4.0):
    from repro.core.serving import class_elasticity

    return Job(0, JobKind.INFERENCE, arrival=0.0, work=work, deadline=60.0,
               elasticity=class_elasticity(slots))


def test_fragmentation_aware_prefers_contiguous_free_region():
    from repro.fleet.devices import device_profile
    from repro.fleet.dispatch import DispatchContext, FragmentationAwareDispatcher

    prof = device_profile("a100-250w")
    shredded = FreeSlotGeometry(
        total_slots=7, runs=((0, 2), (4, 2)), slice_sizes=A100_SIZES
    )
    whole = FreeSlotGeometry(total_slots=7, runs=((0, 4),), slice_sizes=A100_SIZES)
    states = [_FakeState(0, prof, shredded), _FakeState(1, prof, whole)]
    ctx = DispatchContext(t=0.0, job=_capped_job(4), devices=states)
    # only device 1 can place the 4g request now; misfit drives the choice
    assert FragmentationAwareDispatcher().pick(ctx) == 1


def test_fragmentation_aware_spares_the_large_hole_for_small_jobs():
    from repro.fleet.devices import device_profile
    from repro.fleet.dispatch import DispatchContext, FragmentationAwareDispatcher

    prof = device_profile("a100-250w")
    # both devices can place a 1g request; carving it out of the lone 4g
    # run shreds nothing on device 0 (leftover 2g+1g is still placeable),
    # while device 1 keeps a pristine 4-run either way -> equal frag terms
    # break on load, but a *fragmenting* placement is avoided:
    big_hole = FreeSlotGeometry(total_slots=7, runs=((0, 4),), slice_sizes=A100_SIZES)
    small_holes = FreeSlotGeometry(
        total_slots=7, runs=((0, 1), (2, 1)), slice_sizes=A100_SIZES
    )
    states = [_FakeState(0, prof, big_hole), _FakeState(1, prof, small_holes)]
    ctx = DispatchContext(t=0.0, job=_capped_job(1, work=1.0), devices=states)
    # placing 1g into the 4-run leaves a 3g-placeable region (frag 1/3);
    # placing into a 1g hole leaves the other intact (frag 0) -> device 1
    assert FragmentationAwareDispatcher().pick(ctx) == 1


def test_legacy_dispatcher_shim_warns_and_forwards():
    from repro.fleet.devices import device_profile
    from repro.fleet.dispatch import (
        DeviceLoadState,
        DispatchContext,
        as_context_dispatcher,
        make_dispatcher,
    )

    class Legacy:
        name = "legacy-first"

        def pick(self, job, t, states):
            return 0

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        wrapped = as_context_dispatcher(Legacy())
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert wrapped.name == "legacy-first"
    prof = device_profile("a100-250w")
    states = [DeviceLoadState(index=0, profile=prof)]
    ctx = DispatchContext(
        t=0.0, job=_capped_job(1), devices=states, online=False
    )
    assert wrapped.pick(ctx) == 0

    # registry dispatchers already speak the context API: no wrapping
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        d = make_dispatcher("least-loaded")
        assert as_context_dispatcher(d) is d
    assert not w


def test_fleet_serving_run_merges_tenants_across_devices():
    from repro.sweep.cells import make_fleet_cell, result_to_sim_result, run_cell

    cell = make_fleet_cell(
        experiment="t", group="g",
        profiles=["a100-250w", "a30-165w"], dispatcher="fragmentation-aware",
        scheduler="EDF-SS", scenario="multi-tenant-serving",
        scenario_kwargs={"horizon_min": 240.0, "load_scale": 0.5},
        seed=5, policy="static", policy_kwargs={"config_id": 3},
    )
    out = run_cell(cell)
    res = result_to_sim_result(out)
    assert res.tenants
    total = sum(st.jobs for st in res.tenants.values())
    per_device = sum(
        sum(st["jobs"] for st in d.get("tenants", {}).values())
        for d in out["devices"]
    )
    assert total == per_device  # merge is exact, nothing dropped


# ----------------------------------------------------------------------
# the acceptance row, pinned against the checked-in baseline


def test_baseline_fragmentation_aware_beats_least_loaded_on_serving():
    """On the checked-in ``serving_matrix`` baseline the fragmentation-aware
    dispatcher beats least-loaded on fleet SLO attainment at equal-or-better
    energy on the large-heavy mix — on both fleets."""
    from repro.sweep.grids import GRIDS

    baseline = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "baselines",
        "serving_matrix.jsonl",
    )
    assert os.path.exists(baseline), "serving_matrix baseline missing"
    cells, results = [], []
    with open(baseline) as f:
        for line in f:
            if line.strip():
                rec = json.loads(line)
                cells.append(rec["cell"])
                results.append(rec["result"])
    rows = GRIDS["serving_matrix"].aggregate(cells, results)
    by_key = {(r["fleet"], r["mix"], r["dispatcher"]): r for r in rows}
    wins = 0
    for fleet in ("4xA100", "2xA100+2xA30"):
        frag = by_key[(fleet, "large-heavy", "fragmentation-aware")]
        ll = by_key[(fleet, "large-heavy", "least-loaded")]
        assert frag["slo_attainment"] > ll["slo_attainment"], (fleet, frag, ll)
        assert frag["energy_wh"] <= ll["energy_wh"], (fleet, frag, ll)
        wins += 1
    assert wins >= 1
    # every row carries the per-tenant breakdown the nightly artifact reads
    for r in rows:
        assert r["tenant_attainment"]
        assert all(0.0 <= v <= 1.0 for v in r["tenant_attainment"].values())
