"""Crash recovery: snapshot + WAL-tail replay is bit-identical to no crash.

The PR's headline guarantee, pinned three ways:

* an in-process crash matrix — the service is killed (abandoned without
  ``shutdown``) at randomized op indices, recovered, resumed, and the final
  :class:`SimResult` must equal the uninterrupted run's, across two policies
  × both repartition modes;
* a real SIGKILL — ``python -m repro.service replay`` is killed mid-feed
  from outside, then recovered in-process and resumed to the same result;
* a slow-tier soak — a full accelerated diurnal day through the service
  with bounded memory, bounded WAL, and a p99 submit-latency ceiling.
"""

import json
import os
import random
import signal
import subprocess
import sys
import time
import zlib
from pathlib import Path

import pytest

from repro.core.scenarios import generate_scenario
from repro.service import SchedulerService, ServiceConfig, read_wal

POLICIES = ("daynight", "heuristic")
MODES = ("partial", "drain")


def _script(seed, n=110, horizon_min=420.0):
    """An op script: submissions with interleaved cancels and reconfigures."""
    jobs = generate_scenario("trace-scaled", seed=seed, horizon_min=horizon_min)[:n]
    ops = []
    for k, job in enumerate(jobs):
        ops.append(("submit", job))
        if k % 17 == 11:
            ops.append(("cancel", jobs[k - 3].job_id))
        if k % 29 == 23:
            ops.append(("reconfigure", 6 if (k // 29) % 2 == 0 else 2))
    return ops


def _drive(svc, ops):
    for op in ops:
        try:
            if op[0] == "submit":
                svc.submit(op[1])
            elif op[0] == "cancel":
                svc.cancel(op[1])
            else:
                svc.reconfigure(op[1])
        except (ValueError, RuntimeError, KeyError):
            # invalid ops (already-terminal cancel, repart in flight) are
            # rejected *before* logging, so they never enter the WAL and
            # are identical no-ops in every run
            pass


def _resume_ops(svc, ops):
    """The ops a client would re-send after recovery: skip submissions the
    service already knows (ack'd before the crash)."""
    return [
        op for op in ops
        if not (op[0] == "submit" and op[1].job_id in svc.known_jobs)
    ]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_crash_recovery_bit_identical(policy, mode, tmp_path):
    cfg = ServiceConfig(
        policy=policy, repartition_mode=mode, checkpoint_every_min=90.0
    )
    seed = zlib.crc32(f"{policy}/{mode}".encode())
    ops = _script(seed % 16)

    ref = SchedulerService(tmp_path / "ref", cfg)
    _drive(ref, ops)
    ref.close()
    oracle = ref.result()
    ref.shutdown()
    assert oracle.num_jobs > 50

    rng = random.Random(seed)
    cuts = sorted(rng.sample(range(1, len(ops)), 3))
    for ci, cut in enumerate(cuts):
        d = tmp_path / f"crash{ci}"
        victim = SchedulerService(d, cfg)
        _drive(victim, ops[:cut])
        del victim  # crash: no shutdown, no final checkpoint

        svc = SchedulerService(d)  # recover from header+snapshot+WAL tail
        # every op before the cut was acked, so a synchronous client
        # resumes at ops[cut:]; submit dedup guards the ack boundary
        _drive(svc, _resume_ops(svc, ops[cut:]))
        svc.close()
        assert svc.result() == oracle, (policy, mode, cut)
        svc.shutdown()


def test_recovery_replays_only_the_wal_tail(tmp_path):
    """Ops before a checkpoint come back from the snapshot, not the WAL."""
    cfg = ServiceConfig(policy="daynight", checkpoint_every_min=0.0)
    ops = _script(2, n=60)
    k = len(ops) // 2

    d = tmp_path / "svc"
    svc = SchedulerService(d, cfg)
    _drive(svc, ops[:k])
    svc.checkpoint()
    assert read_wal(d / "wal.jsonl") == []  # rotated: all ops snapshotted
    _drive(svc, ops[k:])
    tail = len(read_wal(d / "wal.jsonl"))
    assert tail > 0
    del svc

    svc2 = SchedulerService(d)
    assert svc2.recovered_ops == tail  # only the tail replayed
    svc2.close()
    oracle_dir = tmp_path / "ref"
    ref = SchedulerService(oracle_dir, cfg)
    _drive(ref, _script(2, n=60))
    ref.close()
    assert svc2.result() == ref.result()
    ref.shutdown()
    svc2.shutdown()


def test_recovery_tolerates_torn_wal_tail(tmp_path):
    """A crash mid-append leaves a truncated last line; the unacked op is
    dropped and the service recovers to the state of every *acked* op."""
    cfg = ServiceConfig(policy="static", checkpoint_every_min=0.0)
    ops = _script(4, n=40)
    d = tmp_path / "svc"
    svc = SchedulerService(d, cfg)
    _drive(svc, ops)
    del svc

    wal_path = d / "wal.jsonl"
    full = wal_path.read_bytes()
    wal_path.write_bytes(full[: len(full) - 17])  # tear the final record

    svc2 = SchedulerService(d)
    acked = len(read_wal(wal_path))
    assert svc2.recovered_ops == acked

    # a reference run of just the acked prefix agrees exactly
    ref = SchedulerService(tmp_path / "ref", cfg)
    _drive(ref, _replayable(ops)[:acked])
    svc2.close()
    ref.close()
    assert svc2.result() == ref.result()
    svc2.shutdown()
    ref.shutdown()


def _replayable(ops):
    """The subsequence of ops that actually commit (mirrors _drive's
    swallow-invalid behaviour by simulating against a scratch service)."""
    # ops that raise never reach the WAL; run them through a scratch
    # service to learn which ones committed
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        svc = SchedulerService(
            Path(td), ServiceConfig(policy="static", checkpoint_every_min=0.0)
        )
        kept = []
        for op in ops:
            before = svc.applied_seq
            _drive(svc, [op])
            if svc.applied_seq > before:
                kept.append(op)
        svc.wal.close()
    return kept


def _wait_for_wal_lines(path, n, timeout_s=30.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if path.exists() and sum(1 for _ in open(path)) >= n:
            return True
        time.sleep(0.02)
    return False


def test_sigkill_mid_replay_recovers_bit_identical(tmp_path):
    """Kill a real service process with SIGKILL mid-stream; recovery must
    reproduce the uninterrupted run's result bit-for-bit."""
    n_jobs = 200
    cfg = ServiceConfig(policy="daynight", checkpoint_every_min=60.0)

    # oracle: the same feed, uninterrupted (in-process for speed; the
    # replay CLI's defaults construct exactly this config)
    ref = SchedulerService(tmp_path / "ref", cfg)
    for job in generate_scenario("trace-scaled", seed=3)[:n_jobs]:
        ref.submit(job)
    ref.close()
    oracle = ref.result()
    ref.shutdown()

    d = tmp_path / "victim"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.service", "replay",
            "--dir", str(d), "--scenario", "trace-scaled", "--seed", "3",
            "--max-jobs", str(n_jobs), "--pace-ms", "4",
            "--policy", "daynight", "--checkpoint-every-min", "60",
        ],
        env=env,
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )
    try:
        # wait until the WAL proves we are mid-stream, then SIGKILL
        assert _wait_for_wal_lines(d / "wal.jsonl", 25), "service never started feeding"
        proc.send_signal(signal.SIGKILL)
    finally:
        proc.wait(timeout=30)

    svc = SchedulerService.recover(d)
    assert 0 < len(svc.known_jobs) < n_jobs  # genuinely mid-stream
    for job in generate_scenario("trace-scaled", seed=3)[:n_jobs]:
        if job.job_id not in svc.known_jobs:
            svc.submit(job)
    svc.close()
    assert svc.result() == oracle
    svc.shutdown()


@pytest.mark.slow
def test_service_soak_full_day_bounded(tmp_path):
    """Accelerated full diurnal day: memory, WAL size, and submit latency
    all stay bounded while checkpoints truncate the log."""
    import resource

    jobs = generate_scenario("trace-scaled", seed=0)  # full ~24 h day
    svc = SchedulerService(
        tmp_path / "soak",
        ServiceConfig(policy="daynight", checkpoint_every_min=120.0),
    )
    latencies = []
    max_wal = 0
    rss_mid = None
    for i, job in enumerate(jobs):
        t0 = time.perf_counter()
        svc.submit(job)
        latencies.append(time.perf_counter() - t0)
        max_wal = max(max_wal, svc.wal.size_bytes())
        if i == len(jobs) // 2:
            rss_mid = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    rss_end = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is KB on linux; second-half growth must stay small because
    # checkpointing folds completed jobs out of the engine
    assert rss_end - rss_mid < 200_000, (rss_mid, rss_end)

    # WAL is truncated at every checkpoint: it never accumulates the day
    assert max_wal < 1_000_000, max_wal
    svc.checkpoint()
    assert svc.wal.size_bytes() == 0
    assert len(list((tmp_path / "soak").glob("ckpt-*.pkl"))) <= 2

    # engine population is bounded by in-flight jobs, not history
    assert len(svc.backend.sim.completed) == 0

    lat = sorted(latencies)
    p99 = lat[int(0.99 * (len(lat) - 1))]
    assert p99 < 0.05, f"p99 submit latency {p99 * 1e3:.2f} ms"

    svc.close()
    res = svc.result()
    assert res.num_jobs + int(res.extra.get("cancelled_jobs", 0)) == len(jobs)
    svc.shutdown()
