"""Optimizer, data pipeline, checkpointing (incl. elastic resume), compression."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import smoke_config
from repro.data.pipeline import SyntheticLM, make_batch_specs
from repro.distributed.compression import dequantize_int8, ef_compress, quantize_int8
from repro.optim import AdamW, AdamWConfig, linear_warmup_cosine


# ------------------------------ optimizer -----------------------------------


def test_adamw_converges_on_quadratic():
    opt = AdamW(AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip_norm=None))
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(jnp.square(q["w"])))(p)
        return opt.update(g, s, p)

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2
    assert int(state.step) == 200


def test_adamw_bf16_states():
    opt = AdamW(AdamWConfig(state_dtype="bfloat16"))
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.bfloat16) * 0.1}
    p2, s2 = opt.update(g, state, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert not bool(jnp.any(jnp.isnan(p2["w"].astype(jnp.float32))))


def test_grad_clip_bounds_update():
    opt = AdamW(AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0))
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    g = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    p2, _ = opt.update(g, state, params)
    assert float(jnp.max(jnp.abs(p2["w"]))) <= 1.1  # ~lr x mhat/sqrt(vhat)


def test_schedule_warmup_then_decay():
    lr = linear_warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1.0, abs=0.02)
    assert float(lr(99)) < 0.2


# ------------------------------ data -----------------------------------------


def test_data_determinism_and_restart_safety():
    cfg = smoke_config("stablelm_3b")
    d1 = SyntheticLM(cfg, global_batch=4, seq_len=32, seed=7)
    d2 = SyntheticLM(cfg, global_batch=4, seq_len=32, seed=7)
    b5a = d1.batch_for_step(5)
    _ = d1.batch_for_step(6)
    b5b = d2.batch_for_step(5)  # fresh pipeline, same step -> same batch
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert b5a["tokens"].shape == (4, 32)
    # labels are next tokens of the same stream
    assert b5a["tokens"].max() < cfg.vocab_size


def test_data_host_sharding_partitions_batch():
    cfg = smoke_config("stablelm_3b")
    d = SyntheticLM(cfg, global_batch=8, seq_len=16, seed=0)
    s0 = d.shard_for_step(3, 0, 2)
    s1 = d.shard_for_step(3, 1, 2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_batch_specs_cover_modalities():
    whisper = smoke_config("whisper_base")
    specs = make_batch_specs(whisper, 2, 64)
    assert "enc_frames" in specs and specs["tokens"].shape == (2, 64)
    vlm = smoke_config("phi3_vision_4_2b")
    specs = make_batch_specs(vlm, 2, 64)
    assert "img_embeds" in specs
    assert specs["tokens"].shape == (2, 64 - vlm.vision_tokens)


# ------------------------------ checkpoint -----------------------------------


def _tree():
    return {
        "a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "b": [jnp.ones((2,), jnp.bfloat16), jnp.asarray(3, jnp.int32)],
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), 7, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(out["a"]["w"]), np.asarray(tree["a"]["w"]))
    assert out["b"][0].dtype == jnp.bfloat16


def test_checkpoint_detects_shape_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(
            str(tmp_path), 1, jax.eval_shape(lambda: {"w": jnp.zeros((3, 3))})
        )


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"w": jnp.full((4,), s, jnp.float32)})
    mgr.wait()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4]
    out = restore_checkpoint(str(tmp_path), 4, jax.eval_shape(lambda: {"w": jnp.zeros((4,))}))
    assert float(out["w"][0]) == 4.0


# ------------------------------ compression ----------------------------------


@given(st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(257,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s, pad = quantize_int8(x)
    y = dequantize_int8(q, s, pad, x.shape)
    # per-block max-scale bounds error by scale/2 per element
    blocks = np.abs(np.asarray(x)).max()
    assert float(jnp.max(jnp.abs(x - y))) <= blocks / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    err = jnp.zeros_like(x)
    acc_plain = jnp.zeros_like(x)
    acc_ef = jnp.zeros_like(x)
    for _ in range(50):
        q, s, pad = quantize_int8(x)
        acc_plain = acc_plain + dequantize_int8(q, s, pad, x.shape)
        dec, err = ef_compress(x, err)
        acc_ef = acc_ef + dec
    true = x * 50
    # EF accumulation tracks the true sum tighter than plain quantization
    assert float(jnp.max(jnp.abs(acc_ef - true))) <= float(
        jnp.max(jnp.abs(acc_plain - true))
    ) + 1e-5
    assert float(jnp.max(jnp.abs(acc_ef - true))) < 0.2


def test_adamw_tuple_pytree_params():
    """Param trees containing tuples (the DQN's list of (w, b) layers) must
    update leaf-by-leaf against the params treedef — a tuple-sniffing
    tree_map would mis-split them into (new_p, new_m, new_v) triples."""
    opt = AdamW(AdamWConfig(lr=0.01, weight_decay=0.0, grad_clip_norm=None))
    params = [
        (jnp.ones((3, 2)), jnp.zeros((2,))),
        (jnp.ones((2, 4)), jnp.zeros((4,))),
    ]
    state = opt.init(params)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    p2, s2 = opt.update(grads, state, params)
    # structure preserved exactly
    assert jax.tree_util.tree_structure(p2) == jax.tree_util.tree_structure(params)
    assert jax.tree_util.tree_structure(s2.m) == jax.tree_util.tree_structure(params)
    # every leaf moved against the gradient
    for before, after in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2), strict=True
    ):
        assert before.shape == after.shape
        assert bool(jnp.all(after < before))
    assert int(s2.step) == 1
