"""Event-driven simulator invariants (unit + hypothesis)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.jobs import Job, JobKind, LINEAR, capped
from repro.core.metrics import SimResult
from repro.core.power import A100_250W
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    REPARTITION_PENALTY_MIN,
    StaticPolicy,
)
from repro.core.workload import WorkloadSpec, generate_jobs


def _sim(name="EDF-SS", **kw):
    return MIGSimulator(make_scheduler(name), **kw)


def test_single_job_exact_completion_and_energy():
    # one linear job, work 6 1g-min, on config 5 (two 3g slices) -> 2 min
    j = Job(0, JobKind.INFERENCE, 0.0, work=6.0, deadline=10.0, elasticity=LINEAR)
    sim = _sim()
    res = sim.run([j], policy=StaticPolicy(5))
    assert j.completion == pytest.approx(2.0)
    assert res.avg_tardiness == 0.0
    # energy: 2 min at 3 busy slots
    assert res.energy_wh == pytest.approx(A100_250W.energy_wh(3, 2.0))
    assert res.busy_slot_minutes == pytest.approx(6.0)


def test_tardiness_measured_exactly():
    j = Job(0, JobKind.INFERENCE, 0.0, work=7.0, deadline=0.5, elasticity=LINEAR)
    sim = _sim()
    res = sim.run([j], policy=StaticPolicy(1))  # 7g slice: 1 minute
    assert j.completion == pytest.approx(1.0)
    assert res.avg_tardiness == pytest.approx(0.5)
    assert res.max_tardiness == pytest.approx(0.5)
    # tardiness integral equals summed tardiness when all jobs finish
    assert res.extra["tardiness_integral"] == pytest.approx(0.5, abs=1e-6)


def test_capped_job_gets_no_speedup_beyond_cap():
    j = Job(0, JobKind.INFERENCE, 0.0, work=4.0, deadline=50.0, elasticity=capped(2))
    sim = _sim()
    sim.run([j], policy=StaticPolicy(1))  # 7g but capped at 2 -> 2 min
    assert j.completion == pytest.approx(2.0)


def test_all_jobs_complete_and_determinism():
    spec = WorkloadSpec(horizon_min=300.0, constant_rate=0.4)
    jobs1 = generate_jobs(spec, seed=11)
    jobs2 = generate_jobs(spec, seed=11)
    r1 = _sim().run(jobs1, policy=StaticPolicy(3))
    r2 = _sim().run(jobs2, policy=StaticPolicy(3))
    assert r1.num_jobs == len(jobs1)
    assert r1.energy_wh == pytest.approx(r2.energy_wh)
    assert r1.avg_tardiness == pytest.approx(r2.avg_tardiness)
    assert r1.preemptions == r2.preemptions


@given(st.integers(0, 300), st.sampled_from([1, 2, 3, 6, 9, 12]))
@settings(max_examples=20, deadline=None)
def test_property_conservation(seed, cfg_id):
    """Busy-slot-minutes == total work processed; all jobs complete."""
    spec = WorkloadSpec(horizon_min=120.0, constant_rate=0.3)
    jobs = generate_jobs(spec, seed=seed)
    sim = _sim()
    res = sim.run(jobs, policy=StaticPolicy(cfg_id))
    assert res.num_jobs == len(jobs)
    for j in jobs:
        assert j.remaining == pytest.approx(0.0, abs=1e-6)
        assert j.completion is not None and j.completion >= j.arrival
    # processed work (in slot-minutes at unit rate) <= busy slot minutes:
    # inelastic jobs occupy more slots than they productively use
    total_work = sum(j.work for j in jobs)
    assert res.busy_slot_minutes >= total_work - 1e-6
    # energy bounded by idle..peak over the makespan
    mk = res.extra["makespan_min"]
    assert res.energy_wh <= A100_250W.energy_wh(7, mk) + 1e-6
    assert res.energy_wh >= A100_250W.energy_wh(0, mk) - 1e-6


def test_tardiness_integral_matches_sum():
    spec = WorkloadSpec(horizon_min=240.0, constant_rate=0.5)
    jobs = generate_jobs(spec, seed=3)
    res = _sim().run(jobs, policy=StaticPolicy(6))
    assert res.extra["tardiness_integral"] == pytest.approx(
        res.total_tardiness, rel=1e-6, abs=1e-6
    )


def test_repartition_penalty_blocks_processing():
    # job arrives during the switch; nothing processes for 4 s
    j0 = Job(0, JobKind.INFERENCE, 0.0, work=1.0, deadline=5.0, elasticity=LINEAR)
    sim = _sim()

    class SwitchOnce:
        initial_config = 1
        done = False

        def decide(self, t, s):
            if not self.done:
                self.done = True
                return 2
            return None

        def next_timer(self, t):
            return None

    res = sim.run([j0], policy=SwitchOnce())
    # switch fires at arrival: 4 s stall; EDF-SS then picks the SLOWEST
    # feasible slice of config 2 (3g): 1/3 min
    assert j0.completion == pytest.approx(REPARTITION_PENALTY_MIN + 1.0 / 3.0)
    assert res.repartitions == 1


class _SwitchAtSecondArrival:
    """Switch cfg5 -> cfg2 when the third decision point opens (t=5)."""

    initial_config = 5
    n = 0

    def decide(self, t, s):
        self.n += 1
        return 2 if self.n == 3 else None

    def next_timer(self, t):
        return None


def _repartition_jobs():
    return [
        Job(0, JobKind.TRAINING, 0.0, 30.0, 100.0, LINEAR),
        Job(1, JobKind.TRAINING, 0.0, 30.0, 100.0, LINEAR),
        Job(2, JobKind.INFERENCE, 5.0, 1.0, 50.0, LINEAR),
    ]


def test_drain_repartition_preempts_all_running():
    sim = _sim(repartition_mode="drain")
    res = sim.run(_repartition_jobs(), policy=_SwitchAtSecondArrival())
    assert res.repartitions == 1
    assert res.preemptions >= 2  # both running jobs kicked to queue


def test_partial_repartition_spares_surviving_slice():
    # cfg5 (3g@0 + 3g@4) -> cfg2 (4g@0 + 3g@4): the 3g@4 instance survives,
    # so exactly one of the two running jobs is preempted by the switch
    sim = _sim(repartition_mode="partial")
    res = sim.run(_repartition_jobs(), policy=_SwitchAtSecondArrival())
    assert res.repartitions == 1
    assert res.preemptions == 1


def test_daynight_policy_switches_at_boundaries():
    spec = WorkloadSpec(horizon_min=24 * 60.0)
    jobs = generate_jobs(spec, seed=9)
    sim = _sim()
    res = sim.run(jobs, policy=DayNightPolicy())
    assert res.repartitions >= 2  # 5:00 and 17:00
    cfgs = [c for _, c in sim.config_trace]
    assert 6 in cfgs and 2 in cfgs


def test_no_mig_runs_single_slice_with_speedup():
    spec = WorkloadSpec(horizon_min=120.0, constant_rate=0.2)
    jobs = generate_jobs(spec, seed=2)
    sim = MIGSimulator(make_scheduler("EDF-SS"), mig_enabled=False)
    res = sim.run(jobs, policy=NoMIGPolicy())
    assert res.repartitions == 0
    assert sim.partition.config_id == 1


def test_restricted_preemption_reduction():
    """Fig. 4: restricted EDF-SS cuts preemptions 63-99% at similar ET."""
    spec = WorkloadSpec(horizon_min=480.0, constant_rate=0.5)
    tot = {"EDF-SS": 0, "EDF-SS-unrestricted": 0}
    for name in tot:
        sim = _sim(name)
        for s in range(3):
            tot[name] += sim.run(generate_jobs(spec, seed=s), policy=StaticPolicy(6)).preemptions
    reduction = 1.0 - tot["EDF-SS"] / max(tot["EDF-SS-unrestricted"], 1)
    assert 0.5 <= reduction <= 1.0, reduction
