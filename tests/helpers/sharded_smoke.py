"""Subprocess helper: exercise the sharding machinery on 8 fake CPU devices.

Must run in its own process (forces the device count before jax init).
Lowers + compiles + EXECUTES a smoke-config train step and a serve step on a
4x2 (data, model) mesh, and checks elastic checkpoint restore onto a
different mesh layout.  Exits nonzero on any failure.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.configs import smoke_config
    from repro.data.pipeline import SyntheticLM
    from repro.distributed.sharding import (
        batch_shardings,
        cache_shardings,
        param_shardings,
    )
    from repro.distributed.step import make_serve_step, make_train_step
    from repro.launch.mesh import make_smoke_mesh, set_ambient_mesh
    from repro.models import init_cache, init_params
    from repro.optim import AdamW, AdamWConfig

    assert jax.device_count() == 8, jax.device_count()
    mesh = make_smoke_mesh(4, 2)
    set_ambient_mesh(mesh)

    cfg = smoke_config("mixtral_8x7b")  # MoE + SWA exercises EP + ring caches
    params = init_params(cfg, seed=0)
    p_shard = param_shardings(params, mesh)
    params = jax.device_put(params, p_shard)
    opt = AdamW(AdamWConfig(lr=1e-3))
    opt_state = opt.init(params)

    data = SyntheticLM(cfg, global_batch=8, seq_len=64, seed=0)
    batch = data.batch_for_step(0)
    b_shard = batch_shardings(batch, mesh)
    batch = jax.device_put(batch, b_shard)

    step = jax.jit(make_train_step(cfg, opt, accum_steps=2, impl="ref"),
                   donate_argnums=(0, 1))
    with mesh:
        params, opt_state, metrics = step(params, opt_state, batch)
        loss0 = float(metrics["loss"])
        params, opt_state, metrics = step(params, opt_state, batch)
        loss1 = float(metrics["loss"])
    assert np.isfinite(loss0) and np.isfinite(loss1), (loss0, loss1)
    assert loss1 < loss0 + 1.0  # sane

    # serve step on the mesh with sharded caches
    cache = init_cache(cfg, batch=8, max_len=64)
    cache = jax.device_put(cache, cache_shardings(cache, mesh, 8))
    serve = jax.jit(make_serve_step(cfg, impl="ref"), donate_argnums=(1,))
    tok = jnp.zeros((8, 1), jnp.int32)
    with mesh:
        logits, cache = serve(params, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (8, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # elastic restore: save from the 4x2 mesh, restore onto 2x4
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"params": params})
        mesh2 = make_smoke_mesh(2, 4)
        tgt = jax.eval_shape(lambda: {"params": params})
        shard2 = {"params": param_shardings(params, mesh2)}
        out = restore_checkpoint(d, 1, tgt, shardings=shard2)
        x = jax.tree_util.tree_leaves(out)[0]
        assert x.sharding.mesh.shape == {"data": 2, "model": 4}
    print("SHARDED_SMOKE_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
