"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (its own process) forces 512 host devices.

Hypothesis shim: ``hypothesis`` is a declared test dependency, but hermetic
environments that only bake the runtime toolchain may lack it.  Rather than
letting five test files die at collection, install the deterministic stub
from ``tests/_hypothesis_stub.py`` (boundary values + seeded random draws).
The real package always wins when importable.
"""

import importlib.util
import os
import sys

import numpy as np
import pytest


def _ensure_hypothesis() -> None:
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["hypothesis"] = mod
    spec.loader.exec_module(mod)
    sys.modules["hypothesis.strategies"] = mod.strategies


_ensure_hypothesis()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
