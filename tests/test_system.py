"""End-to-end behaviour tests for the paper's system.

These assert the paper's HEADLINE CLAIMS hold in this reproduction:
  1. Table II ordering: EDF-SS < EDF-FS < LLF < LALF on ET over the
     experiment basket (§V-B).
  2. Fig. 4: restricted EDF-SS preempts 63-99% less at similar ET.
  3. Table III direction: dynamic repartitioning beats all benchmarks and
     no-MIG is far worst.
"""

import pytest

from repro.core.metrics import et_table

# full-day ET batteries across the whole config grid: minutes, not seconds
pytestmark = pytest.mark.slow
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    StaticPolicy,
)
from repro.core.workload import WorkloadSpec, generate_jobs
from repro.launch.cluster_sim import queue_heuristic_policy


def _eval(policy_factory, spec, seeds, scheduler="EDF-SS", mig_enabled=True):
    sim = MIGSimulator(make_scheduler(scheduler), mig_enabled=mig_enabled)
    return [sim.run(generate_jobs(spec, seed=s), policy=policy_factory()) for s in seeds]


def test_table2_scheduler_ordering_on_basket():
    """EDF-SS wins the Table II basket; LLF < LALF."""
    specs = [
        WorkloadSpec(),  # diurnal, 80% inference
        WorkloadSpec(horizon_min=480.0, constant_rate=0.1),
        WorkloadSpec(horizon_min=480.0, constant_rate=0.5),
        WorkloadSpec(inference_split=0.2),
    ]
    names = ["EDF-FS", "EDF-SS", "LLF", "LALF"]
    per = {n: [] for n in names}
    for si, spec in enumerate(specs):
        for cfg in range(1, 13):
            for n in names:
                sim = MIGSimulator(make_scheduler(n))
                jobs = generate_jobs(spec, seed=9000 * si + 17 * cfg)
                per[n].append(sim.run(jobs, policy=StaticPolicy(cfg)))
    table, _ = et_table(per)
    assert table["EDF-SS"] < table["EDF-FS"], table
    assert table["LLF"] < table["LALF"], table
    assert table["EDF-SS"] < table["LLF"], table


def test_fig4_preemption_reduction_with_similar_et():
    """Aggregate over all 12 configs (Fig. 4 is per-config; the ET-parity
    claim holds on the experiment aggregate — see EXPERIMENTS.md)."""
    spec = WorkloadSpec()
    per = {"EDF-SS": [], "EDF-SS-unrestricted": []}
    preempt = {n: 0 for n in per}
    for n in per:
        sim = MIGSimulator(make_scheduler(n))
        for cfg in range(1, 13):
            for s in range(2):
                r = sim.run(generate_jobs(spec, seed=100 * cfg + s), policy=StaticPolicy(cfg))
                per[n].append(r)
                preempt[n] += r.preemptions
    table, _ = et_table(per)
    reduction = 1 - preempt["EDF-SS"] / max(preempt["EDF-SS-unrestricted"], 1)
    assert 0.5 <= reduction <= 0.995, reduction
    # similar ET on the aggregate: restricted within 15% of unrestricted
    assert table["EDF-SS"] <= 1.15 * table["EDF-SS-unrestricted"], table


def test_table3_no_mig_is_far_worst():
    spec = WorkloadSpec()
    seeds = range(40_000, 40_006)
    per = {
        "NoMIG": _eval(NoMIGPolicy, spec, seeds, mig_enabled=False),
        "Static": _eval(lambda: StaticPolicy(3), spec, seeds),
        "DayNight": _eval(DayNightPolicy, spec, seeds),
        "Dynamic": _eval(queue_heuristic_policy, spec, seeds),
    }
    table, _ = et_table(per)
    assert table["NoMIG"] > 2.0 * table["Static"], table
    assert table["NoMIG"] > 2.0 * table["DayNight"], table


def test_table3_dynamic_beats_every_benchmark():
    spec = WorkloadSpec()
    seeds = range(41_000, 41_008)
    per = {
        "Static": _eval(lambda: StaticPolicy(3), spec, seeds),
        "DayNight": _eval(DayNightPolicy, spec, seeds),
        "Dynamic": _eval(queue_heuristic_policy, spec, seeds),
        "NoMIG": _eval(NoMIGPolicy, spec, seeds, mig_enabled=False),
    }
    table, _ = et_table(per)
    assert table["Dynamic"] < table["Static"], table
    assert table["Dynamic"] < table["DayNight"], table
    assert table["Dynamic"] < table["NoMIG"], table
