"""Sweep engine: hashing determinism, cache behavior, worker independence."""

import json
import os

import pytest

from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator, StaticPolicy
from repro.core.workload import WorkloadSpec, generate_jobs
from repro.sweep import (
    GRIDS,
    StaleCacheError,
    SweepCache,
    cell_hash,
    make_cell,
    make_scenario_cell,
    result_to_sim_result,
    run_cell,
    run_cells,
    run_grid,
)

TINY = WorkloadSpec(horizon_min=90.0, constant_rate=0.2)


def _tiny_cells(n_seeds=4, experiment="t", group="EDF-SS"):
    return [
        make_cell(
            experiment=experiment,
            group=group,
            scheduler="EDF-SS",
            workload=TINY,
            seed=s,
            policy="static",
            policy_kwargs={"config_id": 3},
        )
        for s in range(n_seeds)
    ]


# ----------------------------------------------------------------------
# hashing


def test_cell_hash_deterministic_and_content_addressed():
    a, b = _tiny_cells(1)[0], _tiny_cells(1)[0]
    assert cell_hash(a) == cell_hash(b)
    c = dict(a, seed=99)
    assert cell_hash(c) != cell_hash(a)
    d = dict(a, scheduler="LLF")
    assert cell_hash(d) != cell_hash(a)
    e = dict(a, policy_kwargs={"config_id": 4})
    assert cell_hash(e) != cell_hash(a)


def test_dqn_cells_hash_weights_content_not_just_path(tmp_path):
    params = tmp_path / "dqn_params.npz"
    params.write_bytes(b"weights-v1")
    kw = {"params_path": str(params)}
    cell_v1 = make_cell(
        experiment="t", group="dqn", scheduler="EDF-SS", workload=TINY,
        seed=0, policy="dqn", policy_kwargs=kw,
    )
    params.write_bytes(b"weights-v2-retrained")
    cell_v2 = make_cell(
        experiment="t", group="dqn", scheduler="EDF-SS", workload=TINY,
        seed=0, policy="dqn", policy_kwargs=kw,
    )
    assert cell_hash(cell_v1) != cell_hash(cell_v2), (
        "retrained weights at the same path must invalidate the cache"
    )
    # the digest is a hash-only annotation; factories never see it
    from repro.sweep import make_policy

    assert make_policy("static", {"config_id": 2, "_params_digest": "x"}).initial_config == 2


def test_cell_hash_ignores_grid_labels_but_not_sim_version():
    a = _tiny_cells(1, experiment="x", group="g1")[0]
    b = _tiny_cells(1, experiment="y", group="g2")[0]
    assert cell_hash(a) == cell_hash(b)  # same physics, different labels
    assert cell_hash(a, sim_version="other") != cell_hash(a)


# ----------------------------------------------------------------------
# run_cell matches a direct simulator run


def test_run_cell_matches_direct_simulation():
    cell = _tiny_cells(1)[0]
    got = result_to_sim_result(run_cell(cell))
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    want = sim.run(generate_jobs(TINY, seed=0), policy=StaticPolicy(3))
    assert got.energy_wh == want.energy_wh
    assert got.avg_tardiness == want.avg_tardiness
    assert got.preemptions == want.preemptions
    assert got.num_jobs == want.num_jobs
    assert got.extra["makespan_min"] == want.extra["makespan_min"]


# ----------------------------------------------------------------------
# cache


def test_cache_hit_miss_and_resume(tmp_path):
    cache_dir = str(tmp_path / "cache")
    cells = _tiny_cells(3)

    out1 = run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    assert (out1.cached_count, out1.computed_count) == (0, 3)

    out2 = run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    assert (out2.cached_count, out2.computed_count) == (3, 0)
    assert out2.results == out1.results

    # --no-resume recomputes but results stay identical
    out3 = run_cells("t", cells, cache=cache_dir, resume=False, artifacts_dir=None)
    assert (out3.cached_count, out3.computed_count) == (0, 3)
    assert out3.results == out1.results

    # a new cell is a miss; old cells still hit
    out4 = run_cells("t", _tiny_cells(4), cache=cache_dir, artifacts_dir=None)
    assert (out4.cached_count, out4.computed_count) == (3, 1)


def test_cache_rejects_torn_and_foreign_entries(tmp_path):
    cache = SweepCache(str(tmp_path))
    cell = _tiny_cells(1)[0]
    h = cell_hash(cell)
    assert cache.get(h) is None  # miss on empty

    cache.put(h, cell, {"energy_wh": 1.0})
    assert cache.get(h) == {"energy_wh": 1.0}

    # torn write -> treated as a miss, not a crash
    with open(cache._path(h), "w") as f:
        f.write('{"sim_version": "mig-sim')
    assert cache.get(h) is None

    # hand-copied entry from a different simulator version at the current
    # version's path -> miss (the payload check backs up the filename)
    with open(cache._path(h), "w") as f:
        json.dump({"sim_version": "ancient", "cell": cell, "result": {}}, f)
    assert cache.get(h) is None


def test_ad_hoc_policy_bypasses_cache(tmp_path):
    cache_dir = str(tmp_path / "cache")
    cells = _tiny_cells(2)
    out = run_cells(
        "t", cells, cache=cache_dir, artifacts_dir=None,
        policy_factory=lambda: StaticPolicy(3),
    )
    assert out.computed_count == 2
    assert len(SweepCache(cache_dir)) == 0  # nothing persisted


def test_resume_refuses_stale_sim_version(tmp_path):
    """Regression: --resume after a semantics change must refuse, not mix.

    A cache directory holding cells recorded under a different SIM_VERSION
    (e.g. populated before a bump, or hand-copied) raises StaleCacheError on
    resume; --no-resume and purge_stale() are the documented ways out.
    """
    cache_dir = str(tmp_path / "cache")
    cells = _tiny_cells(2)
    run_cells("t", cells, cache=cache_dir, artifacts_dir=None)

    # plant entries from a pre-bump version and from the pre-versioned-
    # filename era; both must trip the refusal
    with open(os.path.join(cache_dir, "0" * 64 + ".mig-sim-0.json"), "w") as f:
        json.dump({"sim_version": "mig-sim-0", "cell": {}, "result": {}}, f)
    with open(os.path.join(cache_dir, "1" * 64 + ".json"), "w") as f:
        json.dump({"sim_version": "mig-sim-0", "cell": {}, "result": {}}, f)

    with pytest.raises(StaleCacheError, match="different\\s+simulator version"):
        run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    # the error names the escape hatches
    with pytest.raises(StaleCacheError, match="purge-stale-cache"):
        run_cells("t", cells, cache=cache_dir, artifacts_dir=None)

    # --no-resume bypasses the cache read and still completes — and must NOT
    # disarm the refusal on the next resume
    out = run_cells("t", cells, cache=cache_dir, artifacts_dir=None, resume=False)
    assert out.computed_count == 2
    with pytest.raises(StaleCacheError):
        run_cells("t", cells, cache=cache_dir, artifacts_dir=None)

    # purging removes exactly the two foreign entries, then resume works
    assert SweepCache(cache_dir).purge_stale() == 2
    out2 = run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    assert (out2.cached_count, out2.computed_count) == (2, 0)


def test_clean_cache_resume_still_works(tmp_path):
    """The version check must not break ordinary warm-cache resumes."""
    cache_dir = str(tmp_path / "cache")
    cells = _tiny_cells(3)
    run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    out = run_cells("t", cells, cache=cache_dir, artifacts_dir=None)
    assert (out.cached_count, out.computed_count) == (3, 0)


def test_cli_purge_without_grid_is_purge_only(tmp_path, capsys):
    """The StaleCacheError remediation command must purge and exit, not
    launch the default full-scale sweep."""
    from repro.sweep.__main__ import main

    cache_dir = str(tmp_path / "cache")
    os.makedirs(cache_dir)
    with open(os.path.join(cache_dir, "a" * 64 + ".mig-sim-0.json"), "w") as f:
        json.dump({"sim_version": "mig-sim-0", "cell": {}, "result": {}}, f)
    rc = main(["--purge-stale-cache", "--cache-dir", cache_dir])
    assert rc == 0
    assert len(SweepCache(cache_dir)) == 0
    out = capsys.readouterr()
    assert "purged 1" in out.err
    assert "###" not in out.out, "no grid must have run"


def test_cli_check_baseline_rejects_multiple_grids(tmp_path):
    from repro.sweep.__main__ import main

    baseline = tmp_path / "b.jsonl"
    baseline.write_text("")
    with pytest.raises(SystemExit):
        main(["smoke", "fleet_scaling", "--check-baseline", str(baseline)])


# ----------------------------------------------------------------------
# scenario cells


def test_scenario_cell_resolves_defaults_and_hashes_on_them():
    a = make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-SS",
        scenario="weekend-flat", seed=0,
    )
    assert a["scenario"]["kwargs"]["rate_per_min"] == 0.15  # default resolved
    b = make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-SS",
        scenario="weekend-flat", seed=0, scenario_kwargs={"rate_per_min": 0.3},
    )
    assert cell_hash(a) != cell_hash(b)
    with pytest.raises(KeyError):
        make_scenario_cell(
            experiment="t", group="g", scheduler="EDF-SS",
            scenario="weekend-flat", seed=0, scenario_kwargs={"bogus": 1},
        )


def test_paper_diurnal_scenario_cell_matches_workload_cell_results():
    """Scenario cells and raw-spec cells describe the same physics for the
    paper workload — their results must agree exactly."""
    spec_cell = make_cell(
        experiment="t", group="g", scheduler="EDF-SS",
        workload=WorkloadSpec(), seed=4,
        policy="static", policy_kwargs={"config_id": 3},
    )
    scen_cell = make_scenario_cell(
        experiment="t", group="g", scheduler="EDF-SS",
        scenario="paper-diurnal", seed=4,
        policy="static", policy_kwargs={"config_id": 3},
    )
    a, b = run_cell(spec_cell), run_cell(scen_cell)
    for k in ("energy_wh", "avg_tardiness", "num_jobs", "preemptions", "extra"):
        assert a[k] == b[k], k


# ----------------------------------------------------------------------
# worker-count independence + artifacts


def test_worker_count_independence_and_jsonl_artifact(tmp_path):
    cells = [
        make_cell(
            experiment="t",
            group=n,
            scheduler=n,
            workload=TINY,
            seed=s,
            policy="static",
            policy_kwargs={"config_id": cfg},
        )
        for n in ("EDF-SS", "LLF")
        for cfg in (2, 3)
        for s in range(2)
    ]
    a1 = str(tmp_path / "a1")
    a4 = str(tmp_path / "a4")
    out1 = run_cells("grid", cells, workers=1, cache=False, artifacts_dir=a1)
    out4 = run_cells("grid", cells, workers=4, cache=False, artifacts_dir=a4)

    assert out1.results == out4.results
    b1 = open(os.path.join(a1, "grid.jsonl"), "rb").read()
    b4 = open(os.path.join(a4, "grid.jsonl"), "rb").read()
    assert b1 == b4, "JSONL artifact must not depend on worker count"

    lines = [json.loads(x) for x in b1.decode().splitlines()]
    assert len(lines) == len(cells)
    assert all(set(rec) == {"hash", "cell", "result"} for rec in lines)
    # grid order is preserved
    assert [rec["cell"]["seed"] for rec in lines] == [c["seed"] for c in cells]
    # volatile timing never leaks into the artifact
    assert all("elapsed_s" not in rec["result"] for rec in lines)


def test_parallel_failure_reports_cell(tmp_path):
    bad = _tiny_cells(2)
    bad[1]["policy"] = "nonexistent-policy"
    with pytest.raises(Exception, match="nonexistent-policy"):
        run_cells("t", bad, workers=2, cache=False, artifacts_dir=None)


# ----------------------------------------------------------------------
# baseline gate (CI)


def test_check_baseline_detects_drift(tmp_path):
    from repro.sweep.__main__ import check_baseline

    cells = _tiny_cells(2)
    out = run_cells("base", cells, cache=False, artifacts_dir=str(tmp_path))
    baseline = str(tmp_path / "baseline.jsonl")
    import shutil

    shutil.copy(out.jsonl_path, baseline)
    assert check_baseline(out.jsonl_path, baseline, rtol=1e-9) == 0

    # perturb one result -> exactly one mismatch
    lines = [json.loads(x) for x in open(baseline)]
    lines[0]["result"]["energy_wh"] *= 1.001
    with open(baseline, "w") as f:
        for rec in lines:
            f.write(json.dumps(rec) + "\n")
    assert check_baseline(out.jsonl_path, baseline, rtol=1e-9) == 1
    # ...which a loose tolerance forgives
    assert check_baseline(out.jsonl_path, baseline, rtol=0.01) == 0


# ----------------------------------------------------------------------
# grids registry


def test_grids_build_and_smoke_aggregates(tmp_path):
    for name, grid in GRIDS.items():
        cells = grid.build(0.1)
        assert cells, name
        hashes = {cell_hash(c) for c in cells}
        assert len(hashes) == len(cells), f"{name}: duplicate cells"

    rows, outcome = run_grid(
        "smoke", scale=0.05, workers=0,
        cache=str(tmp_path / "c"), artifacts_dir=str(tmp_path / "a"),
    )
    assert [r["algorithm"] for r in rows] == ["EDF-FS", "EDF-SS", "LLF", "LALF"]
    assert all(r["ET"] >= 0 for r in rows)
    assert os.path.exists(outcome.jsonl_path)

    # warm rerun serves everything from cache
    rows2, outcome2 = run_grid(
        "smoke", scale=0.05, workers=0,
        cache=str(tmp_path / "c"), artifacts_dir=str(tmp_path / "a"),
    )
    assert rows2 == rows
    assert outcome2.computed_count == 0


# ----------------------------------------------------------------------
# CellSpec: the unified cell constructor must not move a single hash


def test_cellspec_preserves_baseline_hashes():
    """Every checked-in baseline cell must be rebuildable through the
    ``CellSpec`` path at exactly its recorded hash — the regression pin
    behind collapsing the three legacy constructors into one dataclass."""
    base = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "baselines"
    )
    grid_for = {
        "smoke_sweep.jsonl": "smoke",
        "fleet_scaling.jsonl": "fleet_scaling",
        "scenario_matrix.jsonl": "scenario_matrix",
        "repartition_policies.jsonl": "repartition_policies",
        "dispatchers.jsonl": "dispatchers",
        "repartition_modes.jsonl": "repartition_modes",
        "serving_matrix.jsonl": "serving_matrix",
    }
    checked = 0
    for fname, grid in grid_for.items():
        path = os.path.join(base, fname)
        assert os.path.exists(path), f"baseline {fname} missing"
        with open(path) as f:
            want = {
                json.loads(line)["hash"] for line in f if line.strip()
            }
        built = {cell_hash(c) for c in GRIDS[grid].build(0.1)}
        missing = want - built
        assert not missing, f"{fname}: {len(missing)} baseline hashes moved"
        checked += len(want)
    assert checked >= 100  # the pin is only meaningful on the full basket


def test_cellspec_validates_field_combinations():
    from repro.sweep.cells import CellSpec

    ok = CellSpec(
        experiment="t", group="g", scheduler="EDF-SS", seed=1,
        workload=TINY,
    )
    legacy = make_cell(
        experiment="t", group="g", scheduler="EDF-SS", seed=1, workload=TINY,
    )
    assert ok.to_cell() == legacy  # wrappers and direct spec agree exactly

    with pytest.raises(ValueError, match="exactly one job stream"):
        CellSpec(experiment="t", group="g", scheduler="EDF-SS", seed=1).to_cell()
    with pytest.raises(ValueError, match="exactly one job stream"):
        CellSpec(
            experiment="t", group="g", scheduler="EDF-SS", seed=1,
            workload=TINY, scenario="weekend-flat",
        ).to_cell()
    with pytest.raises(ValueError, match="scenario_kwargs"):
        CellSpec(
            experiment="t", group="g", scheduler="EDF-SS", seed=1,
            workload=TINY, scenario_kwargs={"load_scale": 2.0},
        ).to_cell()
    with pytest.raises(ValueError, match="dispatcher"):
        CellSpec(
            experiment="t", group="g", scheduler="EDF-SS", seed=1,
            scenario="weekend-flat", fleet_profiles=["a100-250w"],
        ).to_cell()
    with pytest.raises(ValueError, match="fleet cells"):
        CellSpec(
            experiment="t", group="g", scheduler="EDF-SS", seed=1,
            workload=TINY, dispatcher="round-robin",
        ).to_cell()
    with pytest.raises(ValueError, match="oracle"):
        CellSpec(
            experiment="t", group="g", scheduler="EDF-SS", seed=1,
            scenario="weekend-flat", fleet_profiles=["a100-250w"],
            dispatcher="round-robin", backend="batched",
        ).to_cell()
