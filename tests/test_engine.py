"""SimulationEngine: step-wise == one-shot bit-identity, streaming, fixes.

The headline invariant of the engine extraction: driving the event loop
step-by-step (or injecting the same arrivals online) produces a
bit-identical ``SimResult``, config trace, and preemption count to the
one-shot ``MIGSimulator.run()`` for every policy family × scheduler ×
scenario.  Plus regression tests for the two event-loop fixes that rode
along: the spurious-completion recompute and the policy-timer set pruning.
"""

import math

import pytest

from repro.core.engine import EventKind, SimulationEngine
from repro.core.jobs import Job, JobKind, LINEAR
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    StaticPolicy,
)
from repro.core.workload import WorkloadSpec, generate_jobs
from repro.launch.cluster_sim import queue_heuristic_policy

SHORT = WorkloadSpec(horizon_min=180.0, constant_rate=0.4)

#: the four deterministic repartitioning-policy families (the DQN needs
#: trained weights and the forecast controller is pinned by
#: tests/test_forecast.py's own bit-identity test)
POLICY_FAMILIES = {
    "nomig": (lambda: NoMIGPolicy(), False),
    "static": (lambda: StaticPolicy(3), True),
    "daynight": (lambda: DayNightPolicy(), True),
    "heuristic": (lambda: queue_heuristic_policy(), True),
}

SCHEDULERS = ("EDF-FS", "EDF-SS", "LLF", "LALF")

#: (scenario, seed) triples the property matrix runs over — kept short
#: (3-hour horizons) so the full 4 × 4 × 3 grid stays in the fast tier
SCENARIO_SEEDS = (
    ("trace-scaled", 3),
    ("bursty-mmpp", 5),
    ("weekend-flat", 11),
)
SCENARIO_KW = {"horizon_min": 180.0}


@pytest.mark.parametrize("family", sorted(POLICY_FAMILIES))
@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_stepwise_bit_identical_to_one_shot(family, scheduler):
    """Property: for every policy family × scheduler × scenario/seed, the
    step-wise engine run equals one-shot run() on the full SimResult, the
    config trace, and the preemption count — bit for bit."""
    factory, mig_enabled = POLICY_FAMILIES[family]
    for scenario, seed in SCENARIO_SEEDS:
        jobs_a = generate_scenario(scenario, seed=seed, **SCENARIO_KW)
        jobs_b = generate_scenario(scenario, seed=seed, **SCENARIO_KW)

        sim_a = MIGSimulator(make_scheduler(scheduler), mig_enabled=mig_enabled)
        res_a = sim_a.run(jobs_a, policy=factory())

        sim_b = MIGSimulator(make_scheduler(scheduler), mig_enabled=mig_enabled)
        engine = SimulationEngine(sim_b, policy=factory(), jobs=jobs_b)
        steps = 0
        while engine.step() is not None:
            steps += 1
        res_b = engine.result()

        assert res_a == res_b, (family, scheduler, scenario, seed)
        assert sim_a.config_trace == sim_b.config_trace
        assert sim_a.preemptions == sim_b.preemptions
        assert sim_a.util_histogram == sim_b.util_histogram
        # events_processed counts heap pops incl. stale predictions, so it
        # bounds the number of step() returns from above
        assert steps <= engine.events_processed <= sim_b.max_events


def test_online_injection_bit_identical_to_preloaded():
    """Injecting the arrival stream online (stream_open + inject per job)
    replays the exact event sequence of a pre-loaded engine."""
    jobs_a = generate_jobs(SHORT, seed=13)
    jobs_b = generate_jobs(SHORT, seed=13)

    sim_a = MIGSimulator(make_scheduler("EDF-SS"))
    res_a = sim_a.run(jobs_a, policy=DayNightPolicy())

    sim_b = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim_b, policy=DayNightPolicy(), stream_open=True)
    for job in jobs_b:
        engine.run_until(job.arrival, inclusive=False)
        engine.inject(job)
    engine.close_stream()
    engine.drain()
    assert engine.result() == res_a
    assert sim_b.config_trace == sim_a.config_trace


def test_run_until_is_resumable_and_monotone():
    jobs = generate_jobs(SHORT, seed=21)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=StaticPolicy(3), jobs=jobs)
    n1 = engine.run_until(60.0)
    t_mid = sim.t
    assert t_mid <= 60.0
    snap = engine.snapshot()
    assert snap.sim.t == t_mid
    assert snap.events_processed == engine.events_processed
    n2 = engine.run_until(60.0)
    assert n2 == 0  # idempotent at the same bound
    engine.drain()
    assert engine.finished
    assert engine.result().num_jobs == len(jobs)
    assert n1 > 0


def test_stream_open_engine_is_never_finished_while_idle():
    """An idle stream-open engine is merely between injections: finished
    must stay False (and result() must refuse) until close_stream()."""
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=StaticPolicy(3), stream_open=True)
    assert not engine.finished  # empty heap, but the stream is open
    with pytest.raises(RuntimeError, match="open stream"):
        engine.result()
    engine.inject(Job(0, JobKind.INFERENCE, 1.0, 1.0, 10.0, LINEAR))
    engine.drain()
    assert not engine.finished  # drained, still open
    engine.close_stream()
    assert engine.finished
    assert engine.result().num_jobs == 1


def test_inject_rejects_past_arrivals():
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=StaticPolicy(3), stream_open=True)
    engine.inject(Job(0, JobKind.INFERENCE, 0.0, 1.0, 10.0, LINEAR))
    engine.run_until(50.0)
    with pytest.raises(ValueError, match="cannot inject"):
        engine.inject(Job(1, JobKind.INFERENCE, 0.5, 1.0, 10.0, LINEAR))
    with pytest.raises(ValueError, match="already injected"):
        engine.inject(Job(0, JobKind.INFERENCE, 60.0, 1.0, 70.0, LINEAR))


def test_trace_sink_sees_every_event():
    jobs = generate_jobs(WorkloadSpec(horizon_min=60.0, constant_rate=0.3), seed=4)
    events = []
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(
        sim, policy=StaticPolicy(3), jobs=jobs, trace_sink=events.append
    )
    steps = engine.drain()
    assert len(events) == steps <= engine.events_processed
    arrivals = [e for e in events if e.kind == EventKind.ARRIVAL]
    completions = [e for e in events if e.kind == EventKind.COMPLETION and e.decision]
    assert len(arrivals) == len(jobs)
    assert len(completions) == len(jobs)
    ts = [e.t for e in events]
    assert ts == sorted(ts)


def test_interactive_mode_pauses_at_decisions():
    jobs = generate_jobs(WorkloadSpec(horizon_min=60.0, constant_rate=0.3), seed=4)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, interactive=True, initial_config=2, jobs=jobs)
    decisions = 0
    while engine.run_to_decision():
        assert engine.awaiting_decision
        with pytest.raises(RuntimeError, match="decision pending"):
            engine.step()
        engine.provide_decision(None)
        decisions += 1
    assert decisions > 0
    assert engine.finished
    assert engine.result().num_jobs == len(jobs)
    with pytest.raises(RuntimeError, match="no decision pending"):
        engine.provide_decision(None)


def test_spurious_completion_recomputes_finish_time():
    """Regression (satellite fix): a completion event that fires before the
    job's float depletion reaches zero must be re-predicted from current
    assignments, not blindly re-pushed at t + 1e-6 until the event budget
    burns."""
    job = Job(0, JobKind.INFERENCE, 0.0, work=7.0, deadline=10.0, elasticity=LINEAR)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=StaticPolicy(1), jobs=[job])
    # process the arrival (assigns the job to the 7g slice; finish at t=1.0)
    ev = engine.step()
    assert ev.kind == EventKind.ARRIVAL
    # manufacture the numerical race: force a completion event far before
    # the true finish time, carrying the current (valid) version
    engine._push(0.25, EventKind.COMPLETION, job.job_id, engine._version)
    ev = engine.step()
    assert ev.kind == EventKind.COMPLETION and not ev.decision  # spurious
    # the fix: the follow-up completion is recomputed from the remaining
    # work at the device's current rate — NOT t + 1e-6
    pending = [
        (t, EventKind(k), ver)
        for (t, k, _, _, ver) in engine._heap
        if EventKind(k) == EventKind.COMPLETION and ver == engine._version
    ]
    assert pending, "recomputed completion must be scheduled"
    # (the arrival's original prediction may coexist at the same version;
    # every live completion must sit at the true finish, not t + 1e-6)
    for t_next, _, _ in pending:
        assert t_next == pytest.approx(1.0)
        assert not math.isclose(t_next, 0.25 + 1e-6)
    engine.drain()
    res = engine.result()
    assert res.num_jobs == 1
    assert job.completion == pytest.approx(1.0)
    # the whole run stays within a handful of events (no re-push storm)
    assert engine.events_processed < 10


def test_timer_set_is_pruned_on_pop():
    """Regression (satellite fix): the policy-timer dedup set must not grow
    with every timer ever fired — multi-day streaming runs would otherwise
    leak memory linearly in simulated time."""

    class MinutelyTimer(StaticPolicy):
        def __init__(self):
            super().__init__(config_id=3)

        def next_timer(self, t):
            return math.floor(t) + 1.0

    # one long job keeps the system active for 200 minutes of timer chain
    job = Job(0, JobKind.TRAINING, 0.0, work=600.0, deadline=300.0, elasticity=LINEAR)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=MinutelyTimer(), jobs=[job])
    max_pending = 0
    while engine.step() is not None:
        max_pending = max(max_pending, len(engine._timer_scheduled))
    assert engine.result().num_jobs == 1
    # ~200 timers fired; the pruned set only ever holds the pending one(s)
    assert engine.events_processed > 150
    assert max_pending <= 2


def test_snapshot_fields_are_consistent():
    jobs = generate_jobs(SHORT, seed=30)
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(sim, policy=queue_heuristic_policy(), jobs=jobs)
    engine.run_until(90.0)
    snap = engine.snapshot()
    s = snap.sim
    assert s.t == sim.t
    assert s.config_id == sim.partition.config_id
    assert s.jobs_in_system == len([j for j in sim.active.values() if not j.done])
    assert s.active_jobs == len(sim.active)
    assert s.backlog_1g_min == pytest.approx(
        sum(j.remaining for j in sim.active.values() if not j.done)
    )
    assert s.inference_backlog_1g_min + s.training_backlog_1g_min == pytest.approx(
        s.backlog_1g_min
    )
    assert s.running == len(sim.assignment)
    assert snap.pending_arrivals == engine.arrivals_pending
    if not engine.finished:
        assert snap.next_event_time is not None


def test_one_shot_run_still_validates_policy_choice():
    class BadPolicy(StaticPolicy):
        def __init__(self):
            super().__init__(config_id=3)

        def decide(self, t, sim):
            return 99

    with pytest.raises(KeyError, match="not in this device's table"):
        MIGSimulator(make_scheduler("EDF-SS")).run(
            generate_jobs(WorkloadSpec(horizon_min=30.0, constant_rate=0.2), 1),
            policy=BadPolicy(),
        )
