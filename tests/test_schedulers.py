"""Scheduler invariants (unit + hypothesis property tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.jobs import Job, JobKind, LINEAR, capped, sublinear
from repro.core.schedulers import SCHEDULERS, make_scheduler
from repro.core.slices import config
from repro.core.workload import WorkloadSpec, generate_jobs


def _mk_jobs(n, seed=0, t=0.0):
    rng = np.random.default_rng(seed)
    jobs = []
    elk = [LINEAR, capped(2), capped(4), sublinear("exp-0.35"), sublinear("log-0.65")]
    for i in range(n):
        work = float(rng.uniform(0.5, 30.0))
        el = elk[int(rng.integers(0, len(elk)))]
        dl = t + float(rng.uniform(0.2, 6.0)) * el.duration(work, 7)
        jobs.append(Job(i, JobKind.INFERENCE, arrival=t, work=work, deadline=dl, elasticity=el))
    return jobs


@pytest.mark.parametrize("name", list(SCHEDULERS))
@pytest.mark.parametrize("cfg_id", [1, 3, 5, 9, 12])
def test_assignment_validity(name, cfg_id):
    sched = make_scheduler(name)
    part = config(cfg_id)
    jobs = _mk_jobs(12, seed=cfg_id)
    out = sched.assign(0.0, part, jobs, {}, True)
    # no slice double-booked; all ids valid; no done jobs scheduled
    assert len(set(out.values())) == len(out)
    assert all(0 <= s < part.num_slices for s in out.values())
    ids = {j.job_id for j in jobs}
    assert set(out).issubset(ids)
    # work-conserving: min(#jobs, #slices) assignments made
    assert len(out) == min(len(jobs), part.num_slices)


@given(st.integers(0, 500), st.sampled_from([2, 3, 6, 9]))
@settings(max_examples=40, deadline=None)
def test_property_work_conserving_and_valid(seed, cfg_id):
    part = config(cfg_id)
    jobs = _mk_jobs(seed % 9 + 1, seed=seed)
    for name in ("EDF-FS", "EDF-SS", "LLF", "LALF"):
        out = make_scheduler(name).assign(0.0, part, jobs, {}, True)
        assert len(set(out.values())) == len(out)
        assert len(out) == min(len(jobs), part.num_slices)


def test_edf_fs_priority_order():
    part = config(3)  # 4g, 2g, 1g
    jobs = _mk_jobs(5, seed=1)
    jobs.sort(key=lambda j: j.deadline)
    out = make_scheduler("EDF-FS").assign(0.0, part, jobs, {}, True)
    # earliest deadline gets the fastest slice
    assert out[jobs[0].job_id] == 0
    # third earliest gets the 1g slice; later jobs wait
    assert out[jobs[2].job_id] == 2
    assert jobs[3].job_id not in out


def test_edf_ss_picks_slowest_feasible():
    part = config(3)  # 4g, 2g, 1g
    # single job, lots of slack: must land on the 1g slice
    j = Job(0, JobKind.INFERENCE, 0.0, work=1.0, deadline=100.0, elasticity=LINEAR)
    out = make_scheduler("EDF-SS").assign(0.0, part, [j], {}, True)
    assert out[0] == 2
    # tight deadline: only 4g feasible
    j2 = Job(1, JobKind.INFERENCE, 0.0, work=1.0, deadline=0.3, elasticity=LINEAR)
    out = make_scheduler("EDF-SS").assign(0.0, part, [j2], {}, True)
    assert out[1] == 0
    # impossible deadline: fastest slice (paper rule)
    j3 = Job(2, JobKind.INFERENCE, 0.0, work=10.0, deadline=0.1, elasticity=LINEAR)
    out = make_scheduler("EDF-SS").assign(0.0, part, [j3], {}, True)
    assert out[2] == 0


def test_restricted_edf_ss_keeps_running_jobs():
    part = config(5)  # 3g, 3g
    a = Job(0, JobKind.INFERENCE, 0.0, work=9.0, deadline=50.0, elasticity=LINEAR)
    b = Job(1, JobKind.INFERENCE, 0.0, work=9.0, deadline=60.0, elasticity=LINEAR)
    sched = make_scheduler("EDF-SS")
    cur = {0: 1, 1: 0}  # both running, swapped relative to fresh EDF order
    out = sched.assign(1.0, part, [a, b], cur, True)
    assert out == cur  # no gratuitous reshuffle


def test_restricted_edf_ss_preempts_to_save_deadline():
    part = config(2)  # 4g, 3g
    # running job with late deadline occupies the 4g slice
    runner = Job(0, JobKind.INFERENCE, 0.0, work=20.0, deadline=500.0, elasticity=LINEAR)
    cur = {0: 0}
    # urgent job can ONLY make its deadline on the 4g slice
    urgent = Job(1, JobKind.INFERENCE, 0.0, work=4.0, deadline=1.2, elasticity=LINEAR)
    out = make_scheduler("EDF-SS").assign(0.0, part, [runner, urgent], cur, True)
    assert out[1] == 0  # urgent stole the fast slice
    assert out.get(0) == 1  # victim re-queued onto the free 3g


def test_llf_priority_is_laxity_not_deadline():
    part = config(1)  # single 7g slice
    # A: far deadline but huge work (low laxity). B: near deadline, tiny work.
    a = Job(0, JobKind.TRAINING, 0.0, work=70.0, deadline=12.0, elasticity=LINEAR)
    b = Job(1, JobKind.INFERENCE, 0.0, work=0.7, deadline=5.0, elasticity=LINEAR)
    out = make_scheduler("LLF").assign(0.0, part, [a, b], {}, True)
    # laxity(a) = 12 - 10 = 2 ; laxity(b) = 5 - 0.1 = 4.9 -> a runs
    assert out[0] == 0 and 1 not in out
    out2 = make_scheduler("EDF-FS").assign(0.0, part, [a, b], {}, True)
    assert out2[1] == 0  # EDF picks b instead


def test_lalf_uses_average_laxity():
    part = config(3)
    sched = make_scheduler("LALF")
    j = Job(0, JobKind.INFERENCE, 0.0, work=7.0, deadline=20.0, elasticity=LINEAR)
    lax = sched.job_laxity(0.0, part, j)
    # mean duration across slices (4g, 2g, 1g): mean(7/4, 7/2, 7) = 4.08
    assert lax == pytest.approx(20.0 - (7 / 4 + 7 / 2 + 7) / 3)


def test_critical_laxity_timer():
    part = config(1)
    sched = make_scheduler("LLF")
    run = Job(0, JobKind.TRAINING, 0.0, work=50.0, deadline=100.0, elasticity=LINEAR)
    wait = Job(1, JobKind.INFERENCE, 0.0, work=7.0, deadline=9.0, elasticity=LINEAR)
    cur = {0: 0}
    t = sched.next_critical_time(0.0, part, [run, wait], cur)
    # waiting laxity = 9 - 1 = 8; crosses threshold 1 at t = 7
    assert t == pytest.approx(7.0)
    wait.critical_events = sched.max_critical_preemptions
    assert sched.next_critical_time(0.0, part, [run, wait], cur) is None
