"""Distributed machinery on 8 fake devices (subprocess: needs XLA_FLAGS
before jax init, while the rest of the suite must keep 1 device)."""

import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers", "sharded_smoke.py")
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_sharded_train_serve_and_elastic_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, HELPER],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "SHARDED_SMOKE_OK" in proc.stdout


def test_param_spec_rules_are_complete():
    """Every leaf of every smoke arch resolves to a valid PartitionSpec."""
    import jax
    from repro.configs import ARCH_IDS, smoke_config
    from repro.distributed.sharding import param_spec
    from repro.models import abstract_params

    for name in ARCH_IDS:
        cfg = smoke_config(name)
        abs_params = abstract_params(cfg)
        for path, leaf in jax.tree_util.tree_leaves_with_path(abs_params):
            spec = param_spec(path, leaf)
            assert len(spec) <= leaf.ndim, (name, path, spec, leaf.shape)
