"""Scenario registry: invariants every registered generator must satisfy."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.jobs import JobKind
from repro.core.scenarios import (
    SCENARIOS,
    generate_scenario,
    resolve_scenario_kwargs,
    scenario_names,
)
from repro.core.workload import WorkloadSpec, generate_jobs

EXPECTED = {
    "paper-diurnal",
    "trace-scaled",
    "bursty-mmpp",
    "heavy-tail-lognormal",
    "heavy-tail-pareto",
    "weekend-flat",
}


def _key(j):
    """Job identity up to the (non-comparable) elasticity callable."""
    return (j.job_id, j.kind, j.arrival, j.work, j.deadline, j.elasticity.label,
            j.speedup_no_mig)


def test_registry_contents():
    assert EXPECTED <= set(scenario_names())
    for name in scenario_names():
        sc = SCENARIOS[name]
        assert sc.doc
        assert "horizon_min" in sc.defaults, f"{name}: scenarios must bound time"


def test_resolve_kwargs_rejects_unknown_knobs():
    kw = resolve_scenario_kwargs("bursty-mmpp", {"burst_mult": 5.0})
    assert kw["burst_mult"] == 5.0
    assert kw["quiet_mult"] == SCENARIOS["bursty-mmpp"].defaults["quiet_mult"]
    with pytest.raises(KeyError):
        resolve_scenario_kwargs("bursty-mmpp", {"no_such_knob": 1})
    with pytest.raises(KeyError):
        resolve_scenario_kwargs("no-such-scenario", None)


def test_paper_diurnal_bit_identical_to_legacy_path():
    """The invariant the sweep cache + baselines lean on."""
    for seed in (0, 7, 12345):
        got = generate_scenario("paper-diurnal", seed=seed)
        want = generate_jobs(WorkloadSpec(), seed)
        assert [_key(j) for j in got] == [_key(j) for j in want]


@given(st.sampled_from(sorted(EXPECTED)), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_scenario_stream_invariants(name, seed):
    jobs = generate_scenario(name, seed=seed, horizon_min=360.0)
    arrivals = [j.arrival for j in jobs]
    assert arrivals == sorted(arrivals), f"{name}: arrivals must be sorted"
    assert all(0.0 <= a < 360.0 for a in arrivals)
    assert [j.job_id for j in jobs] == list(range(len(jobs)))
    for j in jobs:
        assert j.work > 0.0, f"{name}: nonpositive duration"
        assert np.isfinite(j.work)
        assert j.deadline >= j.arrival
        assert j.kind in (JobKind.INFERENCE, JobKind.TRAINING)


@given(st.sampled_from(sorted(EXPECTED)), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_scenario_deterministic_per_seed(name, seed):
    a = generate_scenario(name, seed=seed, horizon_min=360.0)
    b = generate_scenario(name, seed=seed, horizon_min=360.0)
    assert [_key(j) for j in a] == [_key(j) for j in b]
    # and a different seed actually changes the stream (whp; pinned seeds)
    c = generate_scenario(name, seed=seed + 1, horizon_min=360.0)
    assert [_key(j) for j in a] != [_key(j) for j in c] or not a


def test_load_scale_scales_volume():
    lo = generate_scenario("trace-scaled", seed=3, load_scale=1.0)
    hi = generate_scenario("trace-scaled", seed=3, load_scale=3.0)
    assert len(hi) > 1.8 * len(lo)


def test_heavy_tails_are_heavier():
    """Capped Pareto/lognormal draws must produce a fatter right tail than
    the §V-A Exp/Uniform model at matched means."""
    base = generate_scenario("paper-diurnal", seed=11)
    pareto = generate_scenario("heavy-tail-pareto", seed=11)
    q99_base = np.quantile([j.work for j in base], 0.99)
    q99_pareto = np.quantile([j.work for j in pareto], 0.99)
    assert q99_pareto > q99_base
    assert max(j.work for j in pareto) <= 480.0  # the cap bounds a day


def test_bursty_mmpp_modulates_rate():
    """Burst multiplier up -> more arrivals on the same seed's envelope."""
    quiet = generate_scenario("bursty-mmpp", seed=5, burst_mult=1.0, quiet_mult=1.0)
    bursty = generate_scenario("bursty-mmpp", seed=5, burst_mult=4.0, quiet_mult=1.0)
    assert len(bursty) > len(quiet)


def test_scenarios_drive_the_simulator():
    """Every scenario must be runnable end-to-end (the 'usable by the
    simulator' half of the registry contract)."""
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import MIGSimulator, StaticPolicy

    for name in sorted(EXPECTED):
        jobs = generate_scenario(name, seed=2, horizon_min=180.0)
        sim = MIGSimulator(make_scheduler("EDF-SS"))
        res = sim.run(jobs, policy=StaticPolicy(3))
        assert res.num_jobs == len(jobs)
        assert res.energy_wh >= 0.0
