"""Benchmark: batched-backend throughput in oracle events/sec-equivalent.

The two backends do different amounts of work per unit of simulated time —
the oracle processes discrete events, the batched backend fixed grid steps
— so raw "steps/sec" comparisons are meaningless.  The common currency is
*events/sec-equivalent*: how many oracle events the batched backend retires
per wall-second, i.e.

    ev_eq/s = (mean oracle events per rollout) * batch / batched wall time

measured on the *same workload*.  Dividing by the oracle's own events/sec
on that workload gives the wall-clock speedup ratio the two-backend
contract gates on (docs/BATCHED_SIM.md §6): the oracle's per-event cost
grows with queue depth (O(queue) scheduler passes) while the batched
per-step cost is load-flat, so the ratio rises with ``load_scale`` — the
curve below measures exactly that, and the headline is its best point.

::

    PYTHONPATH=src python scripts/bench_batched.py               # full curve
    PYTHONPATH=src python scripts/bench_batched.py --quick       # CI smoke
    PYTHONPATH=src python scripts/bench_batched.py --min-ratio 20
    PYTHONPATH=src python scripts/bench_batched.py --write-agreement

Writes ``artifacts/bench/batched_events.json`` (collected into the
BENCH_nightly.json trajectory by ``scripts/bench_nightly.py``);
``--write-agreement`` additionally refreshes the checked-in agreement
baseline ``benchmarks/baselines/batched_agreement.json`` that
``scripts/render_experiments.py`` renders into EXPERIMENTS.md.

``--min-ratio`` is the CI/nightly gate: machine-portable (both backends
run on the same box) where an absolute ev_eq/s floor is not.  The floor is
set far below the measured headline — it catches structural regressions
(a reintroduced per-step sort, a broken scatter merge), not timer noise.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join("artifacts", "bench", "batched_events.json")
AGREEMENT_OUT = os.path.join("benchmarks", "baselines", "batched_agreement.json")

#: the measured curve: heavier load -> deeper queues -> slower oracle, while
#: the batched per-step cost stays flat.  Batch sizes keep each point a few
#: seconds of wall time; oracle seeds shrink as its per-rollout cost explodes
#: (35 s/rollout at load 12) — the reference only needs a stable mean.
FULL_POINTS = (
    {"load_scale": 1.0, "batch": 64, "oracle_seeds": 3},
    {"load_scale": 4.0, "batch": 32, "oracle_seeds": 2},
    {"load_scale": 8.0, "batch": 16, "oracle_seeds": 1},
    {"load_scale": 12.0, "batch": 16, "oracle_seeds": 1},
)
QUICK_POINTS = ({"load_scale": 2.0, "batch": 8, "oracle_seeds": 2},)


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def measure_point(
    load_scale: float,
    batch: int,
    oracle_seeds: int,
    dt_min: float = 0.5,
    scenario: str = "paper-diurnal",
) -> dict:
    """One curve point: oracle reference + batched run + agreement check.

    The oracle reference replays seeds ``0..oracle_seeds-1``; the batched run
    covers seeds ``0..batch-1``, so the reference seeds are a prefix and the
    per-seed agreement columns compare identical job streams.
    """
    from repro.core.batched import BatchedJobs, build_tables, compile_policy, simulate_batch
    from repro.core.engine import SimulationEngine
    from repro.core.scenarios import generate_scenario
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import DayNightPolicy, MIGSimulator

    def day(seed):
        return generate_scenario(scenario, seed=seed, load_scale=load_scale)

    # --- oracle reference (fresh jobs per run: jobs carry mutable state)
    events = 0
    oracle_results = []
    t0 = time.perf_counter()
    for s in range(oracle_seeds):
        sim = MIGSimulator(make_scheduler("EDF-FS"))
        engine = SimulationEngine(sim, policy=DayNightPolicy(), jobs=day(s))
        engine.drain()
        oracle_results.append(engine.result())
        events += engine.events_processed
    oracle_wall = time.perf_counter() - t0
    oracle_eps = events / oracle_wall if oracle_wall > 0 else float("inf")
    ev_per_rollout = events / oracle_seeds

    # --- batched run over the same scenario, seeds 0..batch-1
    tables = build_tables()
    jobs = BatchedJobs.from_job_lists(
        [day(s) for s in range(batch)], max_slots=tables.max_slots
    )
    policy = compile_policy(DayNightPolicy(), tables, batch)
    # warm-up: one chunk compiles the scan for these shapes, so the timed
    # run below measures steady-state throughput, not XLA compile time
    from repro.core.batched import DEFAULT_CHUNK_STEPS
    from repro.core.batched.backend import device_constants, init_state, run_steps

    run_steps(
        init_state(jobs, policy.initial), jobs, policy,
        device_constants(tables, "partial"),
        t0_min=0.0, n_steps=DEFAULT_CHUNK_STEPS, dt_min=dt_min,
    )
    t0 = time.perf_counter()
    res = simulate_batch(jobs, policy, tables=tables, dt_min=dt_min)
    batched_wall = time.perf_counter() - t0
    ev_eq = ev_per_rollout * batch / batched_wall if batched_wall > 0 else float("inf")

    # --- agreement on the shared seed prefix (render_experiments renders it)
    b_results = res.to_sim_results()
    agree_rows = []
    for s, o in enumerate(oracle_results):
        b = b_results[s]
        agree_rows.append(
            {
                "seed": s,
                "energy_rel": abs(b.energy_wh - o.energy_wh) / max(o.energy_wh, 1e-9),
                "tardiness_abs": abs(b.avg_tardiness - o.avg_tardiness),
                "tardiness_rel": abs(b.avg_tardiness - o.avg_tardiness)
                / max(o.avg_tardiness, 0.25),
                "repartitions_oracle": o.repartitions,
                "repartitions_batched": b.repartitions,
                "busy_rel": abs(b.busy_slot_minutes - o.busy_slot_minutes)
                / max(o.busy_slot_minutes, 1e-9),
            }
        )
    agreement = {
        "seeds": oracle_seeds,
        "energy_rel_max": max(r["energy_rel"] for r in agree_rows),
        "tardiness_abs_max": max(r["tardiness_abs"] for r in agree_rows),
        "tardiness_rel_max": max(r["tardiness_rel"] for r in agree_rows),
        "busy_rel_max": max(r["busy_rel"] for r in agree_rows),
        "repartitions_exact": all(
            r["repartitions_oracle"] == r["repartitions_batched"] for r in agree_rows
        ),
        "rows": agree_rows,
    }
    return {
        "load_scale": load_scale,
        "batch": batch,
        "padded_jobs": jobs.padded_jobs,
        "oracle_seeds": oracle_seeds,
        "oracle_events_per_rollout": round(ev_per_rollout, 1),
        "oracle_seconds_per_rollout": round(oracle_wall / oracle_seeds, 4),
        "oracle_events_per_sec": round(oracle_eps, 1),
        "batched_seconds": round(batched_wall, 4),
        "batched_seconds_per_rollout": round(batched_wall / batch, 4),
        "events_equiv_per_sec": round(ev_eq, 1),
        "ratio_vs_oracle": round(ev_eq / oracle_eps, 2),
        "agreement": agreement,
    }


def measure(points, dt_min: float = 0.5, scenario: str = "paper-diurnal",
            verbose: bool = True) -> dict:
    """The full curve; the headline is the best-ratio point."""
    from repro.core.simulator import SIM_VERSION

    measured = []
    for p in points:
        m = measure_point(dt_min=dt_min, scenario=scenario, **p)
        if verbose:
            print(
                f"load {m['load_scale']:>4}: oracle "
                f"{m['oracle_events_per_sec']:>8.0f} ev/s, batched "
                f"{m['events_equiv_per_sec']:>8.0f} ev_eq/s "
                f"({m['ratio_vs_oracle']:.1f}x)",
                file=sys.stderr,
            )
        measured.append(m)
    head = max(measured, key=lambda m: m["ratio_vs_oracle"])
    return {
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "sim_version": SIM_VERSION,
        "scenario": scenario,
        "policy": "daynight",
        "dt_min": dt_min,
        "points": measured,
        "headline_load_scale": head["load_scale"],
        "events_equiv_per_sec": head["events_equiv_per_sec"],
        "ratio_vs_oracle": head["ratio_vs_oracle"],
    }


def write_agreement(entry: dict, path: str = AGREEMENT_OUT) -> None:
    """The checked-in agreement/speedup baseline EXPERIMENTS.md renders."""
    payload = {
        k: entry[k]
        for k in (
            "date", "git_sha", "sim_version", "scenario", "policy", "dt_min",
            "points", "headline_load_scale", "events_equiv_per_sec",
            "ratio_vs_oracle",
        )
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--dt-min", type=float, default=0.5)
    ap.add_argument("--quick", action="store_true",
                    help="one small point (CI smoke) instead of the curve")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail (exit 1) when the headline speedup vs the "
                         "oracle falls below this — the nightly gate")
    ap.add_argument("--min-events-equiv-per-sec", type=float, default=None,
                    help="absolute ev_eq/s floor (machine-specific)")
    ap.add_argument("--write-agreement", action="store_true",
                    help=f"also refresh {AGREEMENT_OUT}")
    ap.add_argument("--dry-run", action="store_true", help="print, don't write")
    args = ap.parse_args(argv)

    points = QUICK_POINTS if args.quick else FULL_POINTS
    entry = measure(points, dt_min=args.dt_min)
    print(json.dumps(entry, indent=2))
    if not args.dry_run:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(entry, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)
        if args.write_agreement:
            write_agreement(entry)

    failures = []
    if args.min_ratio is not None and entry["ratio_vs_oracle"] < args.min_ratio:
        failures.append(
            f"BATCHED SPEEDUP REGRESSION: {entry['ratio_vs_oracle']:.1f}x "
            f"< floor {args.min_ratio:.1f}x"
        )
    if (
        args.min_events_equiv_per_sec is not None
        and entry["events_equiv_per_sec"] < args.min_events_equiv_per_sec
    ):
        failures.append(
            f"BATCHED THROUGHPUT REGRESSION: "
            f"{entry['events_equiv_per_sec']:.0f} ev_eq/s < floor "
            f"{args.min_events_equiv_per_sec:.0f} ev_eq/s"
        )
    for msg in failures:
        print(msg, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
