"""Train the batched DQN and check in the RL-vs-forecast baseline.

ROADMAP item 4's gating rule: the batch-trained policy must beat the
predictive forecast controller on at least one scenario family before the
RL track counts as ahead of the hand-built policies.  This script is that
gate's producer and its re-checker:

::

    PYTHONPATH=src python scripts/train_rl_baseline.py           # retrain + eval + write
    PYTHONPATH=src python scripts/train_rl_baseline.py --check   # re-eval checked-in params
    PYTHONPATH=src python scripts/train_rl_baseline.py --scale 0.1

Training runs the fused on-device trainer (repro.core.rl.batched_train)
with fixed seeds over a scenario × load-scale randomized episode stream;
the greedy policy is then evaluated on its 15-min training cadence against
the forecast controller over every registered scenario family (same seeds
→ identical job streams per family) at the standard ``--scale 0.1``
sizing, and the summary lands in ``benchmarks/baselines/rl_batched.json``
next to the params (``rl_dqn_params.npz``).  The DQN side evaluates
through an ad-hoc factory (inline, uncached) so a retrain can never be
served stale memoized cells recorded under the same params path.

``--check`` skips training and re-evaluates the *checked-in* params: the
nightly workflow runs it so a simulator or forecast change that erases
the recorded win fails loudly instead of letting the baseline rot.  CI
gates the cheap half (tests/test_batched_train.py pins the params file
against recorded greedy actions and asserts the baseline's claim).
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PARAMS_OUT = os.path.join(REPO_ROOT, "benchmarks", "baselines", "rl_dqn_params.npz")
BASELINE_OUT = os.path.join(REPO_ROOT, "benchmarks", "baselines", "rl_batched.json")

#: evaluation cadence = the batched trainer's decision cadence
DECISION_INTERVAL_MIN = 15.0

#: scenario families the trained policy is raced on (fixed order, as in
#: the sweep grids); training draws episodes from the same families so
#: the policy sees every arrival shape it is evaluated under
TRAIN_SCENARIOS = (
    "paper-diurnal",
    "bursty-mmpp",
    "heavy-tail-lognormal",
    "heavy-tail-pareto",
)

TRAIN_SEED = 7
TRAIN_EPISODES = 2048
EVAL_SEED = 90_000


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _dqn_config():
    from repro.core.rl.dqn import DQNConfig
    from repro.core.rl.env import FEATURE_DIM

    return DQNConfig(
        state_dim=FEATURE_DIM,
        n_step=8,
        lr=3e-4,
        target_sync_every=2000,
        min_buffer=2000,
        eps_decay_steps=100_000,
        seed=TRAIN_SEED,
    )


def train(episodes: int = TRAIN_EPISODES, verbose: bool = True):
    """Fixed-seed batched training over the scenario × load-scale mix."""
    from repro.core.rl.batched_train import BatchedTrainConfig, train_dqn_batched

    tcfg = BatchedTrainConfig(
        batch=64,
        scenarios=TRAIN_SCENARIOS,
        load_scale_range=(0.8, 1.2),
        decision_interval_min=DECISION_INTERVAL_MIN,
        horizon_decisions=104,
    )
    learner, stats = train_dqn_batched(
        num_episodes=episodes,
        dqn_config=_dqn_config(),
        train_config=tcfg,
        seed=TRAIN_SEED,
        verbose=verbose,
    )
    return learner, stats


def evaluate(params_path: str, scale: float = 0.1, workers: int = 0) -> list:
    """Race the saved policy against the forecast controller per family.

    Same seeds on both sides → identical job streams; the DQN runs
    uncached (ad-hoc factory) so the results always reflect the params
    file on disk, the forecast side goes through the registered (cached,
    deterministic) sweep policy.
    """
    from repro.core.metrics import et_table
    from repro.core.rl import DQNLearner, evaluate_policy, greedy_policy
    from repro.sweep.grids import SCENARIO_ORDER, _iters

    learner = DQNLearner(_dqn_config())
    learner.load(params_path)
    iters = _iters(40, scale, floor=4)
    rows = []
    for sname in SCENARIO_ORDER:
        common = dict(
            num_iterations=iters,
            scheduler_name="EDF-SS",
            seed=EVAL_SEED,
            scenario=sname,
        )
        per = {
            "DQN": evaluate_policy(
                lambda: greedy_policy(
                    learner, decision_interval_min=DECISION_INTERVAL_MIN
                ),
                **common,
            ),
            "Forecast": evaluate_policy(
                ("forecast", {"scenario": sname}), workers=workers, **common
            ),
        }
        t, a = et_table(per)
        rows.append(
            {
                "scenario": sname,
                "et_a": a,
                "ET_DQN": round(t["DQN"], 4),
                "ET_Forecast": round(t["Forecast"], 4),
                "dqn_beats_forecast": bool(t["DQN"] < t["Forecast"]),
                "repartitions_DQN": round(
                    sum(r.repartitions for r in per["DQN"]) / iters, 1
                ),
                "energy_wh_DQN": round(
                    sum(r.energy_wh for r in per["DQN"]) / iters, 1
                ),
                "iterations": iters,
            }
        )
        print(
            f"{sname:22s} ET DQN={t['DQN']:8.4f}  Forecast={t['Forecast']:8.4f}"
            f"  {'WIN' if t['DQN'] < t['Forecast'] else ''}",
            file=sys.stderr,
        )
    return rows


def _params_probe(params_path: str, seed: int = 123, n: int = 16) -> dict:
    """Greedy actions on a fixed pseudo-random observation batch.

    A cheap determinism pin for CI: tests/test_batched_train.py recomputes
    the probe from the checked-in params and compares — a silently
    corrupted or stale params file fails there without re-running a single
    simulated day.
    """
    import numpy as np
    from repro.core.rl import DQNLearner
    from repro.core.rl.env import FEATURE_DIM

    learner = DQNLearner(_dqn_config())
    learner.load(params_path)
    rng = np.random.default_rng(seed)
    obs = rng.uniform(0.0, 1.0, size=(n, FEATURE_DIM))
    return {
        "seed": seed,
        "actions": [
            int(learner.greedy_action(o.astype(np.float32))) for o in obs
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=0.1,
                    help="evaluation sizing, as in the sweep grids")
    ap.add_argument("--episodes", type=int, default=TRAIN_EPISODES)
    ap.add_argument("--check", action="store_true",
                    help="skip training: re-evaluate the checked-in params "
                         "and gate on the recorded win still holding")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--params", default=PARAMS_OUT)
    ap.add_argument("--out", default=BASELINE_OUT)
    args = ap.parse_args(argv)

    if not args.check:
        t0 = time.time()
        learner, stats = train(args.episodes)
        print(
            f"trained {stats.episodes} episodes / {stats.env_steps} env steps "
            f"in {stats.wall_seconds:.1f}s ({stats.env_steps_per_sec:.0f}/s), "
            f"{stats.updates} updates, final eps {stats.final_epsilon:.3f}",
            file=sys.stderr,
        )
        os.makedirs(os.path.dirname(args.params), exist_ok=True)
        learner.save(args.params)
        print(f"wrote {args.params} ({time.time() - t0:.1f}s)", file=sys.stderr)
    elif not os.path.exists(args.params):
        print(f"--check: no params at {args.params}", file=sys.stderr)
        return 1

    rows = evaluate(args.params, scale=args.scale, workers=args.workers)
    wins = [r["scenario"] for r in rows if r["dqn_beats_forecast"]]
    probe = _params_probe(args.params)
    entry = {
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "scale": args.scale,
        "train": {
            "backend": "batched",
            "episodes": args.episodes,
            "seed": TRAIN_SEED,
            "scenarios": list(TRAIN_SCENARIOS),
            "load_scale_range": [0.8, 1.2],
            "decision_interval_min": DECISION_INTERVAL_MIN,
        },
        "eval_seed": EVAL_SEED,
        "rows": rows,
        "families_beaten": wins,
        "params_probe": probe,
    }
    if args.check:
        print(json.dumps(entry, indent=2))
    else:
        from repro.core.simulator import SIM_VERSION

        entry["sim_version"] = SIM_VERSION
        with open(args.out, "w") as f:
            json.dump(entry, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)
    if not wins:
        print(
            "RL BASELINE GATE: batch-trained policy beats the forecast "
            "controller on 0 scenario families (need >=1)",
            file=sys.stderr,
        )
        return 1
    print(f"beats forecast on: {', '.join(wins)}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
