"""Render, refresh, and check the repo's experiments book (EXPERIMENTS.md).

Usage::

    PYTHONPATH=src python scripts/render_experiments.py            # stdout
    PYTHONPATH=src python scripts/render_experiments.py --write    # refresh EXPERIMENTS.md
    PYTHONPATH=src python scripts/render_experiments.py --check    # CI gate

Sections and their deterministic inputs:

* **§Calibration** — the queue-depth analysis behind ``M_JOBS = 8``
  (``repro.core.rl.env``): re-simulated on the spot from pinned seeds.
* **§Dry-run / §Roofline** — rendered from ``artifacts/dryrun`` records
  when present, ``pending`` rows otherwise (artifacts are not checked in,
  so a fresh checkout renders the same ``pending`` state CI sees).
* **§Perf** — pointers to the benchmark entry points and the nightly
  trajectory.
* **§Batched-backend** — agreement table and speedup curve from the
  checked-in ``benchmarks/baselines/batched_agreement.json``
  (``pending`` when absent).
* **§Sweeps** — the grid registry (``repro.sweep.grids``) mapped to paper
  tables/figures and checked-in baselines.
* **§Predictive-controller** — aggregated from the checked-in
  ``benchmarks/baselines/repartition_policies.jsonl``.
* **§RL-baseline** — the batch-trained DQN raced against the forecast
  controller, from the checked-in ``benchmarks/baselines/rl_batched.json``
  (produced by ``scripts/train_rl_baseline.py``).

``--check`` fails (exit 1) when the checked-in EXPERIMENTS.md differs from
a fresh render, or when any ``*.md`` referenced from ``src/`` does not
exist — the docs gate wired into CI.
"""

from __future__ import annotations

import argparse
import io
import json
import os
import re
import sys
from typing import Dict, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
EXPERIMENTS_PATH = os.path.join(REPO_ROOT, "EXPERIMENTS.md")
POLICY_BASELINE = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "repartition_policies.jsonl"
)

HEADER = """\
# EXPERIMENTS

The experiments book: calibration analyses, dry-run/roofline tables, the
sweep-grid map, and predictive-controller results.  **Generated** by
`scripts/render_experiments.py` — edit the generator, then refresh with

```bash
PYTHONPATH=src python scripts/render_experiments.py --write
```

CI runs `--check` and fails when this file is stale or a `*.md` reference
in `src/` points at a missing document.
"""


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    if x is None:
        return "-"
    return f"{x/2**30:.2f}"


# ----------------------------------------------------------------------
# §Calibration — the m=8 queue-depth analysis


def calibration_md() -> str:
    """Queue-depth distribution under the paper's settings (pinned seeds).

    The paper picks the DQN state depth m = 3 from Alibaba-trace load
    analysis (§IV-D-1); our §V-A calibration produces deeper peak queues,
    and this table is the analysis that selects ``M_JOBS`` instead.
    """
    from repro.core.engine import SimulationEngine
    from repro.core.rl.env import M_JOBS
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import MIGSimulator, StaticPolicy
    from repro.core.workload import WorkloadSpec, generate_jobs

    seeds = (0, 1, 2)
    configs = (3, 6, 12)

    def stats(xs: List[int]) -> Dict[str, float]:
        xs = sorted(xs)
        n = len(xs)

        def pct(p: float) -> int:
            return xs[min(int(p * n), n - 1)] if n else 0

        return {
            "mean": sum(xs) / max(n, 1),
            "p50": pct(0.50),
            "p90": pct(0.90),
            "p99": pct(0.99),
            "max": xs[-1] if xs else 0,
        }

    out = io.StringIO()
    out.write("## Calibration\n\n")
    out.write(
        "Waiting-queue depth at decision events (EDF-SS, paper-diurnal "
        f"seeds {list(seeds)}, static configurations across the coarseness "
        "spectrum) — the load analysis that sets the DQN state depth "
        f"`M_JOBS = {M_JOBS}` in `repro.core.rl.env` (the paper derived "
        "m = 3 from Alibaba-trace load analysis, §IV-D-1):\n\n"
    )
    out.write("| config | mean | p50 | p90 | p99 | max |\n")
    out.write("|---|---|---|---|---|---|\n")
    deepest = 0
    for cfg in configs:
        depths: List[int] = []

        def hook(t, sim):
            depths.append(len(sim.queue_snapshot()))

        for seed in seeds:
            sim = MIGSimulator(make_scheduler("EDF-SS"))
            SimulationEngine(
                sim, policy=StaticPolicy(cfg),
                jobs=generate_jobs(WorkloadSpec(), seed), decision_hook=hook,
            ).drain()
        s = stats(depths)
        deepest = max(deepest, int(s["max"]))
        out.write(
            f"| {cfg} | {s['mean']:.2f} | {s['p50']} | {s['p90']} | "
            f"{s['p99']} | {s['max']} |\n"
        )
    out.write(
        f"\nm = {M_JOBS} keeps the deepest queue observed anywhere in the "
        f"configuration spectrum (max {deepest}) fully visible with "
        "headroom for heavier scenarios, while the paper's m = 3 would "
        "truncate even the p99 tail of every configuration under our §V-A "
        "calibration.  The 2+2m layout itself is unchanged from the paper.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Dry-run / §Roofline — from artifacts/dryrun records


def dryrun_md() -> str:
    from repro.analysis.roofline import load_record
    from repro.launch.shapes import all_cells

    out = io.StringIO()
    out.write("## Dry-run — compile status and per-device memory\n\n")
    out.write(
        "Rendered from `artifacts/dryrun/` records (`python -m "
        "repro.launch.dryrun`); rows are `…` until the artifacts exist.\n\n"
    )
    out.write("| arch | shape | pod 16x16 | multi-pod 2x16x16 | args GiB/dev | temp GiB/dev | compile s |\n")
    out.write("|---|---|---|---|---|---|---|\n")
    n_ok = n_skip = n_fail = 0
    for arch, shape in all_cells():
        pod = load_record(arch, shape.name, False)
        mp = load_record(arch, shape.name, True)

        def status(r):
            if r is None:
                return "…"
            if r.get("skipped"):
                return "skip"
            return "OK" if r.get("ok") else "FAIL"

        s_pod, s_mp = status(pod), status(mp)
        if s_pod == "OK":
            n_ok += 1
        elif s_pod == "skip":
            n_skip += 1
        elif s_pod == "FAIL":
            n_fail += 1
        args = temp = comp = None
        if pod and pod.get("ok") and not pod.get("skipped"):
            args = pod.get("argument_size_in_bytes")
            temp = pod.get("temp_size_in_bytes")
            comp = pod.get("compile_seconds")
        out.write(
            f"| {arch} | {shape.name} | {s_pod} | {s_mp} | {fmt_b(args)} | "
            f"{fmt_b(temp)} | {f'{comp:.0f}' if comp else '-'} |\n"
        )
    out.write(f"\npod cells: {n_ok} OK, {n_skip} skipped (DESIGN.md §4), {n_fail} failed.\n")
    return out.getvalue()


def roofline_md() -> str:
    from repro.analysis.roofline import roofline_row
    from repro.launch.shapes import all_cells

    out = io.StringIO()
    out.write("## Roofline — per (arch x shape), single pod (256 chips)\n\n")
    out.write("| arch | shape | t_comp | t_mem | t_coll | dominant | MODEL/HLO | roofline frac | note |\n")
    out.write("|---|---|---|---|---|---|---|---|---|\n")
    for arch, shape in all_cells():
        row = roofline_row(arch, shape.name)
        if row is None:
            out.write(f"| {arch} | {shape.name} | … | | | | | | pending |\n")
            continue
        if row.get("skipped"):
            out.write(f"| {arch} | {shape.name} | skip | | | | | | {row.get('reason','')} |\n")
            continue
        if row.get("failed"):
            out.write(f"| {arch} | {shape.name} | FAIL | | | | | | |\n")
            continue
        note = _note(row)
        out.write(
            f"| {arch} | {shape.name} | {fmt_s(row['t_compute_s'])} | "
            f"{fmt_s(row['t_memory_s'])} | {fmt_s(row['t_collective_s'])} | "
            f"{row['dominant']} | {row['useful_ratio']:.2f} | "
            f"{row['roofline_fraction']:.2%} | {note} |\n"
        )
    return out.getvalue()


def _note(row) -> str:
    d = row["dominant"]
    if d == "compute":
        if (row["useful_ratio"] or 1) < 0.6:
            return "cut non-useful FLOPs (remat/attention waste)"
        return "near compute roof; fuse/overlap collectives"
    if d == "memory":
        return "raise arithmetic intensity (bigger tiles, bf16 temps, fuse)"
    return "reshard to shrink collective payload / overlap with compute"


# ----------------------------------------------------------------------
# §Perf


def perf_md() -> str:
    return (
        "## Perf\n\n"
        "Kernel and end-to-end performance entry points (numbers live in\n"
        "artifacts and the nightly trajectory, not in this file):\n\n"
        "* `python -m benchmarks.kernels_bench` — Pallas kernels vs reference\n"
        "  einsum paths (flash attention, Mamba scan, mLSTM, MoE grouped\n"
        "  matmul); collective overlap notes live in\n"
        "  `repro/models/transformer.py`.\n"
        "* `python -m benchmarks.run --scale 4 --workers 8` — the paper-table\n"
        "  battery through the sweep engine (the reference EXPERIMENTS\n"
        "  battery used `--scale 4`).\n"
        "* `python scripts/bench_engine.py` — SimulationEngine events/sec\n"
        "  micro-benchmark (paper-diurnal, `--load-scale 0.1`); CI gates a\n"
        "  conservative floor, nightly folds the record into the trajectory.\n"
        "* `python scripts/bench_batched.py` — batched-backend speedup\n"
        "  curve vs the oracle (events/sec-equivalent; §Batched-backend\n"
        "  below renders the checked-in record); `bench_engine.py\n"
        "  --backend batched` delegates here.\n"
        "* `BENCH_nightly.json` — per-grid wall-clock / cache-hit / engine\n"
        "  events/sec trajectory appended by `scripts/bench_nightly.py` from\n"
        "  the nightly workflow.\n"
        "* DQN reference trainings use 900+ episodes\n"
        "  (`examples/dynamic_repartitioning_day.py`); short trainings\n"
        "  underperform the heuristic baseline.\n"
    )


# ----------------------------------------------------------------------
# §Batched-backend — agreement + speedup from the checked-in record

BATCHED_AGREEMENT = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "batched_agreement.json"
)


def batched_md() -> str:
    out = io.StringIO()
    out.write("## Batched-backend — oracle agreement and speedup curve\n\n")
    out.write(
        "`repro.core.batched` re-runs the same physics as fixed-timestep\n"
        "`vmap`/`lax.scan` rollouts (docs/BATCHED_SIM.md, DESIGN.md §8).\n"
        "The event engine stays the bit-exact oracle; the batched backend\n"
        "agrees within the docs/BATCHED_SIM.md §4 tolerances and its advantage\n"
        "grows with load, because the oracle's per-event cost is O(queue)\n"
        "while the scan's per-step cost is flat.\n\n"
    )
    if not os.path.exists(BATCHED_AGREEMENT):
        out.write(
            "*(record `batched_agreement.json` not yet generated — run\n"
            "`PYTHONPATH=src python scripts/bench_batched.py "
            "--write-agreement`)*\n"
        )
        return out.getvalue()

    with open(BATCHED_AGREEMENT, encoding="utf-8") as f:
        rec = json.load(f)

    out.write(
        f"Measured on `{rec['scenario']}` × `{rec['policy']}` at "
        f"`dt = {rec['dt_min']}` min (single-core CPU reference box, "
        "`scripts/bench_batched.py --write-agreement`):\n\n"
    )
    out.write(
        "| load | batch | oracle ev/s | batched ev_eq/s | ratio "
        "| energy rel | tardiness rel | repartitions |\n"
    )
    out.write("|---|---|---|---|---|---|---|---|\n")
    for p in rec["points"]:
        a = p["agreement"]
        ratio = f"**{p['ratio_vs_oracle']:.1f}x**" if (
            p["load_scale"] == rec["headline_load_scale"]
        ) else f"{p['ratio_vs_oracle']:.1f}x"
        out.write(
            f"| {p['load_scale']:g} | {p['batch']} "
            f"| {p['oracle_events_per_sec']:,.0f} "
            f"| {p['events_equiv_per_sec']:,.0f} | {ratio} "
            f"| {a['energy_rel_max']:.2%} | {a['tardiness_rel_max']:.2%} "
            f"| {'exact' if a['repartitions_exact'] else 'MISMATCH'} |\n"
        )
    light = min(rec["points"], key=lambda p: p["load_scale"])
    out.write(
        "\n(`tardiness rel` divides by `max(oracle, 0.25 min)`; the "
        f"{light['agreement']['tardiness_rel_max']:.0%} at load "
        f"{light['load_scale']:g} is a floor artifact — the absolute error "
        f"there is {light['agreement']['tardiness_abs_max']:.2f} min.)\n"
    )
    out.write(
        f"\nHeadline: **{rec['ratio_vs_oracle']:.1f}x** the oracle's\n"
        f"events/sec at `load_scale = {rec['headline_load_scale']:g}`\n"
        "(the paper's overload regime), with repartition counts exact at\n"
        "every point — the speedup is not bought with accuracy.  The ratio\n"
        "crosses 1x near `load_scale ≈ 1.2`; below that the oracle wins\n"
        "and should be used.  The gate CI tracks is the *ratio* (both\n"
        "backends on the same box), never absolute events/sec\n"
        "(`scripts/bench_nightly.py --gate-batched-ratio`).  Regenerate\n"
        "with the CONTRIBUTING.md \"Batched-backend tolerances\" recipe.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Sweeps — grid registry -> paper anchors -> baselines

GRID_ANCHORS = {
    "table2_schedulers": "Table II",
    "fig4_preemption": "Fig. 4",
    "fig6_utilization": "Fig. 6",
    "fig7_fig8_arrival": "Figs. 7-8",
    "fig9_fig10_split": "Figs. 9-10",
    "table3_repartitioning": "Table III",
    "fig11_preferences": "Fig. 11",
    "fleet_scaling": "beyond-paper (fleet)",
    "dispatchers": "beyond-paper (online vs fluid dispatch)",
    "scenario_matrix": "beyond-paper (scenarios)",
    "repartition_policies": "beyond-paper (§V-C conjecture)",
    "repartition_modes": "beyond-paper (partial vs full-drain reconfiguration)",
    "serving_matrix": "beyond-paper (multi-tenant SLO serving, DESIGN.md §9)",
    "smoke": "CI smoke (Table II subset)",
}


def sweeps_md() -> str:
    from repro.sweep.grids import GRIDS

    out = io.StringIO()
    out.write("## Sweeps — grid → paper table/figure map\n\n")
    out.write(
        "Run any grid with `python -m repro.sweep <grid> --workers 4`; CI\n"
        "gates the baselined grids at `--scale 0.1` (see CONTRIBUTING.md for\n"
        "the regeneration recipe after a `SIM_VERSION` bump).\n\n"
    )
    out.write("| grid | reproduces | baseline | description |\n")
    out.write("|---|---|---|---|\n")
    for name in sorted(GRIDS):
        grid = GRIDS[name]
        baseline = ""
        for candidate in (f"{name}.jsonl", "smoke_sweep.jsonl" if name == "smoke" else ""):
            if candidate and os.path.exists(
                os.path.join(REPO_ROOT, "benchmarks", "baselines", candidate)
            ):
                baseline = f"`benchmarks/baselines/{candidate}`"
                break
        out.write(
            f"| `{name}` | {GRID_ANCHORS.get(name, '')} | {baseline} | {grid.doc} |\n"
        )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Dispatchers — online (real-state) vs fluid (estimate) routing

DISPATCHERS_BASELINE = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "dispatchers.jsonl"
)


def _baseline_rows(path: str, grid_name: str):
    """Aggregate a checked-in baseline JSONL through its grid definition."""
    from repro.sweep.grids import GRIDS

    cells, results = [], []
    with open(path) as f:
        for line in f:
            if line.strip():
                rec = json.loads(line)
                cells.append(rec["cell"])
                results.append(rec["result"])
    return GRIDS[grid_name].aggregate(cells, results)


def dispatchers_md() -> str:
    out = io.StringIO()
    out.write("## Dispatchers — what real dispatch-time state is worth\n\n")
    out.write(
        "Fleet dispatch is *online* since `mig-sim-3`: per-device\n"
        "simulation engines are co-advanced to every arrival and the\n"
        "dispatcher observes real queue/partition/repartition state through\n"
        "engine snapshots (`repro.fleet`, DESIGN.md §6).  The previous\n"
        "two-phase *fluid* pre-split (a backlog estimate draining at peak\n"
        "slot rate) is kept as `dispatch_info=\"fluid\"`, and the\n"
        "`dispatchers` grid races both modes so the information gap is a\n"
        "reported number.  `state-aware` routes on signals the fluid model\n"
        "cannot produce (in-flight repartitions, free slices) and therefore\n"
        "has no fluid row.\n\n"
    )
    if not os.path.exists(DISPATCHERS_BASELINE):
        out.write("*(baseline `dispatchers.jsonl` not yet generated)*\n")
        return out.getvalue()

    rows = _baseline_rows(DISPATCHERS_BASELINE, "dispatchers")

    out.write(
        "ET per fleet × dispatcher × dispatch mode (shared per-fleet\n"
        "scaling factor `a`; lower is better) from the checked-in\n"
        "`--scale 0.1` baseline:\n\n"
    )
    out.write("| fleet | dispatcher | ET online | ET fluid | online gain |\n")
    out.write("|---|---|---|---|---|\n")
    for row in rows:
        fluid = f"{row['ET_fluid']:.4f}" if row["ET_fluid"] is not None else "—"
        gain = (
            f"{row['online_gain_pct']:+.2f}%"
            if row["online_gain_pct"] is not None
            else "—"
        )
        out.write(
            f"| {row['fleet']} | {row['dispatcher']} | {row['ET_online']:.4f} "
            f"| {fluid} | {gain} |\n"
        )
    out.write(
        "\nRound-robin ignores state, so its gap is identically zero — a\n"
        "built-in control that the two modes share physics.  Where the gap\n"
        "is non-zero the two information models genuinely route\n"
        "differently; the sign varies by fleet shape because the fluid\n"
        "estimate's peak-rate drain flatters small devices (it dispatches\n"
        "as if an A30 drained like an A100, which sometimes luckily\n"
        "load-balances).  Regenerate with `python -m repro.sweep\n"
        "dispatchers --scale 0.1` and compare via `--check-baseline`.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Repartition-modes — partial vs full-drain reconfiguration

MODES_BASELINE = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "repartition_modes.jsonl"
)


def repartition_modes_md() -> str:
    out = io.StringIO()
    out.write("## Repartition-modes — what partial reconfiguration is worth\n\n")
    out.write(
        "Since `mig-sim-4` partitions are *slot-placed* (NVIDIA placement\n"
        "grid, DESIGN.md §7) and repartitioning is *partial* by default:\n"
        "only the slice instances that differ between the old and new\n"
        "layout are destroyed/created, jobs on surviving instances run\n"
        "through the 4 s stall, and the stall is charged against the\n"
        "affected slots only.  The legacy full-drain model — every running\n"
        "job preempted, the whole GPU blocked — is kept as\n"
        "`repartition_mode=\"drain\"` and reproduces pre-`mig-sim-4`\n"
        "numbers bit-identically.  The `repartition_modes` grid races both\n"
        "models for every repartitioning policy family × scenario on\n"
        "identical job streams.\n\n"
    )
    if not os.path.exists(MODES_BASELINE):
        out.write("*(baseline `repartition_modes.jsonl` not yet generated)*\n")
        return out.getvalue()

    rows = _baseline_rows(MODES_BASELINE, "repartition_modes")

    out.write(
        "ET and preemptions per scenario × family × transition model\n"
        "(shared per-scenario ET scale factor `a`; lower is better) from\n"
        "the checked-in `--scale 0.1` baseline:\n\n"
    )
    out.write(
        "| scenario | family | ET drain | ET partial | preempt drain "
        "| preempt partial | repart drain | repart partial |\n"
    )
    out.write("|---|---|---|---|---|---|---|---|\n")
    for row in rows:
        out.write(
            f"| {row['scenario']} | {row['family']} | {row['ET_drain']:.4f} "
            f"| {row['ET_partial']:.4f} | {row['preemptions_drain']:.1f} "
            f"| {row['preemptions_partial']:.1f} "
            f"| {row['repartitions_drain']:.1f} "
            f"| {row['repartitions_partial']:.1f} |\n"
        )
    # narrative keyed off the families actually present in the baseline —
    # the list is owned by grids.REPARTITION_MODE_FAMILIES and may change
    paper = {
        r["family"]: r for r in rows if r["scenario"] == "paper-diurnal"
    }
    fc, hr = paper.get("Forecast"), paper.get("Heuristic")
    if fc is None or hr is None:
        out.write(
            "\nRegenerate with `python -m repro.sweep repartition_modes "
            "--scale 0.1` and compare via `--check-baseline`.\n"
        )
        return out.getvalue()
    out.write(
        "\nThe reactive heuristic is the biggest beneficiary — it switches\n"
        "hundreds of times a day, and under partial transitions the jobs\n"
        "on surviving slices stop being collateral (paper-diurnal: "
        f"{hr['preemptions_drain']:.0f} → {hr['preemptions_partial']:.0f}\n"
        "preemptions).  The predictive controller prices the partial\n"
        "transition in its MPC lookahead (surviving capacity keeps serving\n"
        "through the stall, displaced work pays the requeue) and times\n"
        "switches opportunistically at displacement-free instants, cutting\n"
        f"preemptions {fc['preemptions_drain']:.1f} → "
        f"{fc['preemptions_partial']:.1f} at equal-or-better ET\n"
        f"({fc['ET_drain']:.4f} → {fc['ET_partial']:.4f}) with fewer\n"
        "repartitions — the paper's §VI conjecture (cheap, frequent\n"
        "reconfiguration) moving in the predicted direction.  DayNightMIG\n"
        "switches twice a day at fixed clock times regardless of model, so\n"
        "its rows double as a drain/partial physics control.  Regenerate\n"
        "with `python -m repro.sweep repartition_modes --scale 0.1` and\n"
        "compare via `--check-baseline`.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Predictive-controller — from the checked-in baseline


def predictive_md() -> str:
    out = io.StringIO()
    out.write("## Predictive-controller results\n\n")
    out.write(
        "The paper closes observing that preferred configurations recur at\n"
        "specific times of day, \"suggesting a policy for predictive and\n"
        "automatic reconfiguration\" (§V-C).  `repro.forecast` implements\n"
        "that policy family: a Fourier day-model + EWMA bias forecaster\n"
        "driving a model-predictive controller that rolls a fluid/queueing\n"
        "approximation forward per candidate configuration (lateness priced\n"
        "from a pinned §V-A job sample, M/G/c stochastic-wait correction,\n"
        "duty-cycle-correct energy) and repartitions under asymmetric\n"
        "hysteresis.  Default candidate set: the Fig.-11 coarse family\n"
        "`(1, 2, 3)` — full GPU overnight (race-to-idle), 4g+3g shoulders,\n"
        "4g+2g+1g through the plateau.\n\n"
    )
    if not os.path.exists(POLICY_BASELINE):
        out.write("*(baseline `repartition_policies.jsonl` not yet generated)*\n")
        return out.getvalue()

    rows = _baseline_rows(POLICY_BASELINE, "repartition_policies")

    families = [
        k[len("ET_"):] for k in rows[0] if k.startswith("ET_")
    ]
    out.write(
        "ET per policy family × scenario (shared per-scenario scaling "
        "factor `a`; lower is better) from the checked-in `--scale 0.1` "
        "baseline:\n\n"
    )
    out.write("| scenario | " + " | ".join(families) + " | forecast beats static |\n")
    out.write("|---|" + "---|" * (len(families) + 1) + "\n")
    for row in rows:
        cells_md = " | ".join(f"{row['ET_' + f]:.4f}" for f in families)
        beats = "**yes**" if row["forecast_beats_static"] else "no"
        out.write(f"| {row['scenario']} | {cells_md} | {beats} |\n")
    paper_row = next(r for r in rows if r["scenario"] == "paper-diurnal")
    out.write(
        "\nOn the paper's own workload the predictive controller beats\n"
        "static partitioning on ET while repartitioning ~"
        f"{paper_row['repartitions_Forecast']:.0f}"
        f" times/day (vs ~{paper_row['repartitions_Heuristic']:.0f} for the\n"
        "reactive queue heuristic, which stays the envelope on most\n"
        "scenarios by exploiting instant reaction to\n"
        "every queue change).  The heavy-tail scenarios break the §V-A\n"
        "job-mix assumptions baked into the controller's lateness curves and\n"
        "stay static-equivalent — the open head-room the DQN (and a\n"
        "retrained lateness sample) can chase.  Regenerate with\n"
        "`python -m repro.sweep repartition_policies --scale 0.1` and\n"
        "compare via `--check-baseline`.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §RL-baseline — the batch-trained DQN vs the forecast controller

RL_BASELINE = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "rl_batched.json"
)


def rl_md() -> str:
    out = io.StringIO()
    out.write("## RL baseline — batch-trained DQN vs forecast\n\n")
    out.write(
        "The fused on-device trainer (`repro.core.rl.batched_train`,\n"
        "DESIGN.md §11) advances B rollouts *and* the DQN update inside one\n"
        "jitted scan — `scripts/bench_rl.py` measures ≥50× the host loop's\n"
        "env-steps/sec at the headline load of its curve, which is what\n"
        "makes the training budget below an interactive job instead of an\n"
        "overnight one.  `scripts/train_rl_baseline.py` trains with fixed\n"
        "seeds over a scenario × load-scale randomized episode stream and\n"
        "races the greedy policy (on its 15-min training cadence) against\n"
        "the predictive forecast controller, same seeds → identical job\n"
        "streams:\n\n"
    )
    if not os.path.exists(RL_BASELINE):
        out.write("*(baseline `rl_batched.json` not yet generated)*\n")
        return out.getvalue()
    with open(RL_BASELINE, encoding="utf-8") as f:
        entry = json.load(f)
    out.write("| scenario | ET DQN | ET Forecast | DQN beats forecast |\n")
    out.write("|---|---|---|---|\n")
    for row in entry["rows"]:
        beats = "**yes**" if row["dqn_beats_forecast"] else "no"
        out.write(
            f"| {row['scenario']} | {row['ET_DQN']:.4f} "
            f"| {row['ET_Forecast']:.4f} | {beats} |\n"
        )
    tr = entry["train"]
    wins = ", ".join(f"`{w}`" for w in entry["families_beaten"]) or "none"
    out.write(
        f"\nTrained {tr['episodes']} episodes (batch {tr.get('batch', 64)},"
        f" seed {tr['seed']}) over {len(tr['scenarios'])} scenario"
        f" families at load scales {tr['load_scale_range']}; the ROADMAP\n"
        f"item-4 gating rule — beat the forecast controller on ≥1 scenario\n"
        f"family — holds on: {wins}.  CI pins the params probe and this\n"
        "file's claim (tests/test_batched_train.py); nightly re-evaluates\n"
        "the checked-in params (`train_rl_baseline.py --check`) and gates\n"
        "training throughput (`bench_rl.py --min-ratio 50`,\n"
        "`bench_nightly.py --gate-rl-ratio`).  Retrain + regenerate with\n"
        "`python scripts/train_rl_baseline.py`.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# §Serving — multi-tenant SLO attainment under fragmentation-aware dispatch

SERVING_BASELINE = os.path.join(
    REPO_ROOT, "benchmarks", "baselines", "serving_matrix.jsonl"
)


def serving_md() -> str:
    out = io.StringIO()
    out.write("## Serving — multi-tenant SLO attainment\n\n")
    out.write(
        "The `multi-tenant-serving` scenario replaces the paper's anonymous\n"
        "batch trace with named tenant request streams: each tenant is a\n"
        "model config mapped memory-first onto a MIG slice class\n"
        "(`repro.core.serving`, DESIGN.md §9), every request carries a\n"
        "latency SLO, and per-tenant attainment is threaded exactly through\n"
        "`SimResult` and the fleet aggregation.  The `serving_matrix` grid\n"
        "races the dispatchers over three tenant mixes on two fleets; the\n"
        "`fragmentation-aware` dispatcher adds a slice-class misfit term and\n"
        "a post-placement fragmentation penalty over the free-slot geometry\n"
        "to the state-aware start-delay proxy.\n\n"
    )
    if not os.path.exists(SERVING_BASELINE):
        out.write("*(baseline `serving_matrix.jsonl` not yet generated)*\n")
        return out.getvalue()

    rows = _baseline_rows(SERVING_BASELINE, "serving_matrix")

    out.write(
        "Fleet SLO attainment (request-weighted; higher is better) and\n"
        "energy per fleet × mix × dispatcher from the checked-in\n"
        "`--scale 0.1` baseline:\n\n"
    )
    out.write("| fleet | mix (load) | dispatcher | SLO attainment | energy (Wh) | ET |\n")
    out.write("|---|---|---|---|---|---|\n")
    for row in rows:
        out.write(
            f"| {row['fleet']} | {row['mix']} ({row['load_scale']:g}) "
            f"| {row['dispatcher']} | {row['slo_attainment']:.4f} "
            f"| {row['energy_wh']:.0f} | {row['ET']:.4f} |\n"
        )
    out.write(
        "\nOn the large-heavy mix fragmentation-aware beats least-loaded on\n"
        "SLO attainment at lower energy on *both* fleets (the CI-gated\n"
        "acceptance row, pinned in `tests/test_serving.py`): keeping a\n"
        "wide instance placeable is exactly what the mixtral-class tenants\n"
        "need.  The saturated mixed-fleet balanced row shows the limit —\n"
        "when offered load exceeds what the fleet can serve within SLO, no\n"
        "routing policy recovers it and blind round-robin's spreading\n"
        "incidentally wins.  Regenerate with `python -m repro.sweep\n"
        "serving_matrix --scale 0.1` and compare via `--check-baseline`.\n"
    )
    return out.getvalue()


# ----------------------------------------------------------------------
# document assembly + checks


def build_markdown() -> str:
    parts = [
        HEADER,
        calibration_md(),
        dryrun_md(),
        roofline_md(),
        perf_md(),
        batched_md(),
        sweeps_md(),
        dispatchers_md(),
        repartition_modes_md(),
        predictive_md(),
        rl_md(),
        serving_md(),
    ]
    return "\n".join(part.rstrip() + "\n" for part in parts)


# any path-qualified or bare markdown reference; the matched path is
# resolved verbatim against the repo root (no prefix stripping), so a
# subdirectory-qualified reference is checked at exactly that path
_MD_REF = re.compile(r"\b((?:[A-Za-z0-9_.-]+/)*[A-Za-z][\w.-]*\.md)\b")


def check_doc_refs(root: str = REPO_ROOT) -> List[Tuple[str, str]]:
    """Dangling ``*.md`` references in ``src/`` (and ``scripts/``)."""
    dangling: List[Tuple[str, str]] = []
    for base in ("src", "scripts"):
        for dirpath, dirnames, filenames in os.walk(os.path.join(root, base)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8") as f:
                    text = f.read()
                for ref in sorted(set(_MD_REF.findall(text))):
                    if not os.path.exists(os.path.join(root, ref)):
                        dangling.append((os.path.relpath(path, root), ref))
    return dangling


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="write EXPERIMENTS.md at the repo root")
    ap.add_argument("--check", action="store_true",
                    help="fail if EXPERIMENTS.md is stale or a doc reference dangles")
    args = ap.parse_args(argv)

    rendered = build_markdown()

    if args.check:
        failed = False
        dangling = check_doc_refs()
        for path, ref in dangling:
            print(f"DANGLING DOC REF: {path} references missing {ref}", file=sys.stderr)
            failed = True
        if not os.path.exists(EXPERIMENTS_PATH):
            print("EXPERIMENTS.md does not exist; run --write", file=sys.stderr)
            failed = True
        else:
            with open(EXPERIMENTS_PATH, encoding="utf-8") as f:
                current = f.read()
            if current != rendered:
                print(
                    "EXPERIMENTS.md is stale: regenerate with "
                    "`PYTHONPATH=src python scripts/render_experiments.py --write`",
                    file=sys.stderr,
                )
                failed = True
        if not failed:
            print("EXPERIMENTS.md up to date; all doc references resolve")
        return 1 if failed else 0

    if args.write:
        with open(EXPERIMENTS_PATH, "w", encoding="utf-8") as f:
            f.write(rendered)
        print(f"wrote {EXPERIMENTS_PATH}")
        return 0

    print(rendered, end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
