"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage: PYTHONPATH=src python scripts/render_experiments.py
Prints markdown to stdout (pasted/refreshed into EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.roofline import load_record, model_flops, roofline_row  # noqa: E402
from repro.launch.shapes import SHAPES, all_cells  # noqa: E402


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    if x is None:
        return "-"
    return f"{x/2**30:.2f}"


def main() -> None:
    print("### §Dry-run — compile status and per-device memory\n")
    print("| arch | shape | pod 16x16 | multi-pod 2x16x16 | args GiB/dev | temp GiB/dev | compile s |")
    print("|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for arch, shape in all_cells():
        pod = load_record(arch, shape.name, False)
        mp = load_record(arch, shape.name, True)

        def status(r):
            if r is None:
                return "…"
            if r.get("skipped"):
                return "skip"
            return "OK" if r.get("ok") else "FAIL"

        s_pod, s_mp = status(pod), status(mp)
        if s_pod == "OK":
            n_ok += 1
        elif s_pod == "skip":
            n_skip += 1
        elif s_pod == "FAIL":
            n_fail += 1
        args = temp = comp = None
        if pod and pod.get("ok") and not pod.get("skipped"):
            args = pod.get("argument_size_in_bytes")
            temp = pod.get("temp_size_in_bytes")
            comp = pod.get("compile_seconds")
        print(
            f"| {arch} | {shape.name} | {s_pod} | {s_mp} | {fmt_b(args)} | "
            f"{fmt_b(temp)} | {f'{comp:.0f}' if comp else '-'} |"
        )
    print(f"\npod cells: {n_ok} OK, {n_skip} skipped (DESIGN.md §4), {n_fail} failed.\n")

    print("### §Roofline — per (arch x shape), single pod (256 chips)\n")
    print("| arch | shape | t_comp | t_mem | t_coll | dominant | MODEL/HLO | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch, shape in all_cells():
        row = roofline_row(arch, shape.name)
        if row is None:
            print(f"| {arch} | {shape.name} | … | | | | | | pending |")
            continue
        if row.get("skipped"):
            print(f"| {arch} | {shape.name} | skip | | | | | | {row.get('reason','')} |")
            continue
        if row.get("failed"):
            print(f"| {arch} | {shape.name} | FAIL | | | | | | |")
            continue
        note = _note(row)
        print(
            f"| {arch} | {shape.name} | {fmt_s(row['t_compute_s'])} | "
            f"{fmt_s(row['t_memory_s'])} | {fmt_s(row['t_collective_s'])} | "
            f"{row['dominant']} | {row['useful_ratio']:.2f} | "
            f"{row['roofline_fraction']:.2%} | {note} |"
        )


def _note(row) -> str:
    d = row["dominant"]
    if d == "compute":
        if (row["useful_ratio"] or 1) < 0.6:
            return "cut non-useful FLOPs (remat/attention waste)"
        return "near compute roof; fuse/overlap collectives"
    if d == "memory":
        return "raise arithmetic intensity (bigger tiles, bf16 temps, fuse)"
    return "reshard to shrink collective payload / overlap with compute"


if __name__ == "__main__":
    main()
