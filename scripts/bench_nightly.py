"""Append one perf/metrics trajectory entry to BENCH_nightly.json.

The nightly workflow runs the slow test tier plus the full smoke + fleet +
scenario sweeps, then calls this script.  It collects the per-grid sidecar
metadata the sweep runner leaves next to each JSONL artifact
(``artifacts/sweeps/<grid>.meta.json``: wall-clock, cell counts, cache
hits) — plus the engine events/sec micro-benchmark record written by
``scripts/bench_engine.py`` (``artifacts/bench/engine_events.json``) when
present — into a single dated entry and appends it to the trajectory file,
so regressions in sweep wall-clock, cache hit rate, or raw simulator
throughput show up as a time series rather than a one-off log line.

::

    python scripts/bench_nightly.py                       # append an entry
    python scripts/bench_nightly.py --dry-run             # print, don't write
"""

from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import subprocess
import sys

DEFAULT_OUT = "BENCH_nightly.json"
DEFAULT_SWEEPS_DIR = os.path.join("artifacts", "sweeps")
ENGINE_BENCH_PATH = os.path.join("artifacts", "bench", "engine_events.json")


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def collect_entry(sweeps_dir: str = DEFAULT_SWEEPS_DIR) -> dict:
    grids = {}
    for meta_path in sorted(glob.glob(os.path.join(sweeps_dir, "*.meta.json"))):
        with open(meta_path) as f:
            meta = json.load(f)
        cells = max(int(meta.get("cells", 0)), 1)
        grids[meta["name"]] = {
            "wall_s": round(float(meta.get("wall_s", 0.0)), 3),
            "cells": meta.get("cells", 0),
            "cached": meta.get("cached", 0),
            "computed": meta.get("computed", 0),
            "cache_hit_rate": round(float(meta.get("cached", 0)) / cells, 4),
            "workers": meta.get("workers", 0),
        }
    try:
        from repro.core.simulator import SIM_VERSION
    except ImportError:  # pragma: no cover - script usable without install
        SIM_VERSION = "unknown"
    entry = {
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "sim_version": SIM_VERSION,
        "grids": grids,
        "total_wall_s": round(sum(g["wall_s"] for g in grids.values()), 3),
    }
    if os.path.exists(ENGINE_BENCH_PATH):
        with open(ENGINE_BENCH_PATH) as f:
            bench = json.load(f)
        entry["engine_bench"] = {
            "events_per_sec": bench.get("events_per_sec"),
            "events": bench.get("events"),
            "load_scale": bench.get("load_scale"),
        }
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--sweeps-dir", default=DEFAULT_SWEEPS_DIR)
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)

    entry = collect_entry(args.sweeps_dir)
    if not entry["grids"]:
        print(f"no sweep metadata under {args.sweeps_dir}; nothing to record",
              file=sys.stderr)
        return 1
    if args.dry_run:
        print(json.dumps(entry, indent=2))
        return 0

    trajectory = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            trajectory = json.load(f)
        if not isinstance(trajectory, list):
            raise SystemExit(f"{args.out} is not a JSON list")
    trajectory.append(entry)
    with open(args.out, "w") as f:
        json.dump(trajectory, f, indent=2)
        f.write("\n")
    print(f"appended entry #{len(trajectory)} to {args.out} "
          f"({len(entry['grids'])} grids, {entry['total_wall_s']}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
