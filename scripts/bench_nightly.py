"""Append one perf/metrics trajectory entry to BENCH_nightly.json.

The nightly workflow runs the slow test tier plus the full smoke + fleet +
scenario sweeps, then calls this script.  It collects the per-grid sidecar
metadata the sweep runner leaves next to each JSONL artifact
(``artifacts/sweeps/<grid>.meta.json``: wall-clock, cell counts, cache
hits) — plus the engine events/sec micro-benchmark record written by
``scripts/bench_engine.py`` (``artifacts/bench/engine_events.json``) when
present — into a single dated entry and appends it to the trajectory file,
so regressions in sweep wall-clock, cache hit rate, or raw simulator
throughput show up as a time series rather than a one-off log line.

::

    python scripts/bench_nightly.py                       # append an entry
    python scripts/bench_nightly.py --dry-run             # print, don't write
    python scripts/bench_nightly.py --gate-events-ratio 0.5   # + regression gate

The trajectory file is written atomically (tmp + rename): a crash mid-write
can never truncate the history to an empty file, and a missing/empty file
seeds a fresh list instead of erroring.  ``--gate-events-ratio`` compares
this run's engine events/sec against the best of the last ``GATE_WINDOW``
previous entries that recorded one and fails (exit 1) when throughput fell
below that fraction — a *trajectory-relative* gate that catches gradual
drift the static CI floor (``bench_engine.py --min-events-per-sec``) is
too conservative to see, without self-ratcheting onto its own regressed
entries.  The entry is appended before the gate verdict (a regression is
recorded in the history it is flagged against); ``--dry-run`` still
evaluates the gate, it only skips the append.
"""

from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import subprocess
import sys

DEFAULT_OUT = "BENCH_nightly.json"
DEFAULT_SWEEPS_DIR = os.path.join("artifacts", "sweeps")
ENGINE_BENCH_PATH = os.path.join("artifacts", "bench", "engine_events.json")
BATCHED_BENCH_PATH = os.path.join("artifacts", "bench", "batched_events.json")
SERVICE_BENCH_PATH = os.path.join("artifacts", "bench", "service_bench.json")
RL_BENCH_PATH = os.path.join("artifacts", "bench", "rl_bench.json")


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _serving_slo_summary(path: str) -> dict:
    """Per-dispatcher SLO attainment (fleet-wide and per tenant) from the
    ``serving_matrix`` JSONL artifact — the nightly time series that shows a
    serving regression as *whose* SLOs degraded, not just a wall-clock blip.
    """
    per: dict = {}
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            disp = rec["cell"]["fleet"]["dispatcher"]
            acc = per.setdefault(disp, {})
            for name, st in (rec["result"].get("tenants") or {}).items():
                a = acc.setdefault(name, [0, 0])
                a[0] += int(st["jobs"])
                a[1] += int(st["attained"])
    out = {}
    for disp, tenants in sorted(per.items()):
        jobs = sum(v[0] for v in tenants.values())
        attained = sum(v[1] for v in tenants.values())
        out[disp] = {
            "slo_attainment": round(attained / jobs, 4) if jobs else 1.0,
            "tenants": {
                n: round(v[1] / v[0], 4) if v[0] else 1.0
                for n, v in sorted(tenants.items())
            },
        }
    return out


def collect_entry(sweeps_dir: str = DEFAULT_SWEEPS_DIR) -> dict:
    grids = {}
    for meta_path in sorted(glob.glob(os.path.join(sweeps_dir, "*.meta.json"))):
        with open(meta_path) as f:
            meta = json.load(f)
        cells = max(int(meta.get("cells", 0)), 1)
        grids[meta["name"]] = {
            "wall_s": round(float(meta.get("wall_s", 0.0)), 3),
            "cells": meta.get("cells", 0),
            "cached": meta.get("cached", 0),
            "computed": meta.get("computed", 0),
            "cache_hit_rate": round(float(meta.get("cached", 0)) / cells, 4),
            "workers": meta.get("workers", 0),
        }
    try:
        from repro.core.simulator import SIM_VERSION
    except ImportError:  # pragma: no cover - script usable without install
        SIM_VERSION = "unknown"
    entry = {
        # lint: waive[DT002] run-date metadata for the trend log, not simulation state
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "sim_version": SIM_VERSION,
        "grids": grids,
        "total_wall_s": round(sum(g["wall_s"] for g in grids.values()), 3),
    }
    serving_path = os.path.join(sweeps_dir, "serving_matrix.jsonl")
    if os.path.exists(serving_path):
        entry["serving_slo"] = _serving_slo_summary(serving_path)
    if os.path.exists(ENGINE_BENCH_PATH):
        with open(ENGINE_BENCH_PATH) as f:
            bench = json.load(f)
        entry["engine_bench"] = {
            "events_per_sec": bench.get("events_per_sec"),
            "events": bench.get("events"),
            "load_scale": bench.get("load_scale"),
        }
    # the batched backend's record rides alongside (never inside) the
    # engine_bench entry: per-backend keys keep the trajectory schema and
    # the existing engine gate untouched (scripts/bench_batched.py)
    if os.path.exists(BATCHED_BENCH_PATH):
        with open(BATCHED_BENCH_PATH) as f:
            bench = json.load(f)
        entry["batched_bench"] = {
            "events_equiv_per_sec": bench.get("events_equiv_per_sec"),
            "ratio_vs_oracle": bench.get("ratio_vs_oracle"),
            "headline_load_scale": bench.get("headline_load_scale"),
            "dt_min": bench.get("dt_min"),
        }
    # the scheduler-service load test (scripts/bench_service.py): end-to-end
    # socket + WAL + engine submit throughput, gated like the backends
    if os.path.exists(SERVICE_BENCH_PATH):
        with open(SERVICE_BENCH_PATH) as f:
            bench = json.load(f)
        entry["service_throughput"] = {
            "jobs_per_min": bench.get("jobs_per_min"),
            "p50_ms": bench.get("p50_ms"),
            "p99_ms": bench.get("p99_ms"),
            "jobs": bench.get("jobs"),
        }
    # RL training throughput (scripts/bench_rl.py): batched trainer
    # env-steps/sec at the headline curve point, plus the batched/host
    # ratio and the host-oracle agreement verdict
    if os.path.exists(RL_BENCH_PATH):
        with open(RL_BENCH_PATH) as f:
            bench = json.load(f)
        entry["rl_throughput"] = {
            "env_steps_per_sec": bench.get("env_steps_per_sec_batched"),
            "ratio_vs_host": bench.get("ratio_vs_host"),
            "headline_load_scale": bench.get("headline_load_scale"),
            "agreement_ok": (bench.get("agreement") or {}).get("within_tolerance"),
        }
    return entry


def load_trajectory(path: str) -> list:
    """The existing trajectory, seeding a fresh list when absent/empty.

    A missing or empty file is a valid starting state (fresh checkout, or a
    previous run crashed before the atomic rename landed) — it seeds ``[]``
    so the append path always produces a one-entry trajectory instead of
    dying and leaving the history stuck at nothing.  Anything else that is
    not a JSON list is a real corruption and errors out loudly.
    """
    if not os.path.exists(path):
        return []
    with open(path) as f:
        text = f.read().strip()
    if not text:
        return []
    try:
        trajectory = json.loads(text)
    except json.JSONDecodeError as e:
        raise SystemExit(f"{path} holds invalid JSON ({e}); refusing to clobber") from e
    if not isinstance(trajectory, list):
        raise SystemExit(f"{path} is not a JSON list")
    return trajectory


def save_trajectory(path: str, trajectory: list) -> None:
    """Atomic write: a crash mid-dump can never truncate the history."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)


#: how many recent measured entries the throughput gate references
GATE_WINDOW = 7


def check_events_regression(
    trajectory: list,
    entry: dict,
    ratio: float,
    window: int = GATE_WINDOW,
    *,
    key: str = "engine_bench",
    field: str = "events_per_sec",
    label: str = "ENGINE",
    unit: str = "ev/s",
) -> "str | None":
    """Trajectory-relative throughput gate (per-backend via ``key``).

    Compares ``entry[key][field]`` against the **best** of the ``window``
    most recent previous entries that recorded one; returns a failure
    message when this run fell below ``ratio`` of that reference (None =
    pass, including when either side has no such record — a missing
    measurement is not a regression).  The defaults gate the oracle
    engine's events/sec; ``key="batched_bench", field="events_equiv_per_
    sec"`` gates the batched backend the same way.  Referencing a rolling
    max rather than only the immediately previous entry keeps the gate
    from self-ratcheting: a persistent regression (which is recorded in
    the trajectory by design) keeps failing until throughput recovers or
    the regressed level ages out of the window, and compounding
    slightly-under-ratio drift cannot slip through night after night.
    """
    now = (entry.get(key) or {}).get(field)
    if now is None:
        return None
    recent = []
    for prev in reversed(trajectory):
        if prev is entry:
            continue
        prev_eps = (prev.get(key) or {}).get(field)
        if prev_eps:
            recent.append((prev_eps, prev.get("date", "?")))
            if len(recent) >= window:
                break
    if not recent:
        return None
    ref_eps, ref_date = max(recent)
    if now < ratio * ref_eps:
        return (
            f"{label} THROUGHPUT REGRESSION: {now:.0f} {unit} is below "
            f"{ratio:.0%} of the best of the last {len(recent)} measured "
            f"trajectory entries ({ref_eps:.0f} {unit} on {ref_date})"
        )
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--sweeps-dir", default=DEFAULT_SWEEPS_DIR)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument(
        "--gate-events-ratio", type=float, default=None, metavar="R",
        help="fail (exit 1) when engine events/sec fell below R x the "
             "previous trajectory entry's (the entry is still appended)",
    )
    ap.add_argument(
        "--gate-batched-ratio", type=float, default=None, metavar="R",
        help="same trajectory-relative gate for the batched backend's "
             "events/sec-equivalent (batched_bench entries)",
    )
    ap.add_argument(
        "--gate-service-ratio", type=float, default=None, metavar="R",
        help="same trajectory-relative gate for the scheduler service's "
             "submit throughput (service_throughput entries)",
    )
    ap.add_argument(
        "--gate-rl-ratio", type=float, default=None, metavar="R",
        help="same trajectory-relative gate for the batched RL trainer's "
             "env-steps/sec (rl_throughput entries)",
    )
    args = ap.parse_args(argv)

    entry = collect_entry(args.sweeps_dir)
    if not entry["grids"]:
        print(f"no sweep metadata under {args.sweeps_dir}; nothing to record",
              file=sys.stderr)
        return 1

    trajectory = load_trajectory(args.out)
    # the gate compares against history *before* this run is appended, and
    # runs under --dry-run too (read-only) so a local gate reproduction
    # does not silently pass
    failures = []
    if args.gate_events_ratio is not None:
        failures.append(
            check_events_regression(trajectory, entry, args.gate_events_ratio)
        )
    if args.gate_batched_ratio is not None:
        failures.append(
            check_events_regression(
                trajectory, entry, args.gate_batched_ratio,
                key="batched_bench", field="events_equiv_per_sec",
                label="BATCHED", unit="ev_eq/s",
            )
        )
    if args.gate_service_ratio is not None:
        failures.append(
            check_events_regression(
                trajectory, entry, args.gate_service_ratio,
                key="service_throughput", field="jobs_per_min",
                label="SERVICE", unit="jobs/min",
            )
        )
    if args.gate_rl_ratio is not None:
        failures.append(
            check_events_regression(
                trajectory, entry, args.gate_rl_ratio,
                key="rl_throughput", field="env_steps_per_sec",
                label="RL TRAIN", unit="steps/s",
            )
        )
    failures = [f for f in failures if f]
    if args.dry_run:
        print(json.dumps(entry, indent=2))
    else:
        trajectory.append(entry)
        save_trajectory(args.out, trajectory)
        print(f"appended entry #{len(trajectory)} to {args.out} "
              f"({len(entry['grids'])} grids, {entry['total_wall_s']}s total)")
    if failures:
        # the regressed entry is recorded above (unless --dry-run) — the
        # history must show the dip the gate is complaining about
        for failure in failures:
            print(failure, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
