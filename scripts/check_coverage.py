"""Per-module coverage floors over a ``coverage json`` report.

The blanket project percentage hides exactly the regressions that matter
here: the engine and the service are the two modules whose behaviour is
pinned by bit-identity guarantees, so *their* coverage must not erode even
when the repo-wide number looks healthy.  CI runs the fast tier with
``pytest --cov``, exports ``coverage.json``, and gates:

::

    python scripts/check_coverage.py coverage.json \\
        --floor repro.core.engine=80 --floor repro.service=70

A floor names either a single module (``repro.core.engine`` ->
``src/repro/core/engine.py``) or a package prefix (``repro.service`` ->
every file under ``src/repro/service/``); line coverage is aggregated as
covered/statements over all matching files, and any floor with no matching
measured files fails loudly (a renamed module must not silently skip its
gate).
"""

from __future__ import annotations

import argparse
import json
import sys


def module_percent(report: dict, module: str) -> "tuple[float, int]":
    """Aggregate (percent, files) for a module/package dotted name."""
    rel = module.replace(".", "/")
    covered = statements = files = 0
    for path, info in report.get("files", {}).items():
        norm = path.replace("\\", "/")
        for prefix in ("src/", ""):
            mod_path = norm[len(prefix):] if norm.startswith(prefix) else None
            if mod_path is None:
                continue
            if mod_path == rel + ".py" or mod_path.startswith(rel + "/"):
                summary = info["summary"]
                covered += int(summary["covered_lines"])
                statements += int(summary["num_statements"])
                files += 1
            break
    if statements == 0:
        return 0.0, files
    return 100.0 * covered / statements, files


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="coverage.json (from `coverage json`)")
    ap.add_argument(
        "--floor", action="append", default=[], metavar="MODULE=PCT",
        help="e.g. repro.core.engine=80; repeatable",
    )
    args = ap.parse_args(argv)
    if not args.floor:
        ap.error("at least one --floor is required")

    with open(args.report) as f:
        report = json.load(f)

    failures = []
    for spec in args.floor:
        module, _, pct = spec.partition("=")
        if not pct:
            ap.error(f"bad --floor {spec!r}; expected MODULE=PCT")
        floor = float(pct)
        got, files = module_percent(report, module)
        if files == 0:
            failures.append(
                f"{module}: no measured files in {args.report} — was the "
                f"module renamed, or --cov not pointed at it?"
            )
            continue
        verdict = "ok" if got >= floor else "FAIL"
        print(f"{module}: {got:.1f}% over {files} file(s), floor {floor:.0f}% [{verdict}]")
        if got < floor:
            failures.append(
                f"{module}: coverage {got:.1f}% is below the {floor:.0f}% floor"
            )
    for failure in failures:
        print(failure, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
