"""Micro-benchmark: SimulationEngine event throughput (events/sec).

Runs the paper-diurnal scenario at ``--load-scale 0.1`` (the CI sweep
sizing) through the steppable engine under a timer-carrying policy
(Day/Night), so every event class — arrival, completion, critical,
repartition-complete, policy timer — is exercised.  Reports the best-of
``--repeats`` throughput, writes it to ``artifacts/bench/engine_events.json``
(collected into the BENCH_nightly.json trajectory by
``scripts/bench_nightly.py``), and optionally gates on a floor:

::

    PYTHONPATH=src python scripts/bench_engine.py                  # measure + write
    PYTHONPATH=src python scripts/bench_engine.py --min-events-per-sec 20000
    PYTHONPATH=src python scripts/bench_engine.py --dry-run        # print only

``--min-events-per-sec`` is the CI smoke threshold: an engine-refactor
regression in simulator throughput fails the build instead of landing
silently.  The floor is deliberately far below developer-laptop numbers —
it catches order-of-magnitude regressions (accidental O(n²) rescheduling,
event storms), not scheduler noise on shared runners.

``--backend batched`` delegates every remaining flag to
``scripts/bench_batched.py`` (the batched backend needs a different
methodology — events/sec-*equivalent* against an oracle reference — and a
different output file, ``artifacts/bench/batched_events.json``), so one
entry point benches either backend:

::

    PYTHONPATH=src python scripts/bench_engine.py --backend batched --quick
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join("artifacts", "bench", "engine_events.json")


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def measure(load_scale: float = 0.1, seeds: int = 3, repeats: int = 3) -> dict:
    """Best-of-``repeats`` engine throughput over ``seeds`` diurnal days."""
    from repro.core.engine import SimulationEngine
    from repro.core.scenarios import generate_scenario
    from repro.core.schedulers import make_scheduler
    from repro.core.simulator import SIM_VERSION, DayNightPolicy, MIGSimulator

    # generate outside the timed region; each repeat needs a fresh job list
    # (jobs carry mutable scheduling state)
    def day(seed):
        return generate_scenario("paper-diurnal", seed=seed, load_scale=load_scale)

    best_eps = 0.0
    best = {}
    for _ in range(repeats):
        job_lists = [day(s) for s in range(seeds)]
        events = 0
        t0 = time.perf_counter()
        for jobs in job_lists:
            sim = MIGSimulator(make_scheduler("EDF-SS"))
            engine = SimulationEngine(sim, policy=DayNightPolicy(), jobs=jobs)
            engine.drain()
            engine.result()
            events += engine.events_processed
        elapsed = time.perf_counter() - t0
        eps = events / elapsed if elapsed > 0 else float("inf")
        if eps > best_eps:
            best_eps = eps
            best = {"events": events, "seconds": round(elapsed, 4)}
    return {
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "sim_version": SIM_VERSION,
        "scenario": "paper-diurnal",
        "load_scale": load_scale,
        "seeds": seeds,
        "repeats": repeats,
        **best,
        "events_per_sec": round(best_eps, 1),
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--backend" in argv:
        i = argv.index("--backend")
        backend = argv[i + 1] if i + 1 < len(argv) else "?"
        rest = argv[:i] + argv[i + 2:]
        if backend == "batched":
            # separate module, separate flags/out path: see its docstring
            import importlib.util

            spec = importlib.util.spec_from_file_location(
                "bench_batched",
                os.path.join(os.path.dirname(__file__), "bench_batched.py"),
            )
            mod = importlib.util.module_from_spec(spec)
            spec.loader.exec_module(mod)
            return mod.main(rest)
        if backend != "oracle":
            print(f"unknown --backend {backend!r} (oracle|batched)",
                  file=sys.stderr)
            return 2
        argv = rest
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--load-scale", type=float, default=0.1)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--min-events-per-sec", type=float, default=None,
                    help="fail (exit 1) below this throughput — the CI gate")
    ap.add_argument("--dry-run", action="store_true", help="print, don't write")
    args = ap.parse_args(argv)

    entry = measure(args.load_scale, args.seeds, args.repeats)
    print(json.dumps(entry, indent=2))
    if not args.dry_run:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(entry, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)
    if (
        args.min_events_per_sec is not None
        and entry["events_per_sec"] < args.min_events_per_sec
    ):
        print(
            f"ENGINE THROUGHPUT REGRESSION: {entry['events_per_sec']:.0f} ev/s "
            f"< floor {args.min_events_per_sec:.0f} ev/s",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
