"""Load-test the scheduler service over its unix-socket front end.

Spawns a real ``python -m repro.service serve`` process, drives it with a
persistent :class:`~repro.service.ServiceClient`, and measures end-to-end
submit throughput and latency — socket round-trip, WAL append, and the
engine co-advance all included.  Arrivals are stamped by the client on a
fixed sim-time grid sized so the device keeps up (completions interleave
with submissions instead of piling into an ever-growing queue), which makes
the run deterministic and the numbers comparable night over night.

::

    PYTHONPATH=src python scripts/bench_service.py                 # measure + write
    PYTHONPATH=src python scripts/bench_service.py --quick --dry-run   # CI smoke
    PYTHONPATH=src python scripts/bench_service.py \\
        --min-jobs-per-min 5000 --max-p99-ms 50                    # nightly gate

Writes ``artifacts/bench/service_bench.json`` (collected into the
BENCH_nightly.json trajectory as the ``service_throughput`` key by
``scripts/bench_nightly.py``).  The floors are the PR's acceptance numbers:
sustained >= 5k jobs/min with p99 submit latency < 50 ms; like the engine
floor they sit far below developer-machine numbers and catch
order-of-magnitude regressions (per-op fsync on the default path, an
accidental O(n²) in the submit path), not runner noise.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join("artifacts", "bench", "service_bench.json")


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _percentile(sorted_vals, q):
    return sorted_vals[min(int(q * (len(sorted_vals) - 1)), len(sorted_vals) - 1)]


def measure(jobs: int, *, arrival_dt_min: float = 0.01, work: float = 0.05,
            checkpoint_every_min: float = 30.0, warmup: int = 50) -> dict:
    """Drive ``jobs`` submissions through a real server process.

    ``work``/``arrival_dt_min`` set the offered load at ~5 slice-minutes per
    sim-minute — under a 7-slice device's capacity, so the engine stays in
    steady state and the measured latency is the service's, not a backlog
    artifact.  The first ``warmup`` submissions prime the interpreter and
    the socket and are excluded from the percentiles.
    """
    from repro.service import ServiceClient, wait_for_socket

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        src + os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else src
    )

    with tempfile.TemporaryDirectory(prefix="bench-service-") as td:
        socket_path = os.path.join(td, "svc.sock")
        proc = subprocess.Popen(
            [
                sys.executable, "-m", "repro.service", "serve",
                "--dir", os.path.join(td, "state"), "--socket", socket_path,
                "--speedup", "0",  # op-driven time: the client stamps arrivals
                "--policy", "static:7", "--scheduler", "EDF-SS",
                "--checkpoint-every-min", str(checkpoint_every_min),
            ],
            env=env,
            stdout=subprocess.DEVNULL,
            stderr=subprocess.STDOUT,
        )
        try:
            wait_for_socket(socket_path, timeout_s=30.0)
            client = ServiceClient(socket_path)
            latencies = []
            t_start = time.perf_counter()
            for i in range(jobs):
                t0 = time.perf_counter()
                client.submit(
                    job_id=i,
                    arrival=i * arrival_dt_min,
                    work=work,
                    deadline_slack_min=60.0,
                    elasticity="linear",
                )
                latencies.append(time.perf_counter() - t0)
            elapsed = time.perf_counter() - t_start
            status = client.status()
            result = client.close_stream()
            client.shutdown()
            client.close()
        finally:
            try:
                proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()

        if result["num_jobs"] != jobs:
            raise RuntimeError(
                f"service lost jobs: {result['num_jobs']} completed != "
                f"{jobs} submitted"
            )
        lat = sorted(latencies[warmup:] or latencies)
        return {
            "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
            "git_sha": _git_sha(),
            "jobs": jobs,
            "arrival_dt_min": arrival_dt_min,
            "checkpoint_every_min": checkpoint_every_min,
            "wall_s": round(elapsed, 4),
            "jobs_per_min": round(jobs / elapsed * 60.0, 1),
            "p50_ms": round(_percentile(lat, 0.50) * 1e3, 3),
            "p95_ms": round(_percentile(lat, 0.95) * 1e3, 3),
            "p99_ms": round(_percentile(lat, 0.99) * 1e3, 3),
            "sim_minutes": round(status["t"], 2),
            "energy_wh": result["energy_wh"],
        }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--jobs", type=int, default=6000)
    ap.add_argument("--quick", action="store_true",
                    help="400 jobs — the CI smoke sizing")
    ap.add_argument("--min-jobs-per-min", type=float, default=None,
                    help="fail (exit 1) below this throughput — the gate")
    ap.add_argument("--max-p99-ms", type=float, default=None,
                    help="fail (exit 1) above this p99 submit latency")
    ap.add_argument("--dry-run", action="store_true", help="print, don't write")
    args = ap.parse_args(argv)

    entry = measure(400 if args.quick else args.jobs)
    print(json.dumps(entry, indent=2))
    if not args.dry_run:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(entry, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)

    failures = []
    if (
        args.min_jobs_per_min is not None
        and entry["jobs_per_min"] < args.min_jobs_per_min
    ):
        failures.append(
            f"SERVICE THROUGHPUT REGRESSION: {entry['jobs_per_min']:.0f} "
            f"jobs/min < floor {args.min_jobs_per_min:.0f}"
        )
    if args.max_p99_ms is not None and entry["p99_ms"] > args.max_p99_ms:
        failures.append(
            f"SERVICE LATENCY REGRESSION: p99 {entry['p99_ms']:.2f} ms > "
            f"ceiling {args.max_p99_ms:.2f} ms"
        )
    for failure in failures:
        print(failure, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
