"""Benchmark: RL training throughput, host loop vs fused on-device trainer.

The common currency is *env-steps/sec*: one env step = one repartitioning
decision (observe -> act -> advance one interval -> store -> train).
Both loops run the identical ``DQNConfig`` on the same scenario family at
the same fixed 15-min decision cadence, with ``min_buffer`` set so the
per-decision TD update runs from (nearly) the first step — steady
*training* throughput, not untrained env stepping.  The host side is
:func:`repro.core.rl.train.train_dqn` stepping one cadence-mode
:class:`repro.core.rl.env.RepartitionEnv` episode at a time; the batched
side is the fused trainer (:mod:`repro.core.rl.batched_train`) advancing
B rollouts plus the learner update inside one jitted scan.

::

    PYTHONPATH=src python scripts/bench_rl.py            # full measurement
    PYTHONPATH=src python scripts/bench_rl.py --quick    # CI smoke
    PYTHONPATH=src python scripts/bench_rl.py --min-ratio 50

Writes ``artifacts/bench/rl_bench.json`` (collected into the
BENCH_nightly.json trajectory by ``scripts/bench_nightly.py``).  The entry
also records the host-oracle *agreement* check: one jitted TD update
through the trainer's scan-embedded path vs the host ``DQNLearner``'s own
update on an identical replay batch — they share
:func:`repro.core.rl.dqn.make_td_update`, so the max parameter difference
must sit at float32 noise (documented tolerance 1e-5; DESIGN.md §11).

``--min-ratio`` is the machine-portable gate (both loops run on the same
box): the acceptance floor is 50x, set far below the measured headline so
it catches structural regressions (a de-fused training step, a host
round-trip reintroduced into the scan), not timer noise.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_OUT = os.path.join("artifacts", "bench", "rl_bench.json")

#: documented float tolerance for one jitted training step vs DQNLearner
AGREEMENT_TOL = 1e-5

# the measured curve: heavier load -> deeper queues -> the host env's
# per-decision event processing slows superlinearly (O(queue) scheduler
# passes per event, more events per decision) while the batched per-step
# cost grows only linearly in the padded job count — the ratio rises with
# load_scale and the headline is the best point (same shape as
# scripts/bench_batched.py).  Two high-load points give the >=50x gate
# redundancy against single-point timer noise.  Host episodes shrink as
# its per-episode cost grows; the batched run times rounds after the
# first (compile) round.  Batch 64 sits at the compute-bound plateau on
# one CPU device (B=32..512 measure within ~15% of each other).
FULL_POINTS = (
    {"load_scale": 1.0, "host_episodes": 2, "batch": 64, "rounds": 2},
    {"load_scale": 4.0, "host_episodes": 1, "batch": 64, "rounds": 2},
    {"load_scale": 12.0, "host_episodes": 1, "batch": 64, "rounds": 2},
    {"load_scale": 16.0, "host_episodes": 1, "batch": 64, "rounds": 2},
)
QUICK_POINTS = (
    {"load_scale": 0.2, "host_episodes": 2, "batch": 8, "rounds": 2},
)

#: both loops decide on this cadence (the batched trainer's default)
DECISION_INTERVAL_MIN = 15.0

#: scan length per round; high-load days do not drain inside it, which is
#: fine for a throughput measurement (every step is a full live step)
HORIZON_DECISIONS = 104


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, check=True
            ).stdout.strip()
        )
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _dqn_config(seed: int = 0):
    """The shared learner config — identical on both sides by construction."""
    from repro.core.rl.dqn import DQNConfig
    from repro.core.rl.env import FEATURE_DIM

    return DQNConfig(
        state_dim=FEATURE_DIM,
        # train from (nearly) the first decision: the bench measures steady
        # training throughput, not buffer warm-up
        min_buffer=128,
        buffer_capacity=50_000,
        target_sync_every=500,
        eps_decay_steps=10_000,
        seed=seed,
    )


_HOST_WARM = [False]


def measure_host(load_scale: float, episodes: int, scenario: str) -> dict:
    """Host loop env-steps/sec: jit warmed by one cheap low-load episode."""
    from repro.core.rl.train import train_dqn

    def kwargs(ls):
        return dict(
            scheduler_name="EDF-FS",
            dqn_config=_dqn_config(),
            scenario=scenario,
            scenario_kwargs={"load_scale": ls},
            decision_interval_min=DECISION_INTERVAL_MIN,
        )

    if not _HOST_WARM[0]:
        # the jitted update/q-forward shapes are load-independent, so one
        # cheap low-load episode warms the cache for every curve point
        train_dqn(num_episodes=1, seed=999, **kwargs(0.1))
        _HOST_WARM[0] = True
    t0 = time.perf_counter()
    _, stats = train_dqn(num_episodes=episodes, seed=0, **kwargs(load_scale))
    wall = time.perf_counter() - t0
    return {
        "episodes": episodes,
        "env_steps": stats.env_steps,
        "seconds": round(wall, 4),
        "env_steps_per_sec": round(stats.env_steps / wall, 1)
        if wall > 0 else float("inf"),
    }


def measure_batched(
    load_scale: float, batch: int, rounds: int, scenario: str
) -> dict:
    """Fused trainer env-steps/sec, steady state (first round = compile)."""
    from repro.core.rl.batched_train import BatchedTrainConfig, train_dqn_batched

    tcfg = BatchedTrainConfig(
        batch=batch,
        scenarios=(scenario,),
        scenario_kwargs={"load_scale": load_scale},
        decision_interval_min=DECISION_INTERVAL_MIN,
        horizon_decisions=HORIZON_DECISIONS,
    )
    _, stats = train_dqn_batched(
        num_episodes=batch * rounds,
        dqn_config=_dqn_config(),
        train_config=tcfg,
        seed=0,
    )
    steady_steps = sum(stats.round_env_steps[1:])
    steady_wall = sum(stats.round_wall_seconds[1:])
    if rounds < 2:  # degenerate: no compile-free round to time
        steady_steps, steady_wall = stats.env_steps, stats.wall_seconds
    return {
        "batch": batch,
        "rounds": rounds,
        "episodes": stats.episodes,
        "env_steps": stats.env_steps,
        "updates": stats.updates,
        "compile_round_seconds": round(stats.round_wall_seconds[0], 4),
        "steady_env_steps": steady_steps,
        "steady_seconds": round(steady_wall, 4),
        "env_steps_per_sec": round(steady_steps / steady_wall, 1)
        if steady_wall > 0 else float("inf"),
    }


def check_agreement() -> dict:
    """One scan-embedded jitted TD update vs the host learner's update.

    Both call :func:`make_td_update`'s function; embedding one side in a
    ``lax.scan`` (as the trainer does) must not change the result beyond
    float32 noise.  Returns the max parameter/loss deltas.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.rl.dqn import DQNLearner, make_td_update

    cfg = _dqn_config()
    learner = DQNLearner(cfg)
    rng = np.random.default_rng(42)
    bs, d = cfg.batch_size, cfg.state_dim
    batch = (
        jnp.asarray(rng.normal(size=(bs, d)).astype(np.float32)),
        jnp.asarray(rng.integers(0, cfg.num_actions, bs).astype(np.int32)),
        jnp.asarray(rng.normal(size=bs).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bs, d)).astype(np.float32)),
        jnp.asarray((rng.uniform(size=bs) < 0.1).astype(np.float32)),
        jnp.full((bs,), cfg.gamma ** cfg.n_step, jnp.float32),
    )
    # host side: the learner's own jitted update
    host_params, _, host_loss = learner._update(
        learner.params, learner.target, learner.opt_state, *batch
    )
    # trainer side: the same shared step, embedded in a one-step scan
    _, td_update = make_td_update(cfg)

    @jax.jit
    def scan_once(params, target, opt_state, batch):
        def body(carry, _):
            p, o = carry
            p2, o2, loss = td_update(p, target, o, *batch)
            return (p2, o2), loss

        (p, o), losses = jax.lax.scan(
            body, (params, opt_state), jnp.arange(1)
        )
        return p, losses[0]

    scan_params, scan_loss = scan_once(
        learner.params, learner.target, learner.opt_state, batch
    )
    param_diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(
            jax.tree_util.tree_leaves(host_params),
            jax.tree_util.tree_leaves(scan_params),
            strict=True,
        )
    )
    return {
        "max_param_diff": param_diff,
        "loss_diff": abs(float(host_loss) - float(scan_loss)),
        "tolerance": AGREEMENT_TOL,
        "within_tolerance": param_diff <= AGREEMENT_TOL,
    }


def measure_point(config: dict, scenario: str, verbose: bool = True) -> dict:
    host = measure_host(config["load_scale"], config["host_episodes"], scenario)
    batched = measure_batched(
        config["load_scale"], config["batch"], config["rounds"], scenario
    )
    ratio = (
        batched["env_steps_per_sec"] / host["env_steps_per_sec"]
        if host["env_steps_per_sec"] > 0 else float("inf")
    )
    if verbose:
        print(
            f"load {config['load_scale']:>4}: host "
            f"{host['env_steps_per_sec']:>7.1f} steps/s, batched "
            f"{batched['env_steps_per_sec']:>7.1f} steps/s "
            f"({ratio:.1f}x)",
            file=sys.stderr,
        )
    return {
        "load_scale": config["load_scale"],
        "host": host,
        "batched": batched,
        "ratio_vs_host": round(ratio, 2),
    }


def measure(points, scenario: str = "paper-diurnal",
            verbose: bool = True) -> dict:
    """The full curve; the headline is the best-ratio point."""
    from repro.core.simulator import SIM_VERSION

    measured = [measure_point(p, scenario, verbose=verbose) for p in points]
    agreement = check_agreement()
    head = max(measured, key=lambda m: m["ratio_vs_host"])
    return {
        "date": datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
        "git_sha": _git_sha(),
        "sim_version": SIM_VERSION,
        "scenario": scenario,
        "decision_interval_min": DECISION_INTERVAL_MIN,
        "points": measured,
        "headline_load_scale": head["load_scale"],
        "env_steps_per_sec_host": head["host"]["env_steps_per_sec"],
        "env_steps_per_sec_batched": head["batched"]["env_steps_per_sec"],
        "ratio_vs_host": head["ratio_vs_host"],
        "agreement": agreement,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true",
                    help="small point (CI smoke) instead of the full config")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail (exit 1) when batched/host env-steps/sec "
                         "falls below this — the nightly gate")
    ap.add_argument("--dry-run", action="store_true", help="print, don't write")
    args = ap.parse_args(argv)

    entry = measure(QUICK_POINTS if args.quick else FULL_POINTS)
    print(json.dumps(entry, indent=2))
    if not args.dry_run:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(entry, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)

    failures = []
    if args.min_ratio is not None and entry["ratio_vs_host"] < args.min_ratio:
        failures.append(
            f"RL THROUGHPUT REGRESSION: {entry['ratio_vs_host']:.1f}x "
            f"< floor {args.min_ratio:.1f}x"
        )
    if not entry["agreement"]["within_tolerance"]:
        failures.append(
            "RL AGREEMENT REGRESSION: jitted training step differs from "
            f"DQNLearner by {entry['agreement']['max_param_diff']:.2e} "
            f"(tolerance {AGREEMENT_TOL:.0e})"
        )
    for msg in failures:
        print(msg, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
