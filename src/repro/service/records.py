"""Wire and WAL codecs for the scheduler service (docs/SERVICE.md).

Two codecs live here:

* ``job_to_dict`` / ``job_from_dict`` — a :class:`~repro.core.jobs.Job` as a
  JSON-safe dict.  Only *submission-time* fields are encoded (id, kind,
  arrival, work, deadline, elasticity label, NoMIG speedup, tenant/SLO):
  a WAL job record is the submission, not the outcome — mutable scheduling
  state (``remaining``, ``completion``, preemption counters) is recomputed
  by replay, never stored.  Elasticity round-trips through its canonical
  label (:func:`repro.core.jobs.elasticity_from_label`), and floats survive
  JSON exactly (``json`` emits the shortest repr that round-trips), so a
  decoded job depletes bit-identically to the original.

* WAL op records — one JSON object per line, schema::

      {"seq": 7, "op": "submit",      "t": 12.5, "job": {...}}
      {"seq": 8, "op": "cancel",      "t": 30.0, "job_id": 3}
      {"seq": 9, "op": "reconfigure", "t": 45.0, "config": 6, "device": 0}
      {"seq": 10, "op": "close",      "t": 200.0}

  ``seq`` is the service's strictly increasing op counter; ``t`` is the
  sim-time the op was applied at (the replay clock's reading, floored to be
  nondecreasing).  Recovery replays a record by advancing the engine to
  ``t`` (exclusive) and re-applying the op — see
  :meth:`repro.service.SchedulerService.recover`.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping

from repro.core.jobs import Job, JobKind, elasticity_from_label

__all__ = [
    "WAL_FORMAT",
    "WAL_OPS",
    "job_to_dict",
    "job_from_dict",
    "validate_record",
]

#: bump when the record schema changes incompatibly
WAL_FORMAT = 1

#: every op a WAL line may carry, with its required extra fields
WAL_OPS: Mapping[str, tuple] = {
    "submit": ("job",),
    "cancel": ("job_id",),
    "reconfigure": ("config",),
    "close": (),
}


def job_to_dict(job: Job) -> Dict[str, Any]:
    """Encode a job's submission-time fields as a JSON-safe dict."""
    d: Dict[str, Any] = {
        "job_id": job.job_id,
        "kind": job.kind.value,
        "arrival": job.arrival,
        "work": job.work,
        "deadline": job.deadline,
        "elasticity": job.elasticity.label,
    }
    # optional fields are emitted only when set, keeping records minimal
    # and byte-stable for the common batch job
    if job.speedup_no_mig != 1.0:
        d["speedup_no_mig"] = job.speedup_no_mig
    if job.tenant is not None:
        d["tenant"] = job.tenant
    if job.slo_min is not None:
        d["slo_min"] = job.slo_min
    return d


def job_from_dict(d: Mapping[str, Any]) -> Job:
    """Decode :func:`job_to_dict` output back into a fresh Job."""
    return Job(
        job_id=int(d["job_id"]),
        kind=JobKind(d["kind"]),
        arrival=float(d["arrival"]),
        work=float(d["work"]),
        deadline=float(d["deadline"]),
        elasticity=elasticity_from_label(d["elasticity"]),
        speedup_no_mig=float(d.get("speedup_no_mig", 1.0)),
        tenant=d.get("tenant"),
        slo_min=d.get("slo_min"),
    )


def validate_record(rec: Mapping[str, Any]) -> None:
    """Reject a malformed WAL record with a message naming what's wrong.

    Called on every record during recovery so a hand-edited or
    version-skewed WAL fails loudly at replay time, not as a KeyError deep
    inside an op application.
    """
    op = rec.get("op")
    if op not in WAL_OPS:
        raise ValueError(
            f"WAL record {rec.get('seq')!r} has unknown op {op!r}; "
            f"valid ops: {sorted(WAL_OPS)}"
        )
    if not isinstance(rec.get("seq"), int):
        raise ValueError(f"WAL record missing integer 'seq': {dict(rec)!r}")
    if not isinstance(rec.get("t"), (int, float)):
        raise ValueError(f"WAL record {rec['seq']} missing numeric 't'")
    for field in WAL_OPS[op]:
        if field not in rec:
            raise ValueError(
                f"WAL record {rec['seq']} (op {op!r}) missing field {field!r}"
            )
