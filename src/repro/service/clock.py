"""The service's replay clock: wall seconds -> simulated minutes.

A live service runs its simulated day against real time at a configurable
``speedup`` (simulated minutes per wall minute; ``60`` replays a 24 h day
in 24 wall minutes).  The clock is *advisory*: it decides how far the idle
tick advances the engine and how submission arrivals are stamped, but the
WAL records the resulting sim-times — replay after a crash never consults
a clock, so recovery is bit-identical regardless of wall-clock pacing
(the chunk-invariance of ``SimulationEngine.run_until`` is what makes
tick boundaries invisible to the final state; DESIGN.md §10).

``speedup=0`` (``ReplayClock.free()``) disables pacing entirely: time is
driven only by the ops themselves (each op's explicit ``t`` / arrival),
which is the mode the test suite and the replay CLI use.
"""

# lint: waive-file[DT002] the replay clock IS the wall-clock boundary: it paces
# the live service; sim-times land in the WAL, so replay never reads a clock.
from __future__ import annotations

import time
from typing import Callable, Optional

__all__ = ["ReplayClock"]


class ReplayClock:
    """Affine wall->sim mapping with re-anchoring (see module docstring)."""

    def __init__(
        self,
        speedup: float = 60.0,
        *,
        start_sim_min: float = 0.0,
        time_source: Callable[[], float] = time.monotonic,
    ) -> None:
        if speedup < 0.0:
            raise ValueError(f"speedup must be >= 0, got {speedup}")
        self.speedup = speedup
        self._src = time_source
        self._t0_wall = time_source()
        self._t0_sim = start_sim_min

    @classmethod
    def free(cls) -> "ReplayClock":
        """A non-advancing clock: op times alone drive the simulation."""
        return cls(speedup=0.0)

    @property
    def paced(self) -> bool:
        """Whether wall time advances the simulation at all."""
        return self.speedup > 0.0

    def now(self) -> float:
        """Current simulated time in minutes."""
        if self.speedup == 0.0:
            return self._t0_sim
        return self._t0_sim + (self._src() - self._t0_wall) * self.speedup / 60.0

    def resync(self, sim_min: float) -> None:
        """Re-anchor so ``now()`` reads ``sim_min`` at this wall instant.

        Called after crash recovery: the restored engine resumes at the
        time it had reached, not at the wall time the outage consumed.
        """
        self._t0_wall = self._src()
        self._t0_sim = sim_min

    def wall_seconds_until(self, sim_min: float) -> float:
        """Wall seconds until the clock reads ``sim_min`` (0 if past)."""
        if self.speedup == 0.0:
            return 0.0
        return max((sim_min - self.now()) * 60.0 / self.speedup, 0.0)

    def sleep_until(self, sim_min: float) -> None:
        """Block until the clock reads ``sim_min`` (paced replay feeding)."""
        delay = self.wall_seconds_until(sim_min)
        if delay > 0.0:
            time.sleep(delay)
