"""CLI for the scheduler service: ``python -m repro.service <cmd>``.

Server side::

    python -m repro.service serve --dir /tmp/svc --socket /tmp/svc.sock \\
        --policy daynight --scheduler EDF-SS --speedup 60

Client side (against a running server)::

    python -m repro.service submit --socket /tmp/svc.sock --work 12 \\
        --kind training --elasticity linear --deadline-slack 90
    python -m repro.service status --socket /tmp/svc.sock [--job 3]
    python -m repro.service cancel --socket /tmp/svc.sock --job 3
    python -m repro.service reconfigure --socket /tmp/svc.sock --config 6
    python -m repro.service close --socket /tmp/svc.sock
    python -m repro.service shutdown --socket /tmp/svc.sock

Headless (no server)::

    python -m repro.service replay --dir /tmp/svc --scenario trace-scaled \\
        --seed 3 --max-jobs 200 --pace-ms 0

``replay`` feeds a registered scenario through an in-process service —
creating the workdir on first run, *recovering and resuming* on later
runs (already-submitted job ids are skipped, so a SIGKILLed replay picks
up exactly where the WAL left off; the crash-recovery tests drive this).
Every command prints one JSON object to stdout.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List, Optional

from repro.service.clock import ReplayClock
from repro.service.server import ServiceClient, ServiceServer
from repro.service.service import (
    POLICY_SPECS,
    SchedulerService,
    ServiceConfig,
    sim_result_to_dict,
)


def _emit(obj: Dict[str, Any]) -> None:
    json.dump(obj, sys.stdout, sort_keys=True)
    sys.stdout.write("\n")
    sys.stdout.flush()


def _config_from_args(args: argparse.Namespace) -> ServiceConfig:
    return ServiceConfig(
        scheduler=args.scheduler,
        policy=args.policy,
        profile=args.profile,
        repartition_mode=args.repartition_mode,
        initial_config=args.initial_config,
        checkpoint_every_min=args.checkpoint_every_min,
        wal_fsync=args.wal_fsync,
        fleet_profiles=tuple(args.fleet) if args.fleet else None,
        dispatcher=args.dispatcher,
    )


def _add_config_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--scheduler", default="EDF-SS")
    p.add_argument(
        "--policy", default="daynight", help=f"one of {', '.join(POLICY_SPECS)}"
    )
    p.add_argument("--profile", default="a100-250w")
    p.add_argument("--repartition-mode", default="partial",
                   choices=("partial", "drain"))
    p.add_argument("--initial-config", type=int, default=None)
    p.add_argument("--checkpoint-every-min", type=float, default=60.0,
                   help="sim-minutes between checkpoints (0 disables)")
    p.add_argument("--wal-fsync", action="store_true")
    p.add_argument("--fleet", nargs="*", default=None,
                   help="device profile names; omit for a single device")
    p.add_argument("--dispatcher", default="least-loaded")


def _cmd_serve(args: argparse.Namespace) -> int:
    clock = (
        ReplayClock(speedup=args.speedup) if args.speedup > 0 else ReplayClock.free()
    )
    service = SchedulerService(
        args.dir,
        None if args.recover_only else _config_from_args(args),
        clock=clock,
    )
    _emit(
        {
            "serving": True,
            "socket": args.socket,
            "dir": args.dir,
            "recovered_ops": service.recovered_ops,
            "t": service.applied_until,
        }
    )
    ServiceServer(service, args.socket).serve_forever()
    return 0


def _cmd_replay(args: argparse.Namespace) -> int:
    from repro.core.scenarios import generate_scenario

    jobs = generate_scenario(args.scenario, args.seed)
    if args.max_jobs is not None:
        jobs = jobs[: args.max_jobs]
    service = SchedulerService(args.dir, _config_from_args(args))
    fed = skipped = 0
    for job in jobs:
        if job.job_id in service.known_jobs:
            skipped += 1
            continue
        service.submit(job)
        fed += 1
        if args.pace_ms > 0:
            time.sleep(args.pace_ms / 1000.0)
    if not service.closed:
        service.close()
    res = service.result()
    service.shutdown()
    _emit(
        {
            "replayed": True,
            "fed": fed,
            "skipped": skipped,
            "recovered_ops": service.recovered_ops,
            "result": sim_result_to_dict(res),
        }
    )
    return 0


def _client_cmd(args: argparse.Namespace) -> int:
    client = ServiceClient(args.socket)
    try:
        if args.cmd == "submit":
            fields: Dict[str, Any] = {"work": args.work, "kind": args.kind,
                                      "elasticity": args.elasticity}
            if args.deadline is not None:
                fields["deadline"] = args.deadline
            else:
                fields["deadline_slack_min"] = args.deadline_slack
            if args.arrival is not None:
                fields["arrival"] = args.arrival
            if args.job is not None:
                fields["job_id"] = args.job
            if args.tenant is not None:
                fields["tenant"] = args.tenant
            if args.slo_min is not None:
                fields["slo_min"] = args.slo_min
            out = client.submit(**fields)
        elif args.cmd == "status":
            out = {"status": client.status(args.job)}
        elif args.cmd == "cancel":
            out = client.cancel(args.job)
        elif args.cmd == "reconfigure":
            out = client.reconfigure(args.config, args.device)
        elif args.cmd == "checkpoint":
            out = {"checkpoint": client.checkpoint()}
        elif args.cmd == "close":
            out = {"result": client.close_stream()}
        elif args.cmd == "result":
            out = {"result": client.result()}
        elif args.cmd == "shutdown":
            out = client.shutdown()
        else:  # pragma: no cover - argparse prevents this
            raise ValueError(args.cmd)
    finally:
        client.close()
    out.pop("ok", None)
    _emit(out)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.service",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("serve", help="run the service behind a unix socket")
    p.add_argument("--dir", required=True)
    p.add_argument("--socket", required=True)
    p.add_argument("--speedup", type=float, default=60.0,
                   help="sim-minutes per wall-minute; 0 = op-driven time")
    p.add_argument("--recover-only", action="store_true",
                   help="refuse to create a fresh service (must recover)")
    _add_config_args(p)

    p = sub.add_parser("replay", help="feed a scenario through an in-process service")
    p.add_argument("--dir", required=True)
    p.add_argument("--scenario", default="trace-scaled")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-jobs", type=int, default=None)
    p.add_argument("--pace-ms", type=float, default=0.0,
                   help="wall-ms to sleep between submissions (crash tests)")
    _add_config_args(p)

    for name, hlp in (
        ("submit", "submit one job"),
        ("status", "service summary or one job's disposition"),
        ("cancel", "cancel a job"),
        ("reconfigure", "manually repartition a device"),
        ("checkpoint", "force a checkpoint now"),
        ("close", "end the stream, drain, print the final result"),
        ("result", "print the final result (after close)"),
        ("shutdown", "checkpoint and stop the server"),
    ):
        p = sub.add_parser(name, help=hlp)
        p.add_argument("--socket", required=True)
        if name == "submit":
            p.add_argument("--work", type=float, default=10.0)
            p.add_argument("--kind", default="inference",
                           choices=("inference", "training"))
            p.add_argument("--elasticity", default="linear")
            p.add_argument("--deadline", type=float, default=None)
            p.add_argument("--deadline-slack", type=float, default=60.0)
            p.add_argument("--arrival", type=float, default=None)
            p.add_argument("--job", type=int, default=None)
            p.add_argument("--tenant", default=None)
            p.add_argument("--slo-min", type=float, default=None)
        elif name in ("status", "cancel"):
            p.add_argument("--job", type=int,
                           default=None, required=(name == "cancel"))
        elif name == "reconfigure":
            p.add_argument("--config", type=int, required=True)
            p.add_argument("--device", type=int, default=0)

    args = ap.parse_args(argv)
    if args.cmd == "serve":
        return _cmd_serve(args)
    if args.cmd == "replay":
        return _cmd_replay(args)
    return _client_cmd(args)


if __name__ == "__main__":
    sys.exit(main())
