"""Unix-socket JSON-lines front end for :class:`SchedulerService`.

Protocol: one JSON object per line in each direction.  Requests carry a
``cmd`` plus command-specific fields; responses are ``{"ok": true, ...}``
or ``{"ok": false, "error": "..."}`` (the error string is the service's
exception message — which, per the engine contract, names the sim time,
the job id, and the remedy).

The server is **single-threaded** (a ``selectors`` loop): ops are applied
and logged in one frame, which is what lets a checkpoint never observe a
half-applied op.  Between socket events the loop runs the service's idle
tick (advancing the replay clock and the checkpoint cadence).

Commands
--------
``ping`` · ``submit`` (job fields; see ``submit_request``) · ``cancel``
(``job_id``) · ``reconfigure`` (``config``, optional ``device``) ·
``status`` (optional ``job_id``) · ``checkpoint`` · ``close`` (drains;
returns the final result) · ``result`` · ``shutdown`` (checkpoint + exit).
"""

from __future__ import annotations

import json
import os
import selectors
import socket
from pathlib import Path
from typing import Any, Dict, Optional, Union

from repro.service.service import SchedulerService, sim_result_to_dict

__all__ = ["ServiceServer", "ServiceClient", "wait_for_socket"]


class ServiceServer:
    """Serve one :class:`SchedulerService` over a unix socket."""

    def __init__(
        self,
        service: SchedulerService,
        socket_path: Union[str, Path],
        *,
        tick_interval_s: float = 0.05,
    ) -> None:
        self.service = service
        self.socket_path = Path(socket_path)
        self.tick_interval_s = tick_interval_s
        self._stop = False

    # -- request handling ------------------------------------------------

    def handle(self, req: Dict[str, Any]) -> Dict[str, Any]:
        """Dispatch one request dict; never raises (errors are responses)."""
        try:
            return {"ok": True, **self._dispatch(req)}
        except Exception as e:  # noqa: BLE001 — every service error is a reply
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _dispatch(self, req: Dict[str, Any]) -> Dict[str, Any]:
        cmd = req.get("cmd")
        svc = self.service
        if cmd == "ping":
            return {"pong": True, "t": svc.applied_until}
        if cmd == "submit":
            fields = {k: v for k, v in req.items() if k != "cmd"}
            return svc.submit_request(fields)
        if cmd == "cancel":
            return svc.cancel(int(req["job_id"]))
        if cmd == "reconfigure":
            return svc.reconfigure(int(req["config"]), int(req.get("device", 0)))
        if cmd == "status":
            return {"status": svc.status(req.get("job_id"))}
        if cmd == "checkpoint":
            return {"checkpoint": str(svc.checkpoint())}
        if cmd == "close":
            svc.close()
            return {"result": sim_result_to_dict(svc.result())}
        if cmd == "result":
            return {"result": sim_result_to_dict(svc.result())}
        if cmd == "shutdown":
            self._stop = True
            return {"stopping": True}
        raise ValueError(
            f"unknown command {cmd!r}; valid: ping, submit, cancel, "
            f"reconfigure, status, checkpoint, close, result, shutdown"
        )

    # -- event loop ------------------------------------------------------

    def serve_forever(self) -> None:
        """Accept clients until a ``shutdown`` request arrives.

        On exit the service is checkpointed and the socket removed; a
        SIGKILL skips all of that — which is exactly the crash the WAL
        protocol recovers from.
        """
        if self.socket_path.exists():
            self.socket_path.unlink()
        sel = selectors.DefaultSelector()
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        srv.bind(str(self.socket_path))
        srv.listen(64)
        srv.setblocking(False)
        sel.register(srv, selectors.EVENT_READ, data=None)
        buffers: Dict[socket.socket, bytes] = {}
        try:
            while not self._stop:
                for key, _ in sel.select(timeout=self.tick_interval_s):
                    if key.data is None:
                        conn, _ = srv.accept()
                        conn.setblocking(False)
                        buffers[conn] = b""
                        sel.register(conn, selectors.EVENT_READ, data="conn")
                        continue
                    conn = key.fileobj
                    try:
                        chunk = conn.recv(65536)
                    except ConnectionError:
                        chunk = b""
                    if not chunk:
                        sel.unregister(conn)
                        conn.close()
                        buffers.pop(conn, None)
                        continue
                    buffers[conn] += chunk
                    while b"\n" in buffers[conn]:
                        line, buffers[conn] = buffers[conn].split(b"\n", 1)
                        if not line.strip():
                            continue
                        try:
                            req = json.loads(line)
                        except json.JSONDecodeError as e:
                            resp = {"ok": False, "error": f"bad JSON: {e}"}
                        else:
                            resp = self.handle(req)
                        conn.sendall(
                            json.dumps(resp, sort_keys=True).encode() + b"\n"
                        )
                        if self._stop:
                            break
                self.service.tick()
        finally:
            for conn in list(buffers):
                conn.close()
            sel.close()
            srv.close()
            if self.socket_path.exists():
                self.socket_path.unlink()
            self.service.shutdown()


class ServiceClient:
    """Line-oriented client for :class:`ServiceServer` (CLI + load tests)."""

    def __init__(self, socket_path: Union[str, Path], timeout: float = 30.0) -> None:
        self.socket_path = str(socket_path)
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.settimeout(timeout)
        self._sock.connect(self.socket_path)
        self._buf = b""

    def request(self, req: Dict[str, Any]) -> Dict[str, Any]:
        """Send one request and block for its response line.

        Raises :class:`RuntimeError` with the server's error message when
        the response carries ``ok=False``.
        """
        self._sock.sendall(json.dumps(req).encode() + b"\n")
        while b"\n" not in self._buf:
            chunk = self._sock.recv(65536)
            if not chunk:
                raise ConnectionError("server closed the connection")
            self._buf += chunk
        line, self._buf = self._buf.split(b"\n", 1)
        resp = json.loads(line)
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error", "unknown server error"))
        return resp

    # convenience wrappers ------------------------------------------------

    def ping(self) -> Dict[str, Any]:
        return self.request({"cmd": "ping"})

    def submit(self, **fields: Any) -> Dict[str, Any]:
        return self.request({"cmd": "submit", **fields})

    def cancel(self, job_id: int) -> Dict[str, Any]:
        return self.request({"cmd": "cancel", "job_id": job_id})

    def reconfigure(self, config: int, device: int = 0) -> Dict[str, Any]:
        return self.request(
            {"cmd": "reconfigure", "config": config, "device": device}
        )

    def status(self, job_id: Optional[int] = None) -> Dict[str, Any]:
        req: Dict[str, Any] = {"cmd": "status"}
        if job_id is not None:
            req["job_id"] = job_id
        return self.request(req)["status"]

    def close_stream(self) -> Dict[str, Any]:
        return self.request({"cmd": "close"})["result"]

    def result(self) -> Dict[str, Any]:
        return self.request({"cmd": "result"})["result"]

    def checkpoint(self) -> str:
        return self.request({"cmd": "checkpoint"})["checkpoint"]

    def shutdown(self) -> Dict[str, Any]:
        return self.request({"cmd": "shutdown"})

    def close(self) -> None:
        self._sock.close()


def wait_for_socket(path: Union[str, Path], timeout_s: float = 10.0) -> None:
    """Block until a server socket exists and accepts (test/bench helper)."""
    import time

    deadline = time.monotonic() + timeout_s  # lint: waive[DT002] test-helper poll deadline
    last: Optional[Exception] = None
    while time.monotonic() < deadline:  # lint: waive[DT002] test-helper poll loop
        if os.path.exists(path):
            try:
                ServiceClient(path, timeout=2.0).close()
                return
            except OSError as e:
                last = e
        time.sleep(0.02)
    raise TimeoutError(f"no server on {path} after {timeout_s}s: {last}")
