"""Atomic, rotated engine checkpoints for the scheduler service.

A checkpoint is an opaque pickle blob (built by
:meth:`repro.service.SchedulerService.checkpoint`) named by the op
sequence number it covers: ``ckpt-000000000042.pkl`` means "service state
after applying WAL op 42".  Writes are atomic (tmp + fsync +
``os.replace``), so the store never holds a half-written snapshot; the
newest ``keep`` checkpoints are retained and older ones pruned, bounding
disk usage over long runs while keeping one fallback should the newest
blob fail to unpickle after a code change.
"""

from __future__ import annotations

import os
import re
from pathlib import Path
from typing import List, Optional, Tuple, Union

__all__ = ["CheckpointStore"]

_NAME = re.compile(r"^ckpt-(\d{12})\.pkl$")


class CheckpointStore:
    """Directory of ``ckpt-<seq>.pkl`` blobs; see module docstring."""

    def __init__(self, directory: Union[str, Path], keep: int = 2) -> None:
        if keep < 1:
            raise ValueError(f"must keep at least one checkpoint, got keep={keep}")
        self.directory = Path(directory)
        self.keep = keep

    def _entries(self) -> List[Tuple[int, Path]]:
        out = []
        for p in self.directory.iterdir():
            m = _NAME.match(p.name)
            if m:
                out.append((int(m.group(1)), p))
        out.sort()
        return out

    def save(self, blob: bytes, seq: int) -> Path:
        """Atomically write the blob as the checkpoint covering op ``seq``."""
        path = self.directory / f"ckpt-{seq:012d}.pkl"
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as fh:
            fh.write(blob)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
        for _, old in self._entries()[: -self.keep]:
            old.unlink()
        return path

    def latest(self) -> Optional[Tuple[int, bytes]]:
        """The newest checkpoint as ``(seq, blob)``, or None when empty."""
        entries = self._entries()
        if not entries:
            return None
        seq, path = entries[-1]
        return seq, path.read_bytes()
