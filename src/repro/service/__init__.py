"""Long-running scheduler service with WAL-backed crash recovery.

The durable front end over the steppable engine (docs/SERVICE.md,
DESIGN.md §10):

* :class:`SchedulerService` — submit/status/cancel/reconfigure over one
  stream-open :class:`~repro.core.engine.SimulationEngine` or a
  :class:`~repro.fleet.simulator.FleetStream`, with an append-only WAL,
  periodic pickled checkpoints, and snapshot+tail recovery that is
  bit-identical to an uninterrupted run;
* :class:`ServiceServer` / :class:`ServiceClient` — a single-threaded
  unix-socket JSON-lines front end (``python -m repro.service serve``);
* :func:`make_policy` — the registry of picklable repartition policies a
  durable service may run (``static``/``nomig``/``daynight``/
  ``heuristic``/``forecast``);
* :class:`ServiceStats` — incremental result aggregates that reproduce
  ``engine.result()`` float-for-float after jobs are folded out of the
  engine to bound memory;
* :class:`ReplayClock`, :class:`WriteAheadLog`, :class:`CheckpointStore` —
  the pacing and durability primitives.
"""

from repro.service.checkpoint import CheckpointStore
from repro.service.clock import ReplayClock
from repro.service.records import (
    WAL_FORMAT,
    job_from_dict,
    job_to_dict,
    validate_record,
)
from repro.service.server import ServiceClient, ServiceServer, wait_for_socket
from repro.service.service import (
    POLICY_SPECS,
    SchedulerService,
    ServiceConfig,
    ServiceStats,
    make_policy,
    sim_result_to_dict,
)
from repro.service.wal import WriteAheadLog, read_wal

__all__ = [
    "CheckpointStore",
    "POLICY_SPECS",
    "ReplayClock",
    "SchedulerService",
    "ServiceClient",
    "ServiceConfig",
    "ServiceServer",
    "ServiceStats",
    "WAL_FORMAT",
    "WriteAheadLog",
    "job_from_dict",
    "job_to_dict",
    "make_policy",
    "read_wal",
    "sim_result_to_dict",
    "validate_record",
    "wait_for_socket",
]
