"""Append-only write-ahead log of service operations (docs/SERVICE.md).

One JSON object per line (see :mod:`repro.service.records` for the schema).
The log is the service's durability primitive:

* :meth:`WriteAheadLog.append` writes + flushes one record (``fsync``
  optionally, per the service config) **before** the op is acknowledged to
  the client — an acked op survives a crash;
* :func:`read_wal` tolerates a *torn tail*: a crash mid-``write`` can leave
  a truncated final line, which is dropped (the op it was recording was
  never acknowledged).  Corruption anywhere *before* the final line is a
  hard error — that is not a crash artifact;
* :meth:`WriteAheadLog.rotate` atomically replaces the log's contents
  (tmp file + ``os.replace``) — the checkpoint path truncates the log to
  the records not yet covered by the latest snapshot, keeping WAL size
  bounded over multi-day runs (pinned by the soak test).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Iterable, List, Union

__all__ = ["WriteAheadLog", "read_wal"]


def _encode(record: Dict[str, Any]) -> str:
    return json.dumps(record, separators=(",", ":"), sort_keys=True)


def read_wal(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """Read every record, dropping at most one torn final line.

    A missing file reads as empty (a fresh service has appended nothing).
    A decode failure on any line but the last raises :class:`ValueError`
    naming the line — mid-file corruption is never silently skipped.
    """
    path = Path(path)
    if not path.exists():
        return []
    raw = path.read_text(encoding="utf-8")
    lines = raw.split("\n")
    records: List[Dict[str, Any]] = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as e:
            # only the final non-empty line may be torn (crash mid-append);
            # anything earlier is real corruption
            rest = "".join(lines[i + 1:]).strip()
            if rest:
                raise ValueError(
                    f"WAL {path} corrupted at line {i + 1} (not the tail): {e}"
                ) from e
            break
    return records


class WriteAheadLog:
    """Append handle over one WAL file; see module docstring."""

    def __init__(self, path: Union[str, Path], *, fsync: bool = False) -> None:
        self.path = Path(path)
        self.fsync = fsync
        self._fh = open(self.path, "a", encoding="utf-8")

    def append(self, record: Dict[str, Any]) -> None:
        """Durably append one record (flush always, fsync per config)."""
        self._fh.write(_encode(record) + "\n")
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())

    def rotate(self, records: Iterable[Dict[str, Any]]) -> None:
        """Atomically replace the log's contents with ``records``.

        The checkpoint path calls this with the (usually empty) tail of
        records newer than the snapshot just written; a crash during
        rotation leaves either the old or the new file, never a mix.
        """
        self._fh.close()
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "w", encoding="utf-8") as fh:
            for rec in records:
                fh.write(_encode(rec) + "\n")
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.path)
        self._fh = open(self.path, "a", encoding="utf-8")

    def size_bytes(self) -> int:
        """Current on-disk size (the soak test's WAL-bound probe)."""
        self._fh.flush()
        return self.path.stat().st_size

    def close(self) -> None:
        self._fh.close()
