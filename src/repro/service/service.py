"""The long-running scheduler service (docs/SERVICE.md, DESIGN.md §10).

:class:`SchedulerService` wraps one stream-open
:class:`~repro.core.engine.SimulationEngine` (or, in fleet mode, a
:class:`~repro.fleet.simulator.FleetStream`) behind a
submit / status / cancel / reconfigure API and makes it durable:

* every state-changing op is applied, then appended to a write-ahead log
  (:mod:`repro.service.wal`) **before** it is acknowledged;
* each op record carries the sim-time ``t`` it was applied at; applying an
  op always means *advance the engine to* ``t`` *(exclusive), then act* —
  the one protocol shared by the live path and replay;
* periodically the whole service state (engine included) is pickled into an
  atomic checkpoint (:mod:`repro.service.checkpoint`) and the WAL is
  truncated;
* crash recovery = newest checkpoint + WAL tail replay.  Because
  ``run_until`` is chunk-invariant (events are processed in time order no
  matter how the advances are sliced) and every op's effect depends only on
  engine state at its recorded ``t``, the recovered service is
  **bit-identical** to one that never crashed — the load-bearing invariant,
  pinned by ``tests/test_service_recovery.py``.

Idle ticks (:meth:`tick`) advance the engine to the replay clock's reading
but are *not* logged: by chunk-invariance they are invisible to the final
state, which is exactly why recovery doesn't need to reproduce wall-clock
pacing.

Memory stays bounded over multi-day streams: at every checkpoint (and at
close) completed/cancelled jobs are folded out of the engine
(:meth:`SimulationEngine.harvest_completed`) into :class:`ServiceStats`,
whose incremental math reproduces ``SimulationEngine.result()``
float-for-float (same additions, same order).
"""

from __future__ import annotations

import dataclasses
import json
import pickle
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.engine import SimulationEngine
from repro.core.jobs import Job, JobKind, elasticity_from_label
from repro.core.metrics import SimResult, TenantSLOStats
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    RepartitionPolicy,
    StaticPolicy,
)
from repro.core.slices import MIG_CONFIGS
from repro.fleet.devices import device_profile
from repro.fleet.simulator import (
    DeviceAdaptedPolicy,
    FleetResult,
    FleetSimulator,
    FleetSpec,
    FleetStream,
)
from repro.service.checkpoint import CheckpointStore
from repro.service.clock import ReplayClock
from repro.service.records import (
    WAL_FORMAT,
    job_from_dict,
    job_to_dict,
    validate_record,
)
from repro.service.wal import WriteAheadLog, read_wal

__all__ = [
    "POLICY_SPECS",
    "make_policy",
    "ServiceConfig",
    "ServiceStats",
    "SchedulerService",
    "sim_result_to_dict",
]

_HEADER = "service.json"
_WAL = "wal.jsonl"

#: policy spec grammar accepted by :func:`make_policy`
POLICY_SPECS = (
    "static[:CONFIG]",
    "nomig",
    "daynight[:DAY,NIGHT]",
    "heuristic",
    "forecast",
)


def make_policy(spec: str, *, repartition_mode: str = "partial") -> RepartitionPolicy:
    """Build a repartition policy from a registry spec string.

    Every policy this returns is picklable (a service checkpoint contains
    the policy's live state), which is why the service accepts specs, not
    policy objects — ``CallbackPolicy`` closures can't checkpoint.
    Each call returns a *fresh* instance: policies carry per-run state and
    must never be shared across devices.
    """
    name, _, arg = spec.partition(":")
    if name == "static":
        return StaticPolicy(config_id=int(arg) if arg else 3)
    if name == "nomig":
        return NoMIGPolicy()
    if name == "daynight":
        if arg:
            day, night = (int(x) for x in arg.split(","))
            return DayNightPolicy(day_config=day, night_config=night)
        return DayNightPolicy()
    if name == "heuristic":
        from repro.launch.cluster_sim import QueueHeuristicPolicy

        return QueueHeuristicPolicy()
    if name == "forecast":
        from repro.forecast.policy import ForecastPolicy

        return ForecastPolicy(repartition_mode=repartition_mode)
    raise ValueError(
        f"unknown policy spec {spec!r}; valid specs: {POLICY_SPECS}"
    )


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Immutable service configuration, persisted as the workdir header.

    ``fleet_profiles=None`` runs one device (``profile``); a tuple of
    profile names runs a fleet behind ``dispatcher``.  ``policy="nomig"``
    implies ``mig_enabled=False`` (the NoMIG benchmark semantics).
    ``checkpoint_every_min`` is in **sim** minutes; ``0`` disables the
    cadence (explicit :meth:`SchedulerService.checkpoint` still works).
    """

    scheduler: str = "EDF-SS"
    policy: str = "daynight"
    profile: str = "a100-250w"
    repartition_mode: str = "partial"
    initial_config: Optional[int] = None
    mig_enabled: bool = True
    checkpoint_every_min: float = 60.0
    wal_fsync: bool = False
    fleet_profiles: Optional[Tuple[str, ...]] = None
    dispatcher: str = "least-loaded"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if self.fleet_profiles is not None:
            d["fleet_profiles"] = list(self.fleet_profiles)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServiceConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"service header has unknown config keys {sorted(unknown)}; "
                f"this workdir was written by an incompatible version"
            )
        d = dict(d)
        if d.get("fleet_profiles") is not None:
            d["fleet_profiles"] = tuple(d["fleet_profiles"])
        return cls(**d)


@dataclasses.dataclass
class ServiceStats:
    """Running aggregates over harvested jobs (single-device mode).

    The fold is performed in completion order starting from the same
    zeros as :meth:`SimulationEngine.result`, so the incremental totals
    are *bit-identical* to the one-shot sums no matter how the stream of
    completions is chunked across checkpoints (left-fold float addition
    is associative-by-construction here because the addition sequence is
    literally the same).
    """

    SCHEMA_VERSION = 1  # bump when the field set below changes (repro.lint SD001/SD002)
    _schema_digest = "2623a1e3"

    num_completed: int = 0
    num_cancelled: int = 0
    total_tardiness: float = 0.0
    max_tardiness: float = 0.0
    deadline_misses: int = 0
    tenant_acc: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def fold(self, completed: List[Job], cancelled: List[Job]) -> None:
        """Absorb one harvest batch (jobs arrive in completion order)."""
        for j in completed:
            self.num_completed += 1
            tard = j.tardiness()
            self.total_tardiness += tard
            self.max_tardiness = max(self.max_tardiness, tard)
            if tard > 1e-9:
                self.deadline_misses += 1
            if j.tenant is not None:
                acc = self.tenant_acc.setdefault(j.tenant, [0, 0, 0.0])
                acc[0] += 1
                acc[1] += 1 if j.slo_attained() else 0
                acc[2] += j.latency()
        self.num_cancelled += len(cancelled)

    def result(self, sim: MIGSimulator) -> SimResult:
        """The final :class:`SimResult`, mirroring ``engine.result()``.

        ``sim`` supplies the device-side accumulators (energy, busy-slot
        integral, preemption/repartition counters, makespan) that are not
        per-job quantities.
        """
        if sim.active:
            raise RuntimeError(
                f"simulation ended with {len(sim.active)} unfinished jobs"
            )
        m = max(self.num_completed, 1)
        tenants = {
            name: TenantSLOStats(
                jobs=int(acc[0]), attained=int(acc[1]), latency_sum_min=acc[2]
            )
            for name, acc in sorted(self.tenant_acc.items())
        }
        extra = {
            "makespan_min": sim.t,
            "tardiness_integral": sim.tardiness_integral,
        }
        if self.num_cancelled:
            extra["cancelled_jobs"] = float(self.num_cancelled)
        return SimResult(
            energy_wh=sim.energy_wh,
            avg_tardiness=self.total_tardiness / m,
            num_jobs=self.num_completed,
            total_tardiness=self.total_tardiness,
            preemptions=sim.preemptions,
            repartitions=sim.repartitions,
            max_tardiness=self.max_tardiness,
            deadline_misses=self.deadline_misses,
            busy_slot_minutes=sim.busy_slot_minutes,
            extra=extra,
            tenants=tenants,
        )


def sim_result_to_dict(res: SimResult) -> Dict[str, Any]:
    """JSON-safe view of a :class:`SimResult` (CLI / server responses)."""
    return {
        "energy_wh": res.energy_wh,
        "avg_tardiness": res.avg_tardiness,
        "num_jobs": res.num_jobs,
        "total_tardiness": res.total_tardiness,
        "preemptions": res.preemptions,
        "repartitions": res.repartitions,
        "max_tardiness": res.max_tardiness,
        "deadline_misses": res.deadline_misses,
        "busy_slot_minutes": res.busy_slot_minutes,
        "extra": dict(res.extra),
        "tenants": {
            name: {
                "jobs": st.jobs,
                "attained": st.attained,
                "latency_sum_min": st.latency_sum_min,
            }
            for name, st in res.tenants.items()
        },
    }


class SchedulerService:
    """One durable scheduling session over a workdir; see module docstring.

    Constructing against an empty directory **creates** a fresh service
    (writing the config header); constructing against a directory that
    already holds a header **recovers** — newest checkpoint, then WAL tail.
    ``config`` may be omitted on recovery (the header's is used) and, if
    given, must match it.

    The service is single-threaded by design: ops are applied and logged
    in one call frame, so a checkpoint can never observe a half-applied
    operation.
    """

    def __init__(
        self,
        workdir: Union[str, Path],
        config: Optional[ServiceConfig] = None,
        *,
        clock: Optional[ReplayClock] = None,
        checkpoint_keep: int = 2,
    ) -> None:
        self.workdir = Path(workdir)
        self.workdir.mkdir(parents=True, exist_ok=True)
        header = self.workdir / _HEADER
        existing = header.exists()
        if existing:
            stored = ServiceConfig.from_dict(
                json.loads(header.read_text(encoding="utf-8"))["config"]
            )
            if config is not None and config != stored:
                raise ValueError(
                    f"workdir {self.workdir} already holds a service with a "
                    f"different config; recover it with config=None or use a "
                    f"fresh directory"
                )
            config = stored
        else:
            config = config if config is not None else ServiceConfig()
            header.write_text(
                json.dumps(
                    {"format": WAL_FORMAT, "config": config.to_dict()},
                    indent=2,
                    sort_keys=True,
                )
                + "\n",
                encoding="utf-8",
            )
        self.config = config
        self.clock = clock
        self.ckpts = CheckpointStore(self.workdir, keep=checkpoint_keep)

        # state (overwritten by a checkpoint restore below)
        self.stats = ServiceStats()
        self.job_state: Dict[int, Tuple[str, float]] = {}
        self.known_jobs: set = set()
        self._max_job_id = -1
        self.applied_seq = 0
        self.applied_until = 0.0
        self.closed = False

        snap = self.ckpts.latest() if existing else None
        if snap is not None:
            self._restore(snap[1])
        else:
            self.backend = _build_backend(config)
        self._fleet = isinstance(self.backend, FleetStream)

        #: ops replayed from the WAL tail at construction (0 = clean start)
        self.recovered_ops = 0
        if existing:
            prev_seq = self.applied_seq
            for rec in read_wal(self.workdir / _WAL):
                validate_record(rec)
                if rec["seq"] <= self.applied_seq:
                    continue  # already covered by the checkpoint
                if rec["seq"] <= prev_seq:
                    raise ValueError(
                        f"WAL seq {rec['seq']} out of order after {prev_seq}"
                    )
                prev_seq = rec["seq"]
                self._apply_op(rec)
                self.applied_seq = rec["seq"]
                self.applied_until = max(self.applied_until, float(rec["t"]))
                self.recovered_ops += 1
        self._next_seq = self.applied_seq + 1
        self.wal = WriteAheadLog(self.workdir / _WAL, fsync=config.wal_fsync)
        self._last_ckpt_t = self.applied_until
        if self.clock is not None:
            self.clock.resync(self.applied_until)

    # ------------------------------------------------------------------
    # construction helpers

    @classmethod
    def recover(
        cls,
        workdir: Union[str, Path],
        *,
        clock: Optional[ReplayClock] = None,
    ) -> "SchedulerService":
        """Recover an existing service (refuses a directory with none)."""
        if not (Path(workdir) / _HEADER).exists():
            raise FileNotFoundError(
                f"no service header in {workdir}; nothing to recover"
            )
        return cls(workdir, clock=clock)

    def _restore(self, blob: bytes) -> None:
        payload = pickle.loads(blob)
        if payload.get("format") != WAL_FORMAT:
            raise ValueError(
                f"checkpoint format {payload.get('format')} != {WAL_FORMAT}"
            )
        self.backend = payload["backend"]
        self.stats = payload["stats"]
        self.job_state = payload["job_state"]
        self.known_jobs = payload["known_jobs"]
        self._max_job_id = payload["max_job_id"]
        self.applied_seq = payload["applied_seq"]
        self.applied_until = payload["applied_until"]
        self.closed = payload["closed"]

    # ------------------------------------------------------------------
    # time

    def now(self) -> float:
        """The service's sim-time frontier: never before any applied op."""
        t = self.applied_until
        if self.clock is not None and self.clock.paced:
            t = max(t, self.clock.now())
        return t

    def _advance(self, t: float) -> int:
        """Advance the backend to ``t`` (exclusive) — the op protocol."""
        if self._fleet:
            return self.backend.run_until(t)
        return self.backend.run_until(t, inclusive=False)

    def _engines(self) -> List[SimulationEngine]:
        return self.backend.engines if self._fleet else [self.backend]

    # ------------------------------------------------------------------
    # the one apply path (live ops and WAL replay share it verbatim)

    def _apply_op(self, rec: Dict[str, Any]) -> Dict[str, Any]:
        op, t = rec["op"], float(rec["t"])
        self._advance(t)
        if op == "submit":
            job = job_from_dict(rec["job"])
            if self._fleet:
                device = self.backend.submit(job)
            else:
                self.backend.inject(job)
                device = 0
            self.known_jobs.add(job.job_id)
            self._max_job_id = max(self._max_job_id, job.job_id)
            return {"job_id": job.job_id, "device": device, "state": "submitted"}
        if op == "cancel":
            jid = int(rec["job_id"])
            disposition = self.backend.cancel(jid)
            self.job_state[jid] = ("cancelled", t)
            return {"job_id": jid, "disposition": disposition}
        if op == "reconfigure":
            cfg = int(rec["config"])
            dev = int(rec.get("device", 0))
            engines = self._engines()
            if not (0 <= dev < len(engines)):
                raise ValueError(
                    f"cannot reconfigure device {dev}: the service has "
                    f"{len(engines)} device(s)"
                )
            changed = engines[dev].reconfigure(cfg)
            return {"config": cfg, "device": dev, "changed": changed}
        # close: end the stream and drain every engine to completion
        if self._fleet:
            self.backend.close()
        else:
            self.backend.close_stream()
            self.backend.drain()
        self.closed = True
        self._harvest()
        self.applied_until = max(
            self.applied_until, max(e.sim.t for e in self._engines())
        )
        return {"closed": True, "t_final": self.applied_until}

    def _commit(self, rec: Dict[str, Any]) -> Dict[str, Any]:
        """Apply, then durably log, then acknowledge (in that order).

        Applying first means an invalid op (bad id, closed stream, config
        not in the table) raises *before* anything reaches the WAL — the
        log only ever contains ops that succeeded, so replay cannot fail
        where the live run did not.
        """
        out = self._apply_op(rec)
        rec["seq"] = self._next_seq
        self.wal.append(rec)
        self.applied_seq = rec["seq"]
        self._next_seq += 1
        self.applied_until = max(self.applied_until, float(rec["t"]))
        self._maybe_checkpoint()
        return out

    def _require_open(self, what: str) -> None:
        if self.closed:
            raise RuntimeError(
                f"cannot {what}: the service stream was closed at "
                f"t={self.applied_until}; results are final "
                f"(start a new workdir for a new session)"
            )

    # ------------------------------------------------------------------
    # public ops

    def submit(self, job: Job, *, restamp: bool = False) -> Dict[str, Any]:
        """Submit one job; returns ``{job_id, device, state}``.

        The arrival may not precede the service frontier (ops are applied
        in nondecreasing sim-time).  ``restamp=True`` (the server/CLI
        default) moves a too-early arrival up to the frontier, preserving
        the deadline *slack*; ``restamp=False`` (the replay/test path)
        rejects it instead.
        """
        self._require_open("submit")
        if job.job_id in self.known_jobs:
            raise ValueError(
                f"cannot submit job {job.job_id}: that id was already "
                f"submitted to this service; ids must be unique for the "
                f"lifetime of the workdir (check `status --job` first)"
            )
        floor = self.now()
        if job.arrival + 1e-9 < floor:
            if not restamp:
                raise ValueError(
                    f"cannot submit job {job.job_id}: arrival t={job.arrival} "
                    f"is before the service frontier t={floor}; pass "
                    f"restamp=True to stamp it at the frontier (slack "
                    f"preserved)"
                )
            job = dataclasses.replace(
                job,
                arrival=floor,
                deadline=job.deadline + (floor - job.arrival),
            )
        rec = {"op": "submit", "t": job.arrival, "job": job_to_dict(job)}
        return self._commit(rec)

    def submit_request(
        self, fields: Dict[str, Any], *, restamp: bool = True
    ) -> Dict[str, Any]:
        """Build a job from client-side fields and submit it (server path).

        Recognized fields: ``work`` (1g-minutes, default 10), ``kind``
        (``inference``/``training``), ``elasticity`` (label),
        ``deadline`` (absolute min) or ``deadline_slack_min`` (default 60,
        relative to arrival), ``arrival`` (default: the frontier),
        ``job_id`` (default: auto), ``speedup_no_mig``, ``tenant``,
        ``slo_min``.
        """
        arrival = float(fields.get("arrival", self.now()))
        deadline = fields.get("deadline")
        if deadline is None:
            deadline = arrival + float(fields.get("deadline_slack_min", 60.0))
        job = Job(
            job_id=int(fields.get("job_id", self._max_job_id + 1)),
            kind=JobKind(fields.get("kind", "inference")),
            arrival=arrival,
            work=float(fields.get("work", 10.0)),
            deadline=float(deadline),
            elasticity=elasticity_from_label(fields.get("elasticity", "linear")),
            speedup_no_mig=float(fields.get("speedup_no_mig", 1.0)),
            tenant=fields.get("tenant"),
            slo_min=fields.get("slo_min"),
        )
        return self.submit(job, restamp=restamp)

    def cancel(self, job_id: int) -> Dict[str, Any]:
        """Cancel a job; returns its disposition (see ``engine.cancel``).

        The service validates against its own lifetime records first: a
        job folded out by a harvest no longer exists inside the engine,
        whose error ("never injected") would be misleading here.
        """
        self._require_open("cancel")
        jid = int(job_id)
        if jid not in self.known_jobs:
            raise ValueError(
                f"cannot cancel job {jid}: it was never submitted to this "
                f"service; check `status --job {jid}` for its disposition"
            )
        terminal = self.job_state.get(jid)
        if terminal is not None:
            raise ValueError(
                f"cannot cancel job {jid}: it already reached terminal "
                f"state {terminal[0]!r} at t={terminal[1]}; only "
                f"pending/queued/running jobs can be cancelled"
            )
        return self._commit({"op": "cancel", "t": self.now(), "job_id": jid})

    def reconfigure(self, config: int, device: int = 0) -> Dict[str, Any]:
        """Manually repartition a device now (same stall as a policy move)."""
        self._require_open("reconfigure")
        return self._commit(
            {
                "op": "reconfigure",
                "t": self.now(),
                "config": int(config),
                "device": int(device),
            }
        )

    def close(self) -> Dict[str, Any]:
        """End the arrival stream and drain to completion (logged op)."""
        self._require_open("close")
        return self._commit({"op": "close", "t": self.now()})

    def tick(self) -> int:
        """Advance to the replay clock's reading; returns events processed.

        Not logged: chunk-invariance makes tick boundaries invisible to
        the final state, so replay needn't reproduce wall-clock pacing.
        Also runs the checkpoint cadence.
        """
        if self.closed:
            return 0
        t = self.now()
        n = 0
        if t > self.applied_until:
            n = self._advance(t)
            self.applied_until = t
        self._maybe_checkpoint()
        return n

    # ------------------------------------------------------------------
    # checkpointing / memory compaction

    def _harvest(self) -> None:
        """Fold finished jobs out of the engine into :class:`ServiceStats`.

        Single-device mode only: fleet engines keep their jobs so the
        fleet's per-device ``result()`` path stays intact.
        """
        if self._fleet:
            return
        done, cancelled = self.backend.harvest_completed()
        self.stats.fold(done, cancelled)
        for j in done:
            self.job_state[j.job_id] = ("completed", j.completion)
        for j in cancelled:
            # the cancel op already recorded the terminal state (keep its t)
            self.job_state.setdefault(j.job_id, ("cancelled", self.applied_until))

    def _maybe_checkpoint(self) -> None:
        every = self.config.checkpoint_every_min
        if every > 0 and self.applied_until - self._last_ckpt_t >= every:
            self.checkpoint()

    def checkpoint(self) -> Path:
        """Snapshot the full service state and truncate the WAL.

        Every logged op is applied before it is logged, so at this point
        the snapshot covers the entire WAL — rotation empties it.
        """
        self._harvest()
        payload = {
            "format": WAL_FORMAT,
            "applied_seq": self.applied_seq,
            "applied_until": self.applied_until,
            "closed": self.closed,
            "backend": self.backend,
            "stats": self.stats,
            "job_state": self.job_state,
            "known_jobs": self.known_jobs,
            "max_job_id": self._max_job_id,
        }
        try:
            blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            raise ValueError(
                f"service state is not picklable ({e}); checkpointing "
                f"requires registry policies/schedulers "
                f"(repro.service.make_policy)"
            ) from e
        path = self.ckpts.save(blob, self.applied_seq)
        self.wal.rotate(())
        self._last_ckpt_t = self.applied_until
        return path

    # ------------------------------------------------------------------
    # observation / results

    def status(self, job_id: Optional[int] = None) -> Dict[str, Any]:
        """Service summary, or one job's disposition when ``job_id`` given."""
        if job_id is not None:
            return self.job_status(int(job_id))
        snaps = [e.sim.snapshot() for e in self._engines()]
        live_cancelled = sum(len(e.sim.cancelled) for e in self._engines())
        return {
            "t": self.applied_until,
            "applied_seq": self.applied_seq,
            "closed": self.closed,
            "devices": len(snaps),
            "configs": [s.config_id for s in snaps],
            "submitted": len(self.known_jobs),
            "completed": self.stats.num_completed
            + sum(s.completed_jobs for s in snaps),
            "cancelled": self.stats.num_cancelled + live_cancelled,
            "queue_depth": sum(s.queue_depth for s in snaps),
            "running": sum(s.running for s in snaps),
            "energy_wh": sum(s.energy_wh for s in snaps),
            "recovered_ops": self.recovered_ops,
        }

    def job_status(self, job_id: int) -> Dict[str, Any]:
        """One job's disposition: pending/queued/running/completed/cancelled."""
        if job_id not in self.known_jobs:
            return {"job_id": job_id, "state": "unknown"}
        terminal = self.job_state.get(job_id)
        if terminal is not None:
            return {"job_id": job_id, "state": terminal[0], "t": terminal[1]}
        if self._fleet:
            device = self.backend.owner.get(job_id)
            state = (
                self.backend.engines[device].job_disposition(job_id)
                if device is not None
                else None
            )
        else:
            device = 0
            state = self.backend.job_disposition(job_id)
        return {"job_id": job_id, "state": state or "unknown", "device": device}

    def result(self) -> SimResult:
        """Final aggregate result; requires a closed (drained) stream."""
        if not self.closed:
            raise RuntimeError(
                "the service stream is still open; close() it (draining "
                "every queued job) before reading the final result"
            )
        if self._fleet:
            return self.backend.result().aggregate
        return self.stats.result(self.backend.sim)

    def fleet_result(self) -> FleetResult:
        """Full per-device fleet result (fleet mode only)."""
        if not self._fleet:
            raise RuntimeError(
                "this service runs a single device; use result()"
            )
        if not self.closed:
            raise RuntimeError(
                "the service stream is still open; close() it first"
            )
        return self.backend.result()

    def shutdown(self) -> None:
        """Checkpoint and release file handles (clean process exit)."""
        self.checkpoint()
        self.wal.close()


def _build_backend(config: ServiceConfig):
    """One stream-open engine, or a FleetStream, per the config."""
    mig_enabled = config.mig_enabled and config.policy.partition(":")[0] != "nomig"
    if config.fleet_profiles:
        spec = FleetSpec.of(
            config.fleet_profiles,
            dispatcher=config.dispatcher,
            scheduler=config.scheduler,
            repartition_mode=config.repartition_mode,
        )
        fleet = FleetSimulator(spec, mig_enabled=mig_enabled)
        policy_spec, mode = config.policy, config.repartition_mode
        return fleet.open_stream(
            lambda i, prof: make_policy(policy_spec, repartition_mode=mode)
        )
    prof = device_profile(config.profile)
    sim = MIGSimulator(
        make_scheduler(config.scheduler),
        power_model=prof.power,
        mig_enabled=mig_enabled,
        config_table=prof.configs,
        repartition_mode=config.repartition_mode,
    )
    policy = make_policy(config.policy, repartition_mode=config.repartition_mode)
    if set(prof.configs) != set(MIG_CONFIGS):
        policy = DeviceAdaptedPolicy(policy, prof.configs)
    return SimulationEngine(
        sim,
        policy=policy,
        initial_config=config.initial_config,
        stream_open=True,
    )
