"""R2 — JAX purity rules (JP001-JP004) over a lightweight call graph.

Scope: the modules that assemble jitted programs
(:data:`repro.lint.paths.R2_PATHS`).  The pass first resolves which
functions *reach a JAX trace*:

* **roots** — functions decorated with / passed to ``jax.jit``, ``vmap``,
  ``lax.scan``/``cond``/``while_loop``/``fori_loop``/``switch``,
  ``pl.pallas_call``, ``jax.grad`` …, including lambdas, ``partial(...)``
  wrappers, and the repo's factory idiom (``step = make_step_fn(...)`` →
  the inner def that ``make_step_fn`` returns is traced when ``step`` is
  passed to a transform);
* **transitive** — anything a traced function calls by name (resolved
  through enclosing scopes, module globals, and imports within the R2
  module set).

Inside traced functions it flags Python side effects (JP001),
tracer-dependent ``if``/``while`` (JP002), host casts
``float()/int()/bool()`` of traced values (JP003), and ``np.*`` calls on
traced arguments (JP004).

Tracedness of a *parameter* is a heuristic (static analysis cannot see
`static_argnames` reaching every call site), tuned to this repo:

* bodies handed to ``scan``/``vmap``/``cond``/``pallas_call`` have **all**
  params traced (JAX guarantees it), and attribute access on a param
  (``state.remaining``) counts as traced — scan carries are NamedTuples;
* ``jax.jit`` roots drop params named in ``static_argnames`` /
  positioned in ``static_argnums``;
* transitively-called helpers treat params as traced but ignore pure
  attribute access (``cfg.use_bias`` — config objects are closure-static
  in this codebase) and shape arithmetic (``x.shape``/``.ndim``/``.dtype``).

``is None`` / ``isinstance`` / ``hasattr`` tests are never flagged (static
under trace).  False positives that survive the heuristics get an inline
``# lint: waive[JP00x] reason``.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.lint.base import Violation
from repro.lint.determinism import _Imports

__all__ = ["check_purity"]

#: transforms whose first function argument may carry static params
_JIT_LIKE = {
    "jax.jit",
    "jax.checkpoint",
    "jax.remat",
    "jax.grad",
    "jax.value_and_grad",
}

#: dotted transform -> indices of function-valued positional args whose
#: params are all traced
_BODY_ARGS = {
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.associative_scan": (0,),
    "jax.lax.cond": (1, 2),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.experimental.pallas.pallas_call": (0,),
}

#: lax.switch(index, [branches], *operands): arg 1 is a list of functions
_SWITCH = "jax.lax.switch"

_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}
_STATIC_TESTS = {"isinstance", "hasattr", "callable", "len", "issubclass"}


@dataclasses.dataclass
class _Func:
    qualname: str
    node: ast.AST  # FunctionDef | Lambda
    params: Tuple[str, ...]
    #: params whose annotation marks them static (str/bool/int/float
    #: hyperparams, config objects) — see :func:`_annotation_static`
    annotated_static: Tuple[str, ...] = ()
    #: trace kind, set during root/propagation: None | "body" | "jit" | "called"
    kind: Optional[str] = None
    static_params: Tuple[str, ...] = ()
    #: names of inner defs this function returns (factory idiom)
    returns: Tuple[str, ...] = ()


#: annotations that mark a parameter as a static hyperparameter rather
#: than a traced array: Python scalars/strings and config-object types.
#: (A traced argument in this codebase is annotated jnp.ndarray/jax.Array/
#: Any or not at all.)
_STATIC_ANN = re.compile(
    r"^(typing\.)?(Optional\[)?(str|bool|int|float)\]?$"
    r"|^(typing\.)?Literal\["
    r"|Config\b|Spec\b"
)


def _annotation_static(ann: Optional[ast.expr]) -> bool:
    if ann is None:
        return False
    try:
        text = ast.unparse(ann).strip("\"'")
    except Exception:
        return False
    return bool(_STATIC_ANN.search(text))


def _annotated_static_params(args: ast.arguments) -> Tuple[str, ...]:
    out = []
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        if _annotation_static(a.annotation):
            out.append(a.arg)
    return tuple(out)


class _FileIndex(ast.NodeVisitor):
    """One file's functions, scope tables, and local aliases."""

    def __init__(self, path: str, module: str, tree: ast.AST) -> None:
        self.path = path
        self.module = module
        self.tree = tree
        self.imports = _Imports()
        self.funcs: Dict[str, _Func] = {}
        #: scope qualname ("" = module) -> {local name: func qualname}
        self.scopes: Dict[str, Dict[str, str]] = {"": {}}
        #: scope -> {var name: qualname of the factory whose result it holds}
        self.aliases: Dict[str, Dict[str, str]] = {"": {}}
        self._stack: List[str] = [""]
        self.visit(tree)

    # -- scope helpers -------------------------------------------------
    @property
    def _scope(self) -> str:
        return self._stack[-1]

    def _qual(self, name: str) -> str:
        return f"{self._scope}.{name}".lstrip(".")

    # -- collection ----------------------------------------------------
    def visit_Import(self, node):  # noqa: D102 - trivial
        self.imports.feed(node)

    def visit_ImportFrom(self, node):  # noqa: D102 - trivial
        self.imports.feed(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._push(node.name)
        self.generic_visit(node)
        self._stack.pop()

    def _push(self, name: str) -> None:
        q = self._qual(name)
        self._stack.append(q)
        self.scopes.setdefault(q, {})
        self.aliases.setdefault(q, {})

    def visit_FunctionDef(self, node) -> None:
        q = self._qual(node.name)
        params = _param_names(node.args)
        self.funcs[q] = _Func(q, node, params, _annotated_static_params(node.args))
        self.scopes[self._scope][node.name] = q
        self._push(node.name)
        self.generic_visit(node)
        # record `return inner_def` for the factory idiom
        rets = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Return) and isinstance(sub.value, ast.Name):
                target = self.lookup(sub.value.id, q)
                if target:
                    rets.append(target)
        self.funcs[q].returns = tuple(rets)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node: ast.Assign) -> None:
        # `step = make_step_fn(...)` — remember which factory built `step`
        if (
            len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and isinstance(node.value, ast.Call)
            and isinstance(node.value.func, ast.Name)
        ):
            factory = self.lookup(node.value.func.id, self._scope)
            if factory:
                self.aliases[self._scope][node.targets[0].id] = factory
        self.generic_visit(node)

    # -- resolution ----------------------------------------------------
    def lookup(self, name: str, scope: str) -> Optional[str]:
        """Resolve a bare name to a function qualname via the scope chain."""
        while True:
            hit = self.scopes.get(scope, {}).get(name)
            if hit:
                return hit
            if not scope:
                return None
            scope = scope.rpartition(".")[0]

    def lookup_alias(self, name: str, scope: str) -> Optional[str]:
        while True:
            hit = self.aliases.get(scope, {}).get(name)
            if hit:
                return hit
            if not scope:
                return None
            scope = scope.rpartition(".")[0]


def _param_names(args: ast.arguments) -> Tuple[str, ...]:
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return tuple(names)


def _static_argnames(call: ast.Call) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    names: List[str] = []
    nums: List[int] = []
    for kw in call.keywords:
        vals: Sequence[ast.expr]
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            vals = kw.value.elts
        else:
            vals = [kw.value]
        if kw.arg == "static_argnames":
            names.extend(
                v.value for v in vals if isinstance(v, ast.Constant) and isinstance(v.value, str)
            )
        elif kw.arg == "static_argnums":
            nums.extend(
                v.value for v in vals if isinstance(v, ast.Constant) and isinstance(v.value, int)
            )
    return tuple(names), tuple(nums)


class _Analyzer:
    """Whole-module-set analysis: roots, propagation, then body checks."""

    def __init__(self, files: Dict[str, Tuple[str, ast.AST]]) -> None:
        # files: rel_path -> (module dotted name, tree)
        self.index: Dict[str, _FileIndex] = {}
        self.by_module: Dict[str, _FileIndex] = {}
        for path, (module, tree) in files.items():
            idx = _FileIndex(path, module, tree)
            self.index[path] = idx
            self.by_module[module] = idx
        self._lambda_seq = 0

    # -- phase 1: roots ------------------------------------------------
    def find_roots(self) -> None:
        for idx in self.index.values():
            for scope, node in _walk_scoped(idx):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{scope}.{node.name}".lstrip(".")
                    for dec in node.decorator_list:
                        self._maybe_decorator_root(idx, q, dec)
                elif isinstance(node, ast.Call):
                    self._maybe_transform_call(idx, scope, node)

    def _maybe_decorator_root(self, idx: _FileIndex, q: str, dec: ast.expr) -> None:
        target = dec
        statics: Tuple[Tuple[str, ...], Tuple[int, ...]] = ((), ())
        if isinstance(dec, ast.Call):
            dotted = idx.imports.resolve(dec.func)
            if dotted == "functools.partial" and dec.args:
                inner = idx.imports.resolve(dec.args[0])
                if inner in _JIT_LIKE:
                    statics = _static_argnames(dec)
                    self._mark(idx, q, "jit", statics)
                return
            target = dec.func
            statics = _static_argnames(dec)
        dotted = idx.imports.resolve(target)
        if dotted in _JIT_LIKE:
            self._mark(idx, q, "jit", statics)

    def _maybe_transform_call(self, idx: _FileIndex, scope: str, call: ast.Call) -> None:
        dotted = idx.imports.resolve(call.func)
        if dotted is None:
            return
        # partial(jax.jit, ...)(f) unwrapping is rare enough to skip; the
        # decorator form above covers the repo's usage.
        if dotted in _JIT_LIKE:
            statics = _static_argnames(call)
            if call.args:
                self._mark_expr(idx, scope, call.args[0], "jit", statics)
        elif dotted in _BODY_ARGS:
            for i in _BODY_ARGS[dotted]:
                if i < len(call.args):
                    self._mark_expr(idx, scope, call.args[i], "body", ((), ()))
        elif dotted == _SWITCH and len(call.args) >= 2:
            branches = call.args[1]
            elts = branches.elts if isinstance(branches, (ast.List, ast.Tuple)) else [branches]
            for e in elts:
                self._mark_expr(idx, scope, e, "body", ((), ()))

    def _mark_expr(self, idx, scope, expr, kind, statics) -> None:
        if isinstance(expr, ast.Call):
            # partial(f, ...) or factory(...) used inline
            dotted = idx.imports.resolve(expr.func)
            if dotted == "functools.partial" and expr.args:
                self._mark_expr(idx, scope, expr.args[0], kind, statics)
            elif isinstance(expr.func, ast.Name):
                factory = idx.lookup(expr.func.id, scope)
                if factory:
                    for ret in idx.funcs[factory].returns:
                        self._mark(idx, ret, kind, statics)
            return
        if isinstance(expr, ast.Lambda):
            self._lambda_seq += 1
            q = f"<lambda#{self._lambda_seq}@{expr.lineno}>"
            idx.funcs[q] = _Func(q, expr, _param_names(expr.args))
            self._mark(idx, q, kind, statics)
            return
        if isinstance(expr, ast.Name):
            q = idx.lookup(expr.id, scope)
            if q:
                self._mark(idx, q, kind, statics)
                return
            factory = idx.lookup_alias(expr.id, scope)
            if factory:  # step = make_step_fn(...); vmap(step, ...)
                for ret in idx.funcs[factory].returns:
                    self._mark(idx, ret, kind, statics)
                return
            imported = idx.imports.resolve(expr)
            if imported:
                self._mark_imported(imported, kind, statics)
        elif isinstance(expr, ast.Attribute):
            imported = idx.imports.resolve(expr)
            if imported:
                self._mark_imported(imported, kind, statics)

    def _mark_imported(self, dotted: str, kind, statics) -> None:
        module, _, name = dotted.rpartition(".")
        idx = self.by_module.get(module)
        if idx and name in idx.scopes.get("", {}):
            self._mark(idx, idx.scopes[""][name], kind, statics)

    def _mark(self, idx: _FileIndex, q: str, kind: str, statics) -> None:
        fn = idx.funcs.get(q)
        if fn is None:
            return
        # "body" is the strictest kind; never downgrade it
        if fn.kind == "body":
            return
        if fn.kind is None or kind == "body":
            fn.kind = kind
            names, nums = statics
            static = set(names)
            for i in nums:
                if i < len(fn.params):
                    static.add(fn.params[i])
            fn.static_params = tuple(sorted(static))

    # -- phase 2: propagation -----------------------------------------
    def propagate(self) -> None:
        work = [
            (idx, q)
            for idx in self.index.values()
            for q, fn in idx.funcs.items()
            if fn.kind is not None
        ]
        seen: Set[Tuple[str, str]] = {(idx.path, q) for idx, q in work}
        while work:
            idx, q = work.pop()
            fn = idx.funcs[q]
            if isinstance(fn.node, ast.Lambda):
                body: List[ast.AST] = [fn.node.body]
            else:
                body = fn.node.body
            for stmt in body:
                for sub in ast.walk(stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    tgt = self._resolve_callee(idx, q, sub.func)
                    if tgt is None:
                        continue
                    tidx, tq = tgt
                    if tidx.funcs[tq].kind is None and (tidx.path, tq) not in seen:
                        tidx.funcs[tq].kind = "called"
                        seen.add((tidx.path, tq))
                        work.append((tidx, tq))

    def _resolve_callee(self, idx, scope, func_expr):
        if isinstance(func_expr, ast.Name):
            q = idx.lookup(func_expr.id, scope)
            if q:
                return idx, q
            imported = idx.imports.resolve(func_expr)
            if imported:
                module, _, name = imported.rpartition(".")
                tidx = self.by_module.get(module)
                if tidx and name in tidx.scopes.get("", {}):
                    return tidx, tidx.scopes[""][name]
        elif isinstance(func_expr, ast.Attribute):
            imported = idx.imports.resolve(func_expr)
            if imported:
                module, _, name = imported.rpartition(".")
                tidx = self.by_module.get(module)
                if tidx and name in tidx.scopes.get("", {}):
                    return tidx, tidx.scopes[""][name]
        return None

    # -- phase 3: checks ----------------------------------------------
    def check(self) -> List[Violation]:
        out: List[Violation] = []
        for idx in self.index.values():
            for fn in idx.funcs.values():
                if fn.kind is not None:
                    out.extend(_check_traced(idx, fn))
        return out


def _walk_scoped(idx: _FileIndex):
    """Yield (enclosing scope qualname, node) over the whole file."""

    def rec(node: ast.AST, scope: str):
        for child in ast.iter_child_nodes(node):
            yield scope, child
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                yield from rec(child, f"{scope}.{child.name}".lstrip("."))
            else:
                yield from rec(child, scope)

    yield from rec(idx.tree, "")


def _refs_traced(expr: ast.expr, traced: Set[str], *, attr_is_traced: bool) -> bool:
    """Does this expression reference a traced parameter?

    Attribute chains rooted at a traced param count only when
    ``attr_is_traced`` (scan carries yes, config objects no); shape/dtype
    attributes never count.
    """

    def rec(node: ast.AST, under_attr: bool) -> bool:
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False
            return rec(node.value, True)
        if isinstance(node, ast.Name):
            if node.id not in traced:
                return False
            return attr_is_traced if under_attr else True
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _STATIC_TESTS:
                return False
            subs = list(node.args) + [k.value for k in node.keywords]
            if isinstance(f, ast.Attribute):
                subs.append(f.value)  # x.sum() on a traced x counts
            return any(rec(s, under_attr) for s in subs)
        return any(rec(c, under_attr) for c in ast.iter_child_nodes(node))

    return rec(expr, False)


def _is_static_test(test: ast.expr) -> bool:
    """`x is None` / isinstance-style tests are static under tracing."""
    if isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    ):
        return True
    # `"key" in params_dict` — pytree *structure* is static under trace
    if (
        isinstance(test, ast.Compare)
        and all(isinstance(op, (ast.In, ast.NotIn)) for op in test.ops)
        and isinstance(test.left, ast.Constant)
    ):
        return True
    if isinstance(test, ast.Call) and isinstance(test.func, ast.Name):
        if test.func.id in _STATIC_TESTS:
            return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_static_test(test.operand)
    if isinstance(test, ast.BoolOp):
        return all(_is_static_test(v) for v in test.values)
    return False


def _calls_jnp(expr: ast.expr, imports: _Imports) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            dotted = imports.resolve(sub.func)
            if dotted and (dotted.startswith("jax.") or dotted == "jax"):
                return True
    return False


def _check_traced(idx: _FileIndex, fn: _Func) -> List[Violation]:
    out: List[Violation] = []
    traced = set(fn.params) - set(fn.static_params) - set(fn.annotated_static)
    attr_traced = fn.kind == "body"
    path = idx.path

    if isinstance(fn.node, ast.Lambda):
        stmts: List[ast.AST] = [fn.node.body]
    else:
        stmts = list(fn.node.body)

    def walk_no_nested(nodes):
        # nested defs/lambdas are checked via their own traced entry (if
        # they are traced at all) — never as part of the parent's body
        stack = [
            n for n in nodes
            if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
        ]
        while stack:
            n = stack.pop()
            yield n
            for c in ast.iter_child_nodes(n):
                if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    continue
                stack.append(c)

    for node in walk_no_nested(stmts):
        # JP001 — Python side effects
        if isinstance(node, ast.Global):
            out.append(
                Violation(
                    "JP001", path, node.lineno, node.col_offset,
                    f"`global` write inside traced function {fn.qualname!r} — "
                    f"jitted code must be pure (runs once at trace time)",
                )
            )
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            if node.func.id in {"print", "open", "input"}:
                out.append(
                    Violation(
                        "JP001", path, node.lineno, node.col_offset,
                        f"{node.func.id}() inside traced function "
                        f"{fn.qualname!r} executes at trace time only; use "
                        f"jax.debug.print / host_callback if intended",
                    )
                )
            # JP003 — host casts of traced values
            elif node.func.id in {"float", "int", "bool"} and node.args:
                if _refs_traced(node.args[0], traced, attr_is_traced=attr_traced):
                    out.append(
                        Violation(
                            "JP003", path, node.lineno, node.col_offset,
                            f"{node.func.id}() of traced value in "
                            f"{fn.qualname!r} forces a host transfer and "
                            f"fails under jit; use jnp casts/astype",
                        )
                    )
        # JP004 — numpy on traced arguments
        if isinstance(node, ast.Call):
            dotted = idx.imports.resolve(node.func)
            if dotted and dotted.startswith("numpy."):
                argrefs = any(
                    _refs_traced(a, traced, attr_is_traced=attr_traced)
                    for a in list(node.args) + [k.value for k in node.keywords]
                )
                if argrefs:
                    out.append(
                        Violation(
                            "JP004", path, node.lineno, node.col_offset,
                            f"np.{dotted.split('.', 1)[1]}() on a traced "
                            f"argument in {fn.qualname!r} falls back to host "
                            f"numpy; use jnp",
                        )
                    )
        # JP002 — tracer-dependent control flow
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
            if _is_static_test(test):
                continue
            hit = _refs_traced(test, traced, attr_is_traced=attr_traced)
            jnp_hit = _calls_jnp(test, idx.imports)
            if hit or jnp_hit:
                kw = "while" if isinstance(node, ast.While) else "if"
                why = (
                    "calls jax in its test" if jnp_hit and not hit
                    else "branches on a traced parameter"
                )
                out.append(
                    Violation(
                        "JP002", path, node.lineno, node.col_offset,
                        f"Python `{kw}` in traced function {fn.qualname!r} "
                        f"{why}; use lax.cond/lax.while_loop/jnp.where",
                    )
                )
    return out


def check_purity(files: Dict[str, Tuple[str, ast.AST]]) -> List[Violation]:
    """Run JP001-JP004 over the R2 module set.

    ``files`` maps repo-relative path -> (dotted module name, parsed tree).
    """
    analyzer = _Analyzer(files)
    analyzer.find_roots()
    analyzer.propagate()
    return analyzer.check()
