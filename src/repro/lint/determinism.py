"""R1 — determinism rules (DT001-DT003).

Applies to modules feeding ``cell_hash`` / ``SimResult`` / WAL records
(:data:`repro.lint.paths.R1_PATHS`).  Everything a gated number depends on
must be a pure function of (seed, inputs, SIM_VERSION):

* ``DT001`` — global-state RNG: ``np.random.<draw>()`` module calls and
  stdlib ``random.<draw>()``.  Seeded constructors (``np.random.default_rng``,
  ``np.random.SeedSequence``, ``random.Random(seed)``) are fine — the rule
  targets the *process-global* streams whose state depends on import order
  and call history.
* ``DT002`` — wall-clock reads: any reference (not just call — passing
  ``time.monotonic`` as a ``time_source`` default counts) to
  ``time.time/monotonic/perf_counter[_ns]``, ``datetime.datetime.now`` and
  friends.  ``service/clock.py`` is legitimately wall-clocked and carries a
  file waiver.
* ``DT003`` — iteration over an unordered set.  Set iteration order is
  salted per process in no way the cache or the WAL can see; wrap in
  ``sorted(...)`` or iterate the ordered source instead.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.lint.base import Violation

__all__ = ["check_determinism"]

#: np.random attributes that construct *seeded* streams (allowed)
_NP_RANDOM_OK = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "PCG64",
    "PCG64DXSM",
    "Philox",
    "SFC64",
    "MT19937",
}

#: stdlib random names that are allowed (seeded-instance construction)
_RANDOM_OK = {"Random", "getstate", "setstate"}

#: fully-resolved dotted names that read the wall clock
_WALL_CLOCK = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.localtime",
    "time.gmtime",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "datetime.date.today",
}


class _Imports:
    """Alias -> dotted-module map from a file's import statements."""

    def __init__(self) -> None:
        self.names: Dict[str, str] = {}

    def feed(self, node: ast.AST) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                self.names[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                self.names[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted name of an Attribute/Name chain, import-resolved, or None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.names.get(node.id)
        if head is None:
            return None
        parts.append(head)
        return ".".join(reversed(parts))


def _is_setlike(node: ast.expr, set_names: Dict[str, ast.expr]) -> bool:
    """Conservatively: does this expression evaluate to a set?"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in {"set", "frozenset"}:
            return True
        if isinstance(f, ast.Attribute) and f.attr in {
            "intersection",
            "union",
            "difference",
            "symmetric_difference",
        }:
            # .union/.difference exist on sets only (frozenset included);
            # str/list have no such methods, so this is unambiguous
            return True
    if isinstance(node, ast.Name) and node.id in set_names:
        return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str) -> None:
        self.path = path
        self.imports = _Imports()
        self.violations: List[Violation] = []
        # per-scope map of names assigned set-like values (module scope at
        # index 0; a function pushes a fresh scope)
        self._set_scopes: List[Dict[str, ast.expr]] = [{}]

    # -- imports ------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        self.imports.feed(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        self.imports.feed(node)

    # -- scopes -------------------------------------------------------
    def visit_FunctionDef(self, node) -> None:
        self._set_scopes.append({})
        self.generic_visit(node)
        self._set_scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node: ast.Assign) -> None:
        scope = self._set_scopes[-1]
        for t in node.targets:
            if isinstance(t, ast.Name):
                if _is_setlike(node.value, scope):
                    scope[t.id] = node.value
                else:
                    scope.pop(t.id, None)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        # x |= {...} keeps set-ness; anything else clears our knowledge
        if isinstance(node.target, ast.Name) and not _is_setlike(
            node.value, self._set_scopes[-1]
        ):
            self._set_scopes[-1].pop(node.target.id, None)
        self.generic_visit(node)

    # -- DT001 --------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        dotted = self.imports.resolve(node.func)
        if dotted:
            parts = dotted.split(".")
            if parts[0] == "numpy" and len(parts) >= 3 and parts[1] == "random":
                if parts[2] not in _NP_RANDOM_OK:
                    self._flag(
                        "DT001", node,
                        f"np.random.{parts[2]}() draws from the process-global "
                        f"stream; use np.random.default_rng(seed)",
                    )
            elif parts[0] == "random" and len(parts) == 2:
                if parts[1] not in _RANDOM_OK:
                    self._flag(
                        "DT001", node,
                        f"random.{parts[1]}() uses the global stdlib stream; "
                        f"use a seeded random.Random(seed) or np.random.default_rng",
                    )
        self.generic_visit(node)

    # -- DT002 --------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        dotted = self.imports.resolve(node)
        if dotted in _WALL_CLOCK:
            self._flag(
                "DT002", node,
                f"{dotted} reads the wall clock; sim paths must derive time "
                f"from the event stream / seeded inputs",
            )
            return  # don't re-flag inner chain links
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load):
            dotted = self.imports.resolve(node)
            if dotted in _WALL_CLOCK:
                self._flag("DT002", node, f"{dotted} reads the wall clock")

    # -- DT003 --------------------------------------------------------
    def _check_iter(self, iter_node: ast.expr) -> None:
        scope = self._set_scopes[-1]
        if _is_setlike(iter_node, scope):
            self._flag(
                "DT003", iter_node,
                "iteration over an unordered set — order varies per process; "
                "use sorted(...) or iterate the ordered source",
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    # a SetComp over a set is fine: the result is unordered anyway, and the
    # body runs per-element with no order-dependent accumulation we can see
    # — but flag it to be safe is noisy; skip SetComp iterables.

    # -- plumbing -----------------------------------------------------
    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.violations.append(
            Violation(rule, self.path, node.lineno, node.col_offset, msg)
        )


def check_determinism(path: str, tree: ast.AST) -> List[Violation]:
    v = _Visitor(path)
    v.visit(tree)
    return v.violations
