"""The ``# lint: waive[RULE] reason`` escape hatch.

Two scopes:

* ``# lint: waive[DT002] reason`` — waives the named rule(s) on the same
  line and the line immediately below (so both trailing comments and a
  comment-above style work; multi-line statements report at the statement
  head, which is the line under the comment).
* ``# lint: waive-file[DT002] reason`` — waives the rule(s) for the whole
  file (e.g. ``service/clock.py`` is *legitimately* wall-clocked).

A justification is mandatory: a waiver with no reason text is itself a
violation (``WV001``) — the whole point of the hatch is that the "why"
lives next to the exemption.  Several rules may share one waiver:
``waive[DT001,DT002]``.
"""

from __future__ import annotations

import re
from typing import Dict, List, Set, Tuple

from repro.lint.base import DIFF_SCOPED_RULES, RULES, Violation

__all__ = ["FileWaivers", "parse_waivers"]

_WAIVE_RE = re.compile(
    r"#\s*lint:\s*(?P<scope>waive-file|waive)\[(?P<rules>[A-Za-z0-9_,\s]+)\]\s*(?P<reason>.*)$"
)


class FileWaivers:
    """Parsed waivers for one file; answers "is (rule, line) waived?"."""

    def __init__(self) -> None:
        self.file_scope: Dict[str, str] = {}  # rule -> reason
        self.line_scope: Dict[Tuple[str, int], str] = {}  # (rule, line) -> reason
        self.errors: List[Violation] = []
        self._used: Set[Tuple[str, int]] = set()
        self._used_file: Set[str] = set()

    def lookup(self, rule: str, line: int):
        """Reason string when waived, else None; marks the waiver used."""
        if rule in self.file_scope:
            self._used_file.add(rule)
            return self.file_scope[rule]
        for probe in (line, line - 1):
            if (rule, probe) in self.line_scope:
                self._used.add((rule, probe))
                return self.line_scope[(rule, probe)]
        return None

    def unused(self) -> List[str]:
        """Human notes for waivers that suppressed nothing (hygiene aid)."""
        out = [
            f"unused file waiver for {rule}"
            for rule in sorted(set(self.file_scope) - self._used_file)
            if rule not in DIFF_SCOPED_RULES
        ]
        out.extend(
            f"unused waiver for {rule} at line {line}"
            for (rule, line) in sorted(set(self.line_scope) - self._used, key=lambda k: k[1])
            if rule not in DIFF_SCOPED_RULES
        )
        return out


def _comment_tokens(source: str):
    """(lineno, comment text) for every comment token; docstrings and
    string literals containing waiver *examples* are never parsed."""
    import io
    import tokenize

    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # unparseable files are reported as LE001 by the runner; no waivers
        return


def parse_waivers(path: str, source: str) -> FileWaivers:
    fw = FileWaivers()
    for lineno, text in _comment_tokens(source):
        m = _WAIVE_RE.search(text)
        if m is None:
            # catch near-miss syntax so typos don't silently waive nothing
            if re.search(r"#\s*lint:\s*waive", text):
                fw.errors.append(
                    Violation(
                        "WV001", path, lineno, 0,
                        "malformed waiver: expected '# lint: waive[RULE] reason' "
                        "or '# lint: waive-file[RULE] reason'",
                    )
                )
            continue
        rules = [r.strip() for r in m.group("rules").split(",") if r.strip()]
        reason = m.group("reason").strip()
        bad = [r for r in rules if r not in RULES]
        if bad:
            fw.errors.append(
                Violation(
                    "WV001", path, lineno, 0,
                    f"waiver names unknown rule(s) {', '.join(bad)}; "
                    f"see docs/LINTING.md for the catalog",
                )
            )
        if not reason:
            fw.errors.append(
                Violation(
                    "WV001", path, lineno, 0,
                    f"waiver for {', '.join(rules)} has no justification — "
                    f"say why the exemption is legitimate",
                )
            )
            continue  # a reasonless waiver does not waive
        for rule in rules:
            if rule in RULES:
                if m.group("scope") == "waive-file":
                    fw.file_scope[rule] = reason
                else:
                    fw.line_scope[(rule, lineno)] = reason
    return fw
