"""CLI: ``python -m repro.lint [paths...] [--diff BASE] [--json]``.

Exit code is the bitwise OR of failing categories — R1 determinism = 1,
R2 JAX purity = 2, R3 version gates = 4, R4 schema drift = 8, waiver
hygiene = 16, internal (unparseable file) = 64 — so a CI log's exit status
names the broken contract.  Waived findings are listed but never fail.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.lint import RULES, category_of, lint_repo
from repro.lint.base import CATEGORY_BITS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description=__doc__.splitlines()[0],
    )
    ap.add_argument(
        "paths", nargs="*",
        help="files/dirs to sweep (default: src/repro scripts)",
    )
    ap.add_argument(
        "--diff", metavar="BASE", default=None,
        help="also run the version-gate rules against this git base "
        "(e.g. origin/main)",
    )
    ap.add_argument("--root", default=None, help="repo root (default: auto-detect)")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, (cat, summary) in sorted(RULES.items()):
            print(f"{rule}  [{cat}, exit bit {CATEGORY_BITS[cat]}]  {summary}")
        return 0

    report = lint_repo(
        root=args.root, targets=args.paths or None, diff_base=args.diff
    )

    if args.json:
        json.dump(report.to_dict(), sys.stdout, indent=2)
        print()
        return report.exit_code

    unwaived = [v for v in report.violations if not v.waived]
    waived = [v for v in report.violations if v.waived]
    for v in unwaived:
        print(f"{v.path}:{v.line}:{v.col}: {v.rule} [{category_of(v.rule)}] {v.message}")
    if waived:
        print(f"-- {len(waived)} waived finding(s):")
        for v in waived:
            print(f"   {v.path}:{v.line}: {v.rule} waived: {v.waive_reason}")
    for note in report.notes:
        print(f"note: {note}")
    status = "clean" if not unwaived else f"{len(unwaived)} violation(s)"
    print(
        f"repro.lint: {report.files_checked} file(s), {status}, "
        f"{len(waived)} waived (exit {report.exit_code})"
    )
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
