"""R3 — version-gate discipline (VG001, VG002, SD002), git-diff-aware.

``python -m repro.lint --diff <base>`` compares the working tree against a
git base and fails when:

* **VG001** — a physics module (:data:`repro.lint.paths.PHYSICS_PATHS`)
  changed *semantically* without a ``SIM_VERSION`` bump;
* **VG002** — a WAL codec module changed semantically without a
  ``WAL_FORMAT`` bump;
* **SD002** — a registered snapshot dataclass's field set changed without
  a ``SCHEMA_VERSION`` bump.

"Semantically" means the docstring-stripped AST differs: comment-only and
docstring-only edits never require a bump (CONTRIBUTING.md explicitly
wants pure refactors *proven* by the bit-identity suites instead, and a
comment edit is below even that bar).

The waiver for a legitimate no-bump change (e.g. a pure refactor covered
by the bit-identity gates) must appear on an **added line of the diff**::

    # lint: waive[VG001] pure refactor; engine bit-identity suite pins semantics

A waiver comment already in the file does not carry over to future diffs —
each PR earns its own exemption.

Limitation (documented, acceptable for CI where everything is committed):
files untracked by git are invisible to ``git diff`` and therefore to this
gate.
"""

from __future__ import annotations

import ast
import re
import subprocess
from typing import List, Optional, Tuple

from repro.lint.base import Violation
from repro.lint.paths import (
    PHYSICS_PATHS,
    SIM_VERSION_FILE,
    SNAPSHOT_REGISTRY,
    WAL_FORMAT_FILE,
    WAL_PATHS,
    in_scope,
)
from repro.lint.schema import extract_schema

__all__ = ["run_diff_gate", "ast_fingerprint"]


def _git(root: str, *args: str) -> Optional[str]:
    try:
        proc = subprocess.run(
            ["git", *args], cwd=root, capture_output=True, text=True, timeout=60
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    return proc.stdout if proc.returncode == 0 else None


def _strip_docstrings(tree: ast.AST) -> ast.AST:
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)):
            body = node.body
            if (
                body
                and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)
            ):
                node.body = body[1:] or [ast.Pass()]
    return tree


def ast_fingerprint(source: Optional[str]) -> Optional[str]:
    """Docstring-insensitive structural fingerprint; None = unparseable."""
    if source is None:
        return None
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return None
    return ast.dump(_strip_docstrings(tree), annotate_fields=False, include_attributes=False)


def _base_source(root: str, base: str, path: str) -> Optional[str]:
    return _git(root, "show", f"{base}:{path}")


def _working_source(root: str, path: str) -> Optional[str]:
    import os

    abs_p = os.path.join(root, path)
    if not os.path.exists(abs_p):
        return None
    with open(abs_p, encoding="utf-8") as f:
        return f.read()


def _module_constant(source: Optional[str], name: str):
    """Module-level `NAME = <literal>` value, or None."""
    if source is None:
        return None
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return None
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    if isinstance(node.value, ast.Constant):
                        return node.value.value
    return None


_WAIVE_ADDED = re.compile(
    r"^\+.*#\s*lint:\s*waive\[(?P<rules>[A-Za-z0-9_,\s]+)\]\s*(?P<reason>\S.*)$"
)


def _added_waivers(root: str, base: str) -> dict:
    """rule -> reason for every waiver on an *added* diff line."""
    out: dict = {}
    diff = _git(root, "diff", "--unified=0", base, "--") or ""
    for line in diff.splitlines():
        m = _WAIVE_ADDED.match(line)
        if m:
            reason = m.group("reason").strip()
            for rule in (r.strip() for r in m.group("rules").split(",")):
                if rule and reason:
                    out[rule] = reason
    return out


def _gate(
    root: str,
    base: str,
    changed: List[str],
    scope_paths,
    version_file: str,
    version_name: str,
    rule: str,
    waivers: dict,
) -> List[Violation]:
    touched = [f for f in changed if in_scope(f, scope_paths)]
    significant = []
    for f in touched:
        old_fp = ast_fingerprint(_base_source(root, base, f))
        new_fp = ast_fingerprint(_working_source(root, f))
        if old_fp is None or new_fp is None or old_fp != new_fp:
            significant.append(f)
    if not significant:
        return []
    old_v = _module_constant(_base_source(root, base, version_file), version_name)
    new_v = _module_constant(_working_source(root, version_file), version_name)
    if old_v != new_v and new_v is not None:
        return []  # bumped — the gate is satisfied
    v = Violation(
        rule,
        version_file,
        1,
        0,
        f"{', '.join(significant)} changed semantically vs {base} but "
        f"{version_name} is still {new_v!r}; bump it (and regenerate the "
        f"baselines, CONTRIBUTING.md) or add an added-line waiver "
        f"`# lint: waive[{rule}] <why no bump is needed>`",
    )
    if rule in waivers:
        v.waived = True
        v.waive_reason = waivers[rule]
    return [v]


def _schema_gate(root: str, base: str, changed: List[str], waivers: dict) -> List[Violation]:
    out: List[Violation] = []
    for path, classname in SNAPSHOT_REGISTRY:
        if path not in changed:
            continue
        old_src = _base_source(root, base, path)
        new_src = _working_source(root, path)
        try:
            old = extract_schema(ast.parse(old_src), classname) if old_src else None
            new = extract_schema(ast.parse(new_src), classname) if new_src else None
        except SyntaxError:
            continue  # LE001 from the static pass covers unparseable files
        if old is None or new is None:
            continue  # class added/removed: SD001 static pass governs
        old_fields, _, old_version, _ = old
        new_fields, _, new_version, lineno = new
        if old_fields != new_fields and old_version == new_version:
            v = Violation(
                "SD002",
                path,
                lineno,
                0,
                f"{classname} field set changed vs {base} "
                f"({sorted(set(old_fields) ^ set(new_fields))}) but "
                f"SCHEMA_VERSION is still {new_version!r}; old pickles will "
                f"unpickle into the wrong shape — bump SCHEMA_VERSION",
            )
            if "SD002" in waivers:
                v.waived = True
                v.waive_reason = waivers["SD002"]
            out.append(v)
    return out


def run_diff_gate(root: str, base: str) -> List[Violation]:
    """VG001 + VG002 + SD002 for the working tree vs ``base``."""
    names = _git(root, "diff", "--name-only", base, "--")
    if names is None:
        return [
            Violation(
                "VG001", SIM_VERSION_FILE, 1, 0,
                f"git diff against {base!r} failed — is the base fetched? "
                f"(CI needs fetch-depth: 0 / an explicit fetch of the base)",
            )
        ]
    changed = [ln.strip() for ln in names.splitlines() if ln.strip()]
    waivers = _added_waivers(root, base)
    out = _gate(
        root, base, changed, PHYSICS_PATHS, SIM_VERSION_FILE, "SIM_VERSION",
        "VG001", waivers,
    )
    out += _gate(
        root, base, changed, WAL_PATHS, WAL_FORMAT_FILE, "WAL_FORMAT",
        "VG002", waivers,
    )
    out += _schema_gate(root, base, changed, waivers)
    return out
