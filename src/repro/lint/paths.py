"""Scope registry: which files each rule category applies to.

All paths are repo-root-relative posix.  The sets mirror the contracts in
CONTRIBUTING.md / DESIGN.md:

* ``R1`` (determinism) covers every module whose output feeds ``cell_hash``
  (sweep cells), ``SimResult`` (the simulation core, fleet, forecast,
  serving), or WAL records (the service) — plus the two gate scripts whose
  artifacts are compared run-to-run.  The training substrate
  (models/kernels/launch/…) is deliberately out: it never feeds a gated
  number, and seeding there is covered by R2's purity rules where it
  matters.
* ``R2`` (JAX purity) covers the modules that build jitted programs.
* ``R3`` physics set = every module a SIM_VERSION bump covers per
  CONTRIBUTING.md ("When to bump SIM_VERSION"); WAL set likewise for
  WAL_FORMAT.
* ``R4`` registry = the pickled snapshot dataclasses.
"""

from __future__ import annotations

import os
from typing import List, Optional, Tuple

__all__ = [
    "DEFAULT_TARGETS",
    "R1_PATHS",
    "R2_PATHS",
    "PHYSICS_PATHS",
    "WAL_PATHS",
    "SIM_VERSION_FILE",
    "WAL_FORMAT_FILE",
    "SNAPSHOT_REGISTRY",
    "find_repo_root",
    "in_scope",
]

#: what a bare ``python -m repro.lint`` sweeps (tests/ hosts deliberately
#: bad fixture snippets and is excluded by design)
DEFAULT_TARGETS = ("src/repro", "scripts")

#: R1 determinism scope — prefixes (dirs) and exact files
R1_PATHS = (
    "src/repro/core",
    "src/repro/fleet",
    "src/repro/forecast",
    "src/repro/sweep",
    "src/repro/service",
    "scripts/bench_nightly.py",
    "scripts/check_coverage.py",
)

#: R2 JAX-purity scope — the modules that assemble jit/scan/vmap programs
R2_PATHS = (
    "src/repro/core/batched",
    "src/repro/core/rl",
    "src/repro/kernels",
    "src/repro/optim",
    "src/repro/models",
)

#: R3 physics set: a semantically visible change here requires a
#: SIM_VERSION bump (CONTRIBUTING.md) or an explicit in-diff waiver
PHYSICS_PATHS = (
    "src/repro/core/simulator.py",
    "src/repro/core/slices.py",
    "src/repro/core/engine.py",
    "src/repro/core/schedulers.py",
    "src/repro/core/workload.py",
    "src/repro/core/scenarios.py",
    "src/repro/core/power.py",
    "src/repro/core/jobs.py",
    "src/repro/core/metrics.py",
    "src/repro/core/serving.py",
    "src/repro/core/batched",
    "src/repro/fleet",
    "src/repro/forecast",
)

#: R3 WAL set: record/WAL codec changes require a WAL_FORMAT bump
WAL_PATHS = (
    "src/repro/service/records.py",
    "src/repro/service/wal.py",
)

SIM_VERSION_FILE = "src/repro/core/simulator.py"
WAL_FORMAT_FILE = "src/repro/service/records.py"

#: R4: pickled snapshot dataclasses that must carry SCHEMA_VERSION +
#: _schema_digest class attributes (file, class name)
SNAPSHOT_REGISTRY: Tuple[Tuple[str, str], ...] = (
    ("src/repro/core/engine.py", "SimSnapshot"),
    ("src/repro/core/engine.py", "EngineSnapshot"),
    ("src/repro/service/service.py", "ServiceStats"),
)


def find_repo_root(start: Optional[str] = None) -> str:
    """Walk up from ``start`` (default cwd) to the dir holding pyproject.toml."""
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise FileNotFoundError(
                "repro.lint could not locate the repo root (no pyproject.toml "
                "above the current directory); run from inside the repo or "
                "pass --root"
            )
        d = parent


def in_scope(rel_path: str, prefixes) -> bool:
    """True when repo-relative ``rel_path`` matches a file or dir prefix."""
    for p in prefixes:
        if rel_path == p or rel_path.startswith(p.rstrip("/") + "/"):
            return True
    return False


def iter_python_files(root: str, targets) -> List[str]:
    """Repo-relative posix paths of .py files under the given targets."""
    out: List[str] = []
    for target in targets:
        abs_t = os.path.join(root, target)
        if os.path.isfile(abs_t):
            if abs_t.endswith(".py"):
                out.append(os.path.relpath(abs_t, root).replace(os.sep, "/"))
            continue
        for dirpath, dirnames, filenames in os.walk(abs_t):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    out.append(rel.replace(os.sep, "/"))
    return sorted(set(out))
