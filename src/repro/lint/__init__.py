"""``repro.lint`` — the repo-specific invariant analyzer.

Four machine-checked contracts (docs/LINTING.md has the full catalog):

* **R1 determinism** (DT001-DT003) — no global-state RNG, wall-clock
  reads, or unordered-set iteration in any module feeding ``cell_hash`` /
  ``SimResult`` / WAL records;
* **R2 JAX purity** (JP001-JP004) — no Python side effects,
  tracer-dependent control flow, host casts, or host-numpy calls inside
  functions reaching ``jax.jit`` / ``lax.scan`` / ``vmap``;
* **R3 version gates** (VG001-VG002) — ``--diff <base>`` mode: physics
  edits require a ``SIM_VERSION`` bump, WAL codec edits a ``WAL_FORMAT``
  bump (comment/docstring-only edits exempt; in-diff waivers allowed);
* **R4 schema drift** (SD001-SD002) — pickled snapshot dataclasses carry
  ``SCHEMA_VERSION`` + a lint-pinned field-set digest.

Run ``python -m repro.lint`` (optionally ``--diff origin/main``); the
inline escape hatch is ``# lint: waive[RULE] reason``.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.lint.base import (
    CATEGORY_BITS,
    RULES,
    Violation,
    category_of,
    exit_code_for,
)
from repro.lint.determinism import check_determinism
from repro.lint.paths import (
    DEFAULT_TARGETS,
    R1_PATHS,
    R2_PATHS,
    SNAPSHOT_REGISTRY,
    find_repo_root,
    in_scope,
    iter_python_files,
)
from repro.lint.purity import check_purity
from repro.lint.schema import check_schema
from repro.lint.version_gate import run_diff_gate
from repro.lint.waivers import parse_waivers

__all__ = [
    "LintReport",
    "lint_repo",
    "Violation",
    "RULES",
    "CATEGORY_BITS",
    "exit_code_for",
]


@dataclasses.dataclass
class LintReport:
    violations: List[Violation]
    files_checked: int
    notes: List[str]  # non-fatal hygiene notes (unused waivers)

    @property
    def exit_code(self) -> int:
        return exit_code_for(self.violations)

    def to_dict(self) -> dict:
        unwaived = [v for v in self.violations if not v.waived]
        by_cat: Dict[str, int] = {}
        for v in unwaived:
            c = category_of(v.rule)
            by_cat[c] = by_cat.get(c, 0) + 1
        return {
            "version": 1,
            "files_checked": self.files_checked,
            "violations": [v.to_dict() for v in self.violations],
            "summary": {
                "total": len(self.violations),
                "unwaived": len(unwaived),
                "waived": len(self.violations) - len(unwaived),
                "by_category": by_cat,
            },
            "notes": self.notes,
            "exit_code": self.exit_code,
        }


def _module_name(rel_path: str) -> str:
    p = rel_path
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith("/__init__.py"):
        p = p[: -len("/__init__.py")]
    elif p.endswith(".py"):
        p = p[: -len(".py")]
    return p.replace("/", ".")


def lint_repo(
    root: Optional[str] = None,
    targets: Optional[Sequence[str]] = None,
    diff_base: Optional[str] = None,
) -> LintReport:
    """Run every applicable rule; the library entry point the CLI wraps."""
    root = root or find_repo_root()
    rel_files = iter_python_files(root, targets or DEFAULT_TARGETS)

    violations: List[Violation] = []
    notes: List[str] = []
    waivers = {}
    purity_files: Dict[str, Tuple[str, ast.AST]] = {}
    registry = {}
    for path, cls in SNAPSHOT_REGISTRY:
        registry.setdefault(path, []).append(cls)

    for rel in rel_files:
        try:
            with open(os.path.join(root, rel), encoding="utf-8") as f:
                source = f.read()
        except OSError as e:
            violations.append(Violation("LE001", rel, 1, 0, f"unreadable: {e}"))
            continue
        fw = parse_waivers(rel, source)
        waivers[rel] = fw
        violations.extend(fw.errors)
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            violations.append(
                Violation("LE001", rel, e.lineno or 1, 0, f"syntax error: {e.msg}")
            )
            continue
        if in_scope(rel, R1_PATHS):
            violations.extend(check_determinism(rel, tree))
        if in_scope(rel, R2_PATHS):
            purity_files[rel] = (_module_name(rel), tree)
        for cls in registry.get(rel, ()):
            violations.extend(check_schema(rel, tree, cls))

    if purity_files:
        violations.extend(check_purity(purity_files))

    # apply inline waivers (diff-gate rules carry their own waiver logic)
    for v in violations:
        if v.waived or v.rule.startswith(("VG", "WV", "LE")):
            continue
        fw = waivers.get(v.path)
        if fw is not None:
            reason = fw.lookup(v.rule, v.line)
            if reason is not None:
                v.waived = True
                v.waive_reason = reason

    if diff_base is not None:
        violations.extend(run_diff_gate(root, diff_base))

    for rel, fw in sorted(waivers.items()):
        notes.extend(f"{rel}: {msg}" for msg in fw.unused())

    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return LintReport(violations, files_checked=len(rel_files), notes=notes)
