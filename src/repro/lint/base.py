"""Violation model, rule registry, and category/exit-code mapping.

``repro.lint`` converts the repo's implicit correctness contracts —
seeded-RNG-only physics, pure jitted code, version bumps on physics edits,
versioned snapshot schemas — into machine-checked rules.  Each rule has a
stable id (``DT001``, ``JP002``, …) grouped into the four categories of
docs/LINTING.md; the CLI exit code is the bitwise OR of the failing
categories, so CI logs show *which* contract broke without parsing output.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = [
    "Violation",
    "CATEGORY_BITS",
    "RULE_CATEGORY",
    "RULES",
    "DIFF_SCOPED_RULES",
    "category_of",
    "exit_code_for",
]


#: category -> exit-code bit.  R1 determinism, R2 JAX purity, R3 version
#: gates, R4 schema drift, WV waiver hygiene, plus 64 for internal errors.
CATEGORY_BITS: Dict[str, int] = {
    "R1": 1,
    "R2": 2,
    "R3": 4,
    "R4": 8,
    "WV": 16,
    "internal": 64,
}

#: every rule id -> (category, one-line summary).  docs/LINTING.md renders
#: this table; tests assert the two stay in sync.
RULES: Dict[str, tuple] = {
    "DT001": ("R1", "global-state RNG call (np.random.* module API, stdlib random)"),
    "DT002": ("R1", "wall-clock read (time.time/monotonic/perf_counter, datetime.now)"),
    "DT003": ("R1", "iteration over an unordered set (use sorted(...))"),
    "JP001": ("R2", "Python side effect (print/open/global write) inside jit-reaching code"),
    "JP002": ("R2", "tracer-dependent Python if/while inside jit-reaching code"),
    "JP003": ("R2", "host cast float()/int()/bool() of a traced value"),
    "JP004": ("R2", "numpy call on a traced argument inside jit-reaching code"),
    "VG001": ("R3", "physics module changed without a SIM_VERSION bump or waiver"),
    "VG002": ("R3", "WAL module changed without a WAL_FORMAT bump or waiver"),
    "SD001": ("R4", "snapshot dataclass schema digest missing or stale"),
    "SD002": ("R4", "snapshot field set changed without a SCHEMA_VERSION bump"),
    "WV001": ("WV", "malformed waiver (missing rule id or justification)"),
    "LE001": ("internal", "file could not be parsed"),
}

RULE_CATEGORY: Dict[str, str] = {rule: cat for rule, (cat, _) in RULES.items()}

#: rules enforced only by ``--diff`` mode; their inline waivers are matched
#: against *added diff lines* rather than the static waiver table, so the
#: static pass must not report them as "unused".
DIFF_SCOPED_RULES = frozenset({"VG001", "VG002", "SD002"})


@dataclasses.dataclass
class Violation:
    """One finding.  ``waived`` findings are reported but never fail."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    waived: bool = False
    waive_reason: Optional[str] = None

    def to_dict(self) -> dict:
        d = {
            "rule": self.rule,
            "category": category_of(self.rule),
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "waived": self.waived,
        }
        if self.waive_reason is not None:
            d["waive_reason"] = self.waive_reason
        return d


def category_of(rule: str) -> str:
    return RULE_CATEGORY.get(rule, "internal")


def exit_code_for(violations: List[Violation]) -> int:
    """Bitwise OR of the categories with at least one unwaived violation."""
    code = 0
    for v in violations:
        if not v.waived:
            code |= CATEGORY_BITS[category_of(v.rule)]
    return code
