"""R4 — snapshot schema drift (SD001).

Pickled snapshot dataclasses (:data:`repro.lint.paths.SNAPSHOT_REGISTRY`)
must carry two class attributes::

    SCHEMA_VERSION = 1                 # bump when the field set changes
    _schema_digest = "7f3a9c21"        # sha256(field names)[:8], lint-pinned

The digest is recomputed from the AST field list on every run, so adding,
removing, or renaming a field fails SD001 with the expected digest in the
message — forcing the edit to *also* touch the digest line, which the
``--diff`` gate (SD002, :mod:`repro.lint.version_gate`) then requires to
come with a ``SCHEMA_VERSION`` bump.  Class attributes are not pickled, so
carrying them is free; the version rides along for readers that want to
refuse foreign blobs.
"""

from __future__ import annotations

import ast
import hashlib
from typing import List, Optional, Tuple

from repro.lint.base import Violation

__all__ = ["extract_schema", "field_digest", "check_schema"]


def field_digest(fields: Tuple[str, ...]) -> str:
    return hashlib.sha256(",".join(fields).encode()).hexdigest()[:8]


def _is_classvar(ann: ast.expr) -> bool:
    if isinstance(ann, ast.Subscript):
        ann = ann.value
    return (isinstance(ann, ast.Name) and ann.id == "ClassVar") or (
        isinstance(ann, ast.Attribute) and ann.attr == "ClassVar"
    )


def extract_schema(tree: ast.AST, classname: str):
    """(fields, digest_attr, version_attr, lineno) for a class, or None.

    ``fields`` are the annotated (dataclass) fields in declaration order;
    plain assignments like ``SCHEMA_VERSION = 1`` are class attributes.
    """
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef) and node.name == classname):
            continue
        fields: List[str] = []
        digest: Optional[str] = None
        version = None
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                if not _is_classvar(stmt.annotation):
                    fields.append(stmt.target.id)
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t = stmt.targets[0]
                if isinstance(t, ast.Name) and isinstance(stmt.value, ast.Constant):
                    if t.id == "_schema_digest":
                        digest = stmt.value.value
                    elif t.id == "SCHEMA_VERSION":
                        version = stmt.value.value
        return tuple(fields), digest, version, node.lineno
    return None


def check_schema(path: str, tree: ast.AST, classname: str) -> List[Violation]:
    got = extract_schema(tree, classname)
    if got is None:
        return [
            Violation(
                "SD001", path, 1, 0,
                f"registered snapshot class {classname!r} not found — update "
                f"repro.lint.paths.SNAPSHOT_REGISTRY if it moved",
            )
        ]
    fields, digest, version, lineno = got
    expected = field_digest(fields)
    out: List[Violation] = []
    if version is None:
        out.append(
            Violation(
                "SD001", path, lineno, 0,
                f"{classname} is pickled but carries no SCHEMA_VERSION class "
                f"attribute; add `SCHEMA_VERSION = 1`",
            )
        )
    if digest is None:
        out.append(
            Violation(
                "SD001", path, lineno, 0,
                f"{classname} has no _schema_digest; add "
                f'`_schema_digest = "{expected}"` (sha256 of its field names)',
            )
        )
    elif digest != expected:
        out.append(
            Violation(
                "SD001", path, lineno, 0,
                f"{classname} field set changed: _schema_digest is "
                f"{digest!r} but the fields hash to {expected!r} — update the "
                f"digest AND bump SCHEMA_VERSION",
            )
        )
    return out
