"""Roofline-term derivation from the dry-run artifacts.

Per (arch x shape x mesh) cell (EXPERIMENTS.md §Roofline):

  compute term    = HLO_FLOPs    / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes    / (chips x 819 GB/s)
  collective term = coll_bytes   / (chips x 50 GB/s/link)

FLOPs/bytes come from the scan-corrected *composite* cost (dryrun.py lowers
1- and 2-unit unscanned mini-models; ``total = outer + unit x repeats``)
because XLA's cost analysis counts ``lax.scan`` bodies once.  Collective
bytes are parsed from the compiled per-device HLO and multiplied by the
device count (the brief's "sum operand sizes" over the whole machine).

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for
inference shapes.  The MODEL/HLO ratio flags remat or redundant compute.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.analysis.constants import CHIP_FLOPS_BF16, HBM_BW, LINK_BW
from repro.configs import get_config
from repro.launch.shapes import SHAPES

__all__ = ["roofline_terms", "model_flops", "roofline_row", "load_record"]

ART_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")
)


def load_record(arch: str, shape: str, multi_pod: bool = False) -> Optional[Dict]:
    key = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"
    path = os.path.join(ART_DIR, key + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful FLOPs for the whole cell (6ND train / 2ND inference)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.param_count(active_only=True)
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.global_batch  # decode: one token per request


def roofline_terms(rec: Dict) -> Optional[Dict[str, float]]:
    """Three terms in seconds + diagnostics, from one dry-run record."""
    if not rec.get("ok") or rec.get("skipped"):
        return None
    chips = rec.get("devices", 256)
    comp = (rec.get("cost") or {}).get("composite")
    if comp is None:
        flops_total = (rec.get("flops") or 0.0) * chips
        bytes_total = (rec.get("bytes_accessed") or 0.0) * chips
        coll_total = sum((rec.get("collectives") or {}).values()) * chips
        scan_corrected = False
    else:
        flops_total = comp["flops"] * chips
        bytes_total = comp["bytes_accessed"] * chips
        coll_total = sum(comp["collectives"].values()) * chips
        scan_corrected = True
    t_compute = flops_total / (chips * CHIP_FLOPS_BF16)
    t_memory = bytes_total / (chips * HBM_BW)
    t_coll = coll_total / (chips * LINK_BW)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_total": flops_total,
        "hlo_bytes_total": bytes_total,
        "collective_bytes_total": coll_total,
        "scan_corrected": scan_corrected,
        "chips": chips,
    }


def roofline_row(arch: str, shape: str, multi_pod: bool = False) -> Optional[Dict]:
    rec = load_record(arch, shape, multi_pod)
    if rec is None:
        return None
    if rec.get("skipped"):
        return {"arch": arch, "shape": shape, "skipped": True, "reason": rec.get("reason", "")}
    terms = roofline_terms(rec)
    if terms is None:
        return {"arch": arch, "shape": shape, "failed": True, "error": rec.get("error")}
    mf = model_flops(arch, shape)
    t_bound = max(terms["t_compute_s"], terms["t_memory_s"], terms["t_collective_s"])
    t_ideal = mf / (terms["chips"] * CHIP_FLOPS_BF16)
    row = {
        "arch": arch,
        "shape": shape,
        **terms,
        "model_flops": mf,
        "useful_ratio": mf / terms["hlo_flops_total"] if terms["hlo_flops_total"] else None,
        # roofline fraction: ideal compute time / achievable-bound time
        "roofline_fraction": t_ideal / t_bound if t_bound > 0 else None,
        "temp_bytes_per_device": rec.get("temp_size_in_bytes"),
        "argument_bytes_per_device": rec.get("argument_size_in_bytes"),
    }
    return row
