"""Roofline analysis: hardware constants + term derivation from dry-run
artifacts (EXPERIMENTS.md §Roofline)."""

from repro.analysis.constants import CHIP_FLOPS_BF16, HBM_BW, LINK_BW, HBM_BYTES
from repro.analysis.roofline import roofline_terms, model_flops, roofline_row

__all__ = [
    "CHIP_FLOPS_BF16",
    "HBM_BW",
    "LINK_BW",
    "HBM_BYTES",
    "roofline_terms",
    "model_flops",
    "roofline_row",
]
