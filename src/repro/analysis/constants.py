"""TPU v5e hardware constants (assignment brief)."""

CHIP_FLOPS_BF16 = 197e12  # 197 TFLOP/s bf16 per chip
HBM_BW = 819e9  # 819 GB/s HBM bandwidth per chip
LINK_BW = 50e9  # ~50 GB/s per ICI link
HBM_BYTES = 16 * 1024**3  # 16 GiB HBM per chip
