"""Mamba selective-SSM scan in Pallas (TPU).

The recurrence h_t = exp(dt_t*A) h_{t-1} + (dt_t x_t) B_t,  y_t = C_t.h_t + D x_t
is sequential in T but embarrassingly parallel over (batch, channel).  TPU
mapping:

* grid = (B, Di/bDi, T/chunk); the chunk dimension is sequential
  ("arbitrary") and the carried state h (bDi, N) lives in VMEM scratch.
* Each grid step streams a (chunk, bDi) slab of x/dt and (chunk, N) slabs of
  B/C through VMEM and walks the chunk with a fori_loop of VPU elementwise
  ops — the (bDi, N) state update is rank-1 and memory-resident.
* channels are blocked at bDi (lane-aligned multiples of 128) so the state
  and slabs fit VMEM comfortably: bDi=512, N=16, chunk=128 -> ~0.6 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["mamba_scan"]


def _mamba_kernel(
    x_ref,  # (1, chunk, bDi)
    dt_ref,  # (1, chunk, bDi)
    a_ref,  # (bDi, N)
    b_ref,  # (1, chunk, N)
    c_ref,  # (1, chunk, N)
    d_ref,  # (1, bDi)
    y_ref,  # (1, chunk, bDi)
    h_scr,  # (bDi, N) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # (chunk, bDi)
    dt = dt_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)  # (bDi, N)
    Bm = b_ref[0].astype(jnp.float32)  # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)
    Dv = d_ref[0].astype(jnp.float32)  # (bDi,)

    def step(t, h):
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]  # (bDi,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)[0]  # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)[0]
        dA = jnp.exp(dt_t[:, None] * A)  # (bDi, N)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + Dv * x_t  # (bDi,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def mamba_scan(
    x: jnp.ndarray,  # (B, T, Di)
    dt: jnp.ndarray,  # (B, T, Di) post-softplus
    A: jnp.ndarray,  # (Di, N)
    Bmat: jnp.ndarray,  # (B, T, N)
    Cmat: jnp.ndarray,  # (B, T, N)
    D: jnp.ndarray,  # (Di,)
    *,
    block_channels: int = 512,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas selective scan; see :func:`repro.kernels.ref.mamba_scan_ref`."""
    B, T, Di = x.shape
    N = A.shape[1]
    bDi = min(block_channels, Di)
    ch = min(chunk, T)
    assert Di % bDi == 0 and T % ch == 0, (Di, bDi, T, ch)

    grid = (B, Di // bDi, T // ch)
    kernel = functools.partial(_mamba_kernel, chunk=ch)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ch, bDi), lambda b, di, c: (b, c, di)),
            pl.BlockSpec((1, ch, bDi), lambda b, di, c: (b, c, di)),
            pl.BlockSpec((bDi, N), lambda b, di, c: (di, 0)),
            pl.BlockSpec((1, ch, N), lambda b, di, c: (b, c, 0)),
            pl.BlockSpec((1, ch, N), lambda b, di, c: (b, c, 0)),
            pl.BlockSpec((1, bDi), lambda b, di, c: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, ch, bDi), lambda b, di, c: (b, c, di)),
        out_shape=jax.ShapeDtypeStruct((B, T, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bDi, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat, D[None, :])
