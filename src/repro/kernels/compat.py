"""Version-compat shims for the Pallas TPU API.

The kernels target the current Pallas surface, but the name of the TPU
compiler-params struct has moved across jax releases:

* jax <= 0.4.x exposes ``pltpu.TPUCompilerParams``;
* newer jax renames it to ``pltpu.CompilerParams``.

``tpu_compiler_params(...)`` resolves whichever exists at import time so every
kernel builds on any toolchain the container bakes in.  Keep all version
probing here — kernels must not touch ``hasattr(pltpu, ...)`` themselves.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams", "tpu_compiler_params"]

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams", None
)
if CompilerParams is None:  # pragma: no cover - only on exotic jax builds
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version"
    )


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params struct for :func:`pl.pallas_call`."""
    return CompilerParams(**kwargs)
