"""Jit-ready wrappers that dispatch each op to its Pallas kernel or oracle.

Dispatch policy (``impl``):
* ``"pallas"``    — the TPU kernel (compiled; requires a TPU backend),
* ``"interpret"`` — the same kernel body executed by the Pallas interpreter
                    (CPU correctness path; used by the kernel test sweeps),
* ``"ref"``       — the pure-jnp oracle (XLA-native; the dry-run path, so
                    lowered HLO stays collective-analyzable and compile-fast),
* ``"auto"``      — pallas on TPU backends, ref elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.gmm import gmm as _gmm
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.mlstm import mlstm_chunkwise as _mlstm

__all__ = ["attention", "mamba_scan", "mlstm", "gmm", "resolve_impl"]


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "ref"


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    impl: str = "auto",
) -> jnp.ndarray:
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
        )
    return _fa(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        interpret=(impl == "interpret"),
    )


def mamba_scan(x, dt, A, B, C, D, *, impl: str = "auto") -> jnp.ndarray:
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.mamba_scan_ref(x, dt, A, B, C, D)
    return _mamba(x, dt, A, B, C, D, interpret=(impl == "interpret"))


def mlstm(q, k, v, i_gate, f_gate, *, chunk: int = 128, impl: str = "auto") -> jnp.ndarray:
    impl = resolve_impl(impl)
    if impl == "ref":
        # chunked-scan form: O(T*L) memory (the quadratic oracle is for tests)
        T = q.shape[1]
        return _ref.mlstm_chunked_scan(
            q, k, v, i_gate, f_gate, chunk=min(256, T)
        )
    return _mlstm(q, k, v, i_gate, f_gate, chunk=chunk, interpret=(impl == "interpret"))


def gmm(lhs, rhs, group_ids, group_sizes=None, *, impl: str = "auto") -> jnp.ndarray:
    impl = resolve_impl(impl)
    if impl == "ref":
        assert group_sizes is not None, "ref gmm needs group_sizes"
        return _ref.gmm_ref(lhs, rhs, group_sizes)
    return _gmm(lhs, rhs, group_ids, interpret=(impl == "interpret"))
