"""mLSTM (xLSTM matrix-memory) chunkwise kernel in Pallas (TPU).

Chunkwise-parallel formulation: the sequence is split into chunks of length
L; within a chunk the output is a masked, gate-decayed attention-like product
(MXU matmuls); across chunks a matrix state C (D x D), normalizer n (D) and
max-tracker m (scalar) are carried in VMEM scratch — the chunk grid dimension
is sequential ("arbitrary").

Stabilized recurrences per head (b = inclusive cumsum of logsigmoid(f),
g = b[L-1], i = input-gate preactivation):

  state:  m' = max(g + m, max_j(g - b_j + i_j))
          C' = e^{g+m-m'} C + sum_j e^{g-b_j+i_j-m'} k_j v_j^T   (k scaled 1/sqrt(D))
          n' = e^{g+m-m'} n + sum_j e^{g-b_j+i_j-m'} k_j
  output: m_t = max(b_t + m, max_{s<=t}(b_t - b_s + i_s))
          h_t = [e^{b_t+m-m_t} q_t C + sum_s e^{b_t-b_s+i_s-m_t}(q_t.k_s) v_s]
                / max(|e^{b_t+m-m_t} q_t.n + sum_s e^{...}(q_t.k_s)|, e^{-m_t})

Matches :func:`repro.kernels.ref.mlstm_chunkwise_ref` (quadratic oracle).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["mlstm_chunkwise"]

NEG_INF = -1e30


def _mlstm_kernel(
    q_ref,  # (1, L, D)
    k_ref,  # (1, L, D)
    v_ref,  # (1, L, D)
    i_ref,  # (1, L)
    f_ref,  # (1, L)
    o_ref,  # (1, L, D)
    c_scr,  # (D, D) f32
    n_scr,  # (1, D) f32
    m_scr,  # (1, 1) f32
    *,
    L: int,
    scale: float,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        # empty state: max-tracker = -inf so inter terms vanish exactly
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(jnp.float32)  # (L, D)
    k = k_ref[0].astype(jnp.float32) * scale
    v = v_ref[0].astype(jnp.float32)
    ig = i_ref[0].astype(jnp.float32)  # (L,)
    lf = jax.nn.log_sigmoid(f_ref[0].astype(jnp.float32))  # (L,)

    b = jnp.cumsum(lf)  # (L,)
    g = b[L - 1]
    m_prev = m_scr[0, 0]
    C_prev = c_scr[...]
    n_prev = n_scr[0]

    # --- intra-chunk decay matrix -----------------------------------
    Dm = b[:, None] - b[None, :] + ig[None, :]  # (L_t, L_s)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    causal = s_idx <= t_idx
    Dm = jnp.where(causal, Dm, NEG_INF)

    m_inter = b + m_prev  # (L,)
    m_comb = jnp.maximum(jnp.max(Dm, axis=1), m_inter)  # (L,)

    dexp = jnp.exp(Dm - m_comb[:, None])  # (L, L)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    w = scores * dexp
    inter_w = jnp.exp(m_inter - m_comb)  # (L,)

    num = jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + inter_w[:, None] * jax.lax.dot_general(
        q, C_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den = jnp.sum(w, axis=1) + inter_w * jnp.sum(q * n_prev[None, :], axis=1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # --- state update -------------------------------------------------
    key_w = g - b + ig  # (L,)
    m_new = jnp.maximum(g + m_prev, jnp.max(key_w))
    kw = jnp.exp(key_w - m_new)  # (L,)
    decay = jnp.exp(g + m_prev - m_new)
    kscaled = k * kw[:, None]
    c_scr[...] = decay * C_prev + jax.lax.dot_general(
        kscaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_scr[0] = decay * n_prev + jnp.sum(kscaled, axis=0)
    m_scr[0, 0] = m_new


def mlstm_chunkwise(
    q: jnp.ndarray,  # (B, T, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # (B, T, H)
    f_gate: jnp.ndarray,  # (B, T, H)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas chunkwise mLSTM; see :func:`repro.kernels.ref.mlstm_chunkwise_ref`."""
    B, T, H, D = q.shape
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    scale = 1.0 / math.sqrt(D)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    it = i_gate.transpose(0, 2, 1).reshape(B * H, T)
    ft = f_gate.transpose(0, 2, 1).reshape(B * H, T)

    grid = (B * H, T // L)
    kernel = functools.partial(_mlstm_kernel, L=L, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, D), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, L), lambda bh, c: (bh, c)),
        ],
        out_specs=pl.BlockSpec((1, L, D), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt, it, ft)
    return out.reshape(B, H, T, D).transpose(0, 2, 1, 3)
