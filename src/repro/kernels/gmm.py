"""Grouped matmul (MoE expert FFN) in Pallas (TPU).

After MoE routing, tokens are sorted by expert: row-block ``m`` of the sorted
activation matrix belongs to exactly one expert (the dispatcher pads each
group to a multiple of the row-block size).  The expert id per row-block is
delivered through scalar prefetch so the ``rhs`` BlockSpec can select the
right expert's weights — no (tokens, experts) one-hot and no weight gather
ever materializes in HBM.

Grid = (M/bm, N/bn, K/bk); K is innermost/sequential with an (bm, bn) fp32
VMEM accumulator; 128-aligned tiles keep the MXU busy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["gmm"]


def _gmm_kernel(
    gid_ref,  # scalar prefetch: (M/bm,) int32 group id per row block
    lhs_ref,  # (bm, bk)
    rhs_ref,  # (1, bk, bn)
    out_ref,  # (bm, bn)
    acc_scr,  # (bm, bn) f32
    *,
    k_steps: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32),
        rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == k_steps - 1)
    def _done():
        out_ref[...] = acc_scr[...].astype(out_ref.dtype)


def gmm(
    lhs: jnp.ndarray,  # (M, K) rows sorted by group, groups padded to bm
    rhs: jnp.ndarray,  # (G, K, N)
    group_ids: jnp.ndarray,  # (M // bm,) int32: group of each row block
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas grouped matmul; see :func:`repro.kernels.ref.gmm_ref`.

    The caller guarantees every row block is homogeneous (group boundaries
    aligned to ``block_m``) and passes the per-block group ids.
    """
    M, K = lhs.shape
    G, K2, N = rhs.shape
    assert K == K2, (K, K2)
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, bm, N, bn, K, bk)
    assert group_ids.shape == (M // bm,), group_ids.shape

    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_gmm_kernel, k_steps=K // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k, gid: (m, k)),
            pl.BlockSpec((1, bk, bn), lambda m, n, k, gid: (gid[m], k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, gid: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(group_ids.astype(jnp.int32), lhs, rhs)
