"""Pallas TPU kernels for the substrate's compute hot-spots.

The paper's own contribution is a scheduling layer (no custom device
kernels); these kernels optimize the LM substrate the cluster layer
schedules — attention, selective-SSM, mLSTM and MoE grouped matmul.

Each kernel ships with a pure-jnp oracle (:mod:`repro.kernels.ref`) and a
dispatching wrapper (:mod:`repro.kernels.ops`).  Kernels are validated in
interpret mode on CPU; ``pallas`` impl is the TPU deployment path.
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.mlstm import mlstm_chunkwise

__all__ = [
    "ops",
    "ref",
    "flash_attention",
    "gmm",
    "mamba_scan",
    "mlstm_chunkwise",
]
