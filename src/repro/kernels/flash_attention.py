"""Flash attention for TPU in Pallas (GQA + causal + sliding window + softcap).

TPU-native design (DESIGN.md §2 hardware adaptation):
* grid = (batch*q_heads, Sq/bq, Sk/bk); the innermost (kv) dimension is
  sequential ("arbitrary") so the online-softmax state — running max ``m``,
  normalizer ``l`` and output accumulator ``acc`` — lives in VMEM scratch and
  persists across kv steps of one (bh, q-block).
* BlockSpecs tile q/k/v into VMEM as (bq, D) / (bk, D) slabs; matmul shapes
  (bq x D) @ (D x bk) and (bq x bk) @ (bk x D) keep the MXU fed with
  128-aligned dims.  fp32 accumulation; bf16 inputs.
* GQA is expressed through the k/v index_map (query head h reads kv head
  ``h // q_per_kv``) — no KV replication in HBM.
* causal + sliding-window masks are computed from block offsets with iota;
  fully-masked kv blocks are skipped via ``pl.when`` on block indices.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # (1, bq, D)
    k_ref,  # (1, bk, D)
    v_ref,  # (1, bk, D)
    o_ref,  # (1, bq, D)
    m_scr,  # (bq,) f32 scratch
    l_scr,  # (bq,) f32 scratch
    acc_scr,  # (bq, D) f32 scratch
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    bq: int,
    bk: int,
    kv_steps: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset  # absolute position of first query row
    k_start = ki * bk

    # block-level skip: entirely above the diagonal, or entirely left of the
    # sliding window
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + bq - 1
    if window is not None:
        run &= k_start + bk - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas flash attention; see :func:`repro.kernels.ref.attention_ref`."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    kv_steps = Sk // bk

    # (B, S, H, D) -> (B*H, S, D) layout for clean 2-D blocks
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)

    grid = (B * Hq, Sq // bq, kv_steps)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq,
        bk=bk,
        kv_steps=kv_steps,
        q_offset=q_offset,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
