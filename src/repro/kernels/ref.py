"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*: each Pallas kernel must match its oracle
to float tolerance (tests/test_kernels.py sweeps shapes/dtypes).  They are
also the CPU execution path of the model substrate (``use_pallas=False``) —
the dry-run lowers these, the TPU deployment lowers the Pallas kernels.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "mamba_scan_ref",
    "mlstm_chunkwise_ref",
    "mlstm_chunked_scan",
    "gmm_ref",
]


# ------------------------------ attention ---------------------------------


def _attn_mask(
    q_pos: jnp.ndarray,  # (Sq,)
    k_pos: jnp.ndarray,  # (Sk,)
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """Boolean mask (Sq, Sk): True = attend."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return ok


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """GQA attention with optional causal/sliding-window mask and softcap.

    ``q_offset`` places the query block at absolute positions
    ``[q_offset, q_offset + Sq)`` against keys at ``[0, Sk)`` (decode).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = _attn_mask(q_pos, k_pos, causal, window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, not NaN
    probs = jnp.where(jnp.any(mask, axis=-1)[None, None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ------------------------------ mamba scan ---------------------------------


def mamba_scan_ref(
    x: jnp.ndarray,  # (B, T, Di)
    dt: jnp.ndarray,  # (B, T, Di)  (already softplus'd)
    A: jnp.ndarray,  # (Di, N)     (negative; continuous-time)
    Bmat: jnp.ndarray,  # (B, T, N)
    Cmat: jnp.ndarray,  # (B, T, N)
    D: jnp.ndarray,  # (Di,)
) -> jnp.ndarray:
    """Selective SSM scan (Mamba-1 semantics), sequential over T.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t;  y_t = C_t . h_t + D x_t
    """
    Bsz, T, Di = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs  # (B,Di) (B,Di) (B,N) (B,N)
        dA = jnp.exp(dt_t[..., None] * Af[None])  # (B, Di, N)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]  # (B, Di, N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    from repro.distributed.hints import hint  # lazy: avoids import cycle

    h0 = hint(jnp.zeros((Bsz, Di, N), jnp.float32), "dp", "model")
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype)


# --------------------------- mLSTM (chunkwise) ------------------------------


def mlstm_chunkwise_ref(
    q: jnp.ndarray,  # (B, T, H, D)
    k: jnp.ndarray,  # (B, T, H, D)
    v: jnp.ndarray,  # (B, T, H, D)
    i_gate: jnp.ndarray,  # (B, T, H)  pre-activation (exponential gate)
    f_gate: jnp.ndarray,  # (B, T, H)  pre-activation (sigmoid-ish, via logsigmoid)
) -> jnp.ndarray:
    """mLSTM with matrix memory and exponential gating (xLSTM paper, eq. 19-27).

    Numerically-stabilized parallel (quadratic-in-T) formulation — the oracle
    for the chunkwise Pallas kernel.  Per head:
      F_t = cumsum(logsigmoid(f)); D_{ts} = F_t - F_s + i_s  (s <= t)
      out_t = sum_s exp(D_ts - m_t) (q_t . k_s / sqrt(d)) v_s / denom
      denom = max(|sum_s exp(D_ts - m_t) q.k|, exp(-m_t))
    """
    B, T, H, D = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) / math.sqrt(D)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,T,H)
    ii = i_gate.astype(jnp.float32)

    F = jnp.cumsum(lf, axis=1)  # (B,T,H)
    # Dmat[b,h,t,s] = F_t - F_s + i_s for s<=t else -inf
    Dmat = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]  # (B,T_t,T_s,H)? fix axes
    Dmat = jnp.transpose(Dmat, (0, 3, 1, 2))  # (B,H,T,S)
    causal = jnp.tril(jnp.ones((T, T), bool))
    Dmat = jnp.where(causal[None, None], Dmat, -jnp.inf)
    m = jnp.max(Dmat, axis=-1, keepdims=True)  # (B,H,T,1)
    m = jnp.maximum(m, -1e30)  # rows are never fully masked (s=t allowed)
    Dexp = jnp.exp(Dmat - m)

    scores = jnp.einsum("bthd,bshd->bhts", qf, kf)  # (B,H,T,S)
    w = scores * Dexp
    num = jnp.einsum("bhts,bshd->bthd", w, vf)
    den = jnp.abs(jnp.sum(w, axis=-1))  # (B,H,T)
    den = jnp.maximum(den, jnp.exp(-m[..., 0]))
    out = num / den.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def mlstm_chunked_scan(
    q: jnp.ndarray,  # (B, T, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # (B, T, H)
    f_gate: jnp.ndarray,  # (B, T, H)
    chunk: int = 256,
) -> jnp.ndarray:
    """Chunkwise mLSTM in pure lax — O(T*L) memory (the model path).

    Mathematically identical to :func:`mlstm_chunkwise_ref` (the quadratic
    oracle) and to the Pallas kernel: per-chunk masked attention-like intra
    term + carried (C, n, m) inter-chunk state.  ``lax.scan`` over chunks.
    """
    B, T, H, D = q.shape
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L
    scale = 1.0 / math.sqrt(D)

    # (B,T,H,*) -> (nc, B, H, L, *)
    def rs(x, dlast):
        x = x.reshape(B, nc, L, H, dlast) if dlast > 1 else x.reshape(B, nc, L, H)
        return jnp.moveaxis(x, 1, 0).swapaxes(2, 3)  # (nc, B, H, L, dlast?)

    qf = rs(q.astype(jnp.float32), D)
    kf = rs(k.astype(jnp.float32) * scale, D)
    vf = rs(v.astype(jnp.float32), D)
    ii = rs(i_gate.astype(jnp.float32), 1)
    lf = rs(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)), 1)

    t_idx = jnp.arange(L)
    causal = t_idx[:, None] >= t_idx[None, :]

    def step(carry, xs):
        C_p, n_p, m_p = carry  # (B,H,D,D) (B,H,D) (B,H)
        qc, kc, vc, ic, lc = xs  # (B,H,L,D) ... (B,H,L)
        b = jnp.cumsum(lc, axis=-1)  # (B,H,L)
        g = b[..., -1]  # (B,H)
        Dm = b[..., :, None] - b[..., None, :] + ic[..., None, :]  # (B,H,L,L)
        Dm = jnp.where(causal[None, None], Dm, -1e30)
        m_inter = b + m_p[..., None]  # (B,H,L)
        m_comb = jnp.maximum(jnp.max(Dm, axis=-1), m_inter)
        dexp = jnp.exp(Dm - m_comb[..., None])
        scores = jnp.einsum("bhld,bhsd->bhls", qc, kc)
        w = scores * dexp
        inter_w = jnp.exp(m_inter - m_comb)  # (B,H,L)
        num = jnp.einsum("bhls,bhsd->bhld", w, vc) + inter_w[..., None] * jnp.einsum(
            "bhld,bhde->bhle", qc, C_p
        )
        den = jnp.sum(w, axis=-1) + inter_w * jnp.einsum("bhld,bhd->bhl", qc, n_p)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))
        out = num / den[..., None]  # (B,H,L,D)
        # state update
        key_w = g[..., None] - b + ic  # (B,H,L)
        m_new = jnp.maximum(g + m_p, jnp.max(key_w, axis=-1))
        kw = jnp.exp(key_w - m_new[..., None])
        decay = jnp.exp(g + m_p - m_new)
        C_n = decay[..., None, None] * C_p + jnp.einsum(
            "bhld,bhle->bhde", kc * kw[..., None], vc
        )
        n_n = decay[..., None] * n_p + jnp.sum(kc * kw[..., None], axis=-2)
        return (C_n, n_n, m_new), out

    from repro.distributed.hints import hint  # lazy: avoids import cycle

    carry0 = (
        hint(jnp.zeros((B, H, D, D), jnp.float32), "dp"),
        hint(jnp.zeros((B, H, D), jnp.float32), "dp"),
        hint(jnp.full((B, H), -1e30, jnp.float32), "dp"),
    )
    _, outs = jax.lax.scan(step, carry0, (qf, kf, vf, ii, lf))
    # (nc, B, H, L, D) -> (B, T, H, D)
    out = jnp.moveaxis(outs, 0, 1).swapaxes(2, 3).reshape(B, T, H, D)
    return out.astype(q.dtype)


# ------------------------------ grouped matmul ------------------------------


def gmm_ref(
    lhs: jnp.ndarray,  # (M, K) tokens sorted by group
    rhs: jnp.ndarray,  # (G, K, N) per-group weights
    group_sizes: jnp.ndarray,  # (G,) int32, sum == M
) -> jnp.ndarray:
    """Grouped matmul: rows of ``lhs`` hit their group's ``rhs`` matrix.

    Semantics of ``jax.lax.ragged_dot`` (MoE expert FFN after token sort).
    """
    M, K = lhs.shape
    G, _, N = rhs.shape
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(M)
    # group id per row
    gid = jnp.sum(row[:, None] >= ends[None, :], axis=1)  # (M,)
    w = rhs[gid]  # (M, K, N) gather — oracle only; kernel never materializes
    out = jnp.einsum("mk,mkn->mn", lhs.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(lhs.dtype)
