"""Gradient compression with error feedback (beyond-paper distributed trick).

Cross-pod data-center interconnect (DCI) is the scarcest bandwidth on the
multi-pod mesh, and the cross-pod traffic is exactly one gradient all-reduce
per step.  int8 block-quantized all-reduce cuts those bytes 4x vs fp32 (2x vs
bf16); the quantization error is carried in an error-feedback buffer so the
*accumulated* update stays unbiased (EF-SGD / 1-bit-Adam lineage).

``compressed_psum`` composes with ``jax.shard_map`` over the ``pod`` axis;
the pure quantization math is tested standalone (tests/test_compression.py).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "ef_compress",
    "compressed_psum",
]

_BLOCK = 2048  # quantization block (per-block scales bound the error)


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _BLOCK), pad


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """x (any shape) -> (int8 blocks, fp32 per-block scales, pad)."""
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(
    q: jnp.ndarray, scale: jnp.ndarray, pad: int, shape: Tuple[int, ...]
) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_compress(x: jnp.ndarray, error: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression: returns (decoded(x+error), new_error)."""
    target = x.astype(jnp.float32) + error
    q, s, pad = quantize_int8(target)
    decoded = dequantize_int8(q, s, pad, x.shape)
    return decoded, target - decoded


def compressed_psum(
    grads: Any, error: Any, axis_name: str
) -> Tuple[Any, Any]:
    """Per-leaf int8 EF-quantized psum over ``axis_name`` (inside shard_map).

    Returns (reduced grads fp32, new error tree).  int8 payloads are summed
    in int32 (no overflow for pod counts << 2^23) and rescaled by the mean of
    participating scales — a standard compressed-allreduce approximation
    whose residual lands in the error buffer next step.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s, pad = quantize_int8(target)
        decoded_local = dequantize_int8(q, s, pad, g.shape)
        new_e = target - decoded_local
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale = jax.lax.pmean(s, axis_name)
        reduced = dequantize_int8(summed, scale, pad, g.shape)
        return reduced, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e, strict=True)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return red, new_err
