"""Train / serve step builders (pjit-ready pure functions).

``make_train_step`` supports microbatch gradient accumulation via lax.scan —
required for the biggest assigned archs: with layer-scan remat the saved
residuals scale with the *microbatch*, so accumulation bounds live
activations (EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import loss_fn as model_loss
from repro.models import decode_step as model_decode
from repro.models import forward as model_forward
from repro.models.config import ArchConfig
from repro.optim import AdamW

__all__ = ["make_train_step", "make_serve_step", "make_prefill_step"]


def make_train_step(
    cfg: ArchConfig,
    optimizer: AdamW,
    accum_steps: int = 1,
    impl: str = "auto",
    grad_accum_dtype: str = "float32",
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_accum_dtype="bfloat16"`` halves the accumulation carry — used by
    the >=100B archs where the fp32 grad tree alone is ~5 GB/device
    (EXPERIMENTS.md §Dry-run memory notes).  The adds still run in fp32.
    """
    acc_dt = jnp.dtype(grad_accum_dtype)

    def loss_of(params, batch):
        return model_loss(cfg, params, batch, impl=impl)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            # split leading batch dim into (accum, micro) and scan
            def reshape(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree_util.tree_map(reshape, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )

            def body(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: (
                        a.astype(jnp.float32) + b_.astype(jnp.float32)
                    ).astype(acc_dt),
                    g_acc,
                    g,
                )
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / accum_steps, grads
            )
            loss = loss / accum_steps

        params2, opt_state2 = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt_state2.step}
        return params2, opt_state2, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, impl: str = "auto") -> Callable:
    """Returns serve_step(params, cache, token, index) -> (logits, cache).

    One new token per request with the KV cache / recurrent state carried —
    the ``decode_*`` and ``long_*`` dry-run shapes lower this function.
    """

    def serve_step(params, cache, token, index, enc_out=None):
        return model_decode(cfg, params, cache, token, index, enc_out=enc_out, impl=impl)

    return serve_step


def make_prefill_step(cfg: ArchConfig, impl: str = "auto") -> Callable:
    """Returns prefill_step(params, batch) -> last-position logits."""

    def prefill_step(params, batch):
        logits, _ = model_forward(cfg, params, batch, impl=impl)
        return logits[:, -1, :]

    return prefill_step
