"""Sharding rules: FSDP x TP 2-D parameter sharding, EP for MoE, SP for
long-context decode.

Mesh axes:
* ``data``  — batch / FSDP axis (16 per pod),
* ``model`` — tensor-parallel / expert-parallel / sequence axis (16 per pod),
* ``pod``   — present on the multi-pod mesh; pure data parallelism
              (parameters replicated across pods, gradients reduced over it).

Parameter rule: 2-D weights are sharded (contract-dim -> ``data`` [FSDP,
gathered at use], parallel-dim -> ``model`` [Megatron TP, stays sharded]).
Expert stacks put the expert dim on ``model`` (EP).  Rules are resolved by
leaf *name* via tree paths, so one table covers every architecture.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DP_AXES",
    "param_spec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "out_shardings_like",
]

# batch ("data-parallel") axes: pod axis, when present, is outermost DP
DP_AXES = ("pod", "data")


def _dp(mesh: Mesh) -> Any:
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


# --------------------------- parameter rules -------------------------------

# leaf name -> spec template for the UNSTACKED (per-layer) array.
# "D" = data axis, "M" = model axis, None = replicated dim.
_RULES = {
    # projections: (in, out)
    "wq": ("D", "M"),
    "wk": ("D", "M"),
    "wv": ("D", "M"),
    "wo": ("M", "D"),
    "w_up": ("D", "M"),
    "w_gate": ("D", "M"),
    "w_down": ("M", "D"),
    "w_ffn_up": ("D", "M"),
    "w_ffn_down": ("M", "D"),
    "w_in": ("D", "M"),
    "w_out": ("M", "D"),
    "w_xdbc": ("M", None),
    "w_dt": (None, "M"),
    "w_i": ("M", None),
    "w_f": ("M", None),
    "w_z": ("D", "M"),
    "w_o": ("D", "M"),
    # embeddings: (vocab/time, d_model)
    "embed": ("M", "D"),
    "unembed": ("M", "D"),
    "pos": (None, "D"),
    # misc
    "router": ("D", None),
    "conv": (None, "M"),
    "log_a": ("M", None),
    "dt_bias": ("M",),
    "d_skip": ("M",),
    "scale": (None,),
    "bias": (None,),
    # sLSTM recurrent blocks (small, head-blocked)
    "r_i": (None, None, None),
    "r_f": (None, None, None),
    "r_z": (None, None, None),
    "r_o": (None, None, None),
}

# MoE expert stacks carry a leading expert dim -> model axis (EP); the
# per-expert matrices are then FSDP-sharded on their d_model dim.
_MOE_RULES = {
    "w_up": ("M", "D", None),
    "w_gate": ("M", "D", None),
    "w_down": ("M", None, "D"),
}


def _axis(token: Optional[str]) -> Optional[str]:
    return {"D": "data", "M": "model", None: None}[token]


def param_spec(path: Tuple[Any, ...], leaf: Any) -> P:
    """PartitionSpec for one parameter leaf, from its tree path."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf_name = names[-1]
    in_moe = "moe" in names
    in_blocks = "blocks" in names

    if in_moe and leaf_name in _MOE_RULES:
        base = _MOE_RULES[leaf_name]
    elif leaf_name in _RULES:
        base = _RULES[leaf_name]
    else:
        base = (None,) * (leaf.ndim - (2 if in_blocks else 0) - ("layers" in names))

    spec = [_axis(t) for t in base]
    # stacked leading axes: pattern repeats (blocks) / encoder layer stack
    ndim = leaf.ndim
    while len(spec) < ndim:
        spec.insert(0, None)
    if len(spec) > ndim:  # e.g. rules longer than a squeezed leaf
        spec = spec[-ndim:]
    # drop shardings that don't divide the dim evenly
    return P(*spec)


def _validated(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec)), strict=False):
        if ax is None:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_shardings(params: Any, mesh: Mesh, mode: str = "train") -> Any:
    """NamedSharding tree matching a parameter (or abstract-param) tree.

    ``mode="serve"``: inference keeps weights *resident* — the FSDP ("data")
    dimension is dropped from every spec (pure TP/EP) whenever the resulting
    per-device footprint fits HBM.  Without this, decode steps all-gather the
    FSDP shards every token (§Perf hillclimb 2: mixtral decode was spending
    181 GB/device/token on weight gathers).  Models too big for 1-axis
    sharding (nemotron-340b) keep the 2-D layout.
    """
    serve = mode == "serve"
    if serve:
        total_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(params)
        )
        # would pure model-axis sharding fit comfortably (<= half of HBM)?
        per_dev = total_bytes / mesh.shape["model"]
        serve = per_dev <= 8 * 1024**3

    def mk(path, leaf):
        spec = param_spec(path, leaf)
        if serve:
            spec = P(*[None if ax == "data" else ax for ax in spec])
            names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
            leaf_name = names[-1]
            if "moe" in names and leaf_name in _MOE_RULES:
                E = leaf.shape[-3] if leaf.ndim >= 3 else 0
                if E % mesh.shape["model"] != 0:
                    # EP impossible (E < axis): TP-shard the expert FFN dims
                    # (contraction-dim psum at decode is tokens-sized, tiny)
                    base = (
                        (None, None, "model")
                        if leaf_name in ("w_up", "w_gate")
                        else (None, "model", None)
                    )
                    spec = P(*([None] * (leaf.ndim - 3)), *base)
        spec = _validated(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(mk, params)


# --------------------------- activations -----------------------------------


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    """Input batch: leading (batch) dim over the DP axes, rest replicated."""
    dp = _dp(mesh)

    def mk(leaf):
        dims = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
        first = dp if leaf.shape and leaf.shape[0] % dims == 0 else None
        return NamedSharding(mesh, P(first, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(mk, batch)


def cache_shardings(cache: Any, mesh: Mesh, batch: int) -> Any:
    """Decode-state sharding.

    KV caches (stacked: (R, B, L, H, D)) shard batch over the DP axes when it
    divides evenly; the sequence dim takes the ``model`` axis (SP — the 32k
    KV cache is the dominant decode footprint) and, for batch=1 long-context,
    whatever DP axes are idle join the sequence dim.
    Recurrent states (mamba/xlstm) shard their channel dims on ``model``.
    """
    dp = _dp(mesh)
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))

    def mk(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        leaf_name = names[-1]
        if leaf_name in ("k", "v") and leaf.ndim == 5:  # (R, B, L, H, D)
            _, B, L, H, D = leaf.shape
            if B % dp_size == 0:
                seq_ax = "model" if L % mesh.shape["model"] == 0 else None
                return NamedSharding(mesh, P(None, dp, seq_ax, None, None))
            # tiny batch (long-context): give the sequence every axis we can
            seq_axes = tuple(
                a for a in ("data", "model") if L % mesh.shape[a] == 0
            )
            if len(seq_axes) == 2 and L % (mesh.shape["data"] * mesh.shape["model"]) != 0:
                seq_axes = ("model",)
            spec = seq_axes if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
            return NamedSharding(mesh, P(None, None, spec, None, None))
        if leaf_name in ("h", "C") and leaf.ndim >= 3:  # recurrent states
            B = leaf.shape[1]
            bspec = dp if B % dp_size == 0 else None
            rest = [None] * (leaf.ndim - 2)
            if leaf.ndim >= 3 and leaf.shape[2] % mesh.shape["model"] == 0:
                rest[0] = "model"
            return NamedSharding(mesh, P(None, bspec, *rest))
        # conv windows / norm stats / small states
        B = leaf.shape[1] if leaf.ndim > 1 else 0
        bspec = dp if B and B % dp_size == 0 else None
        return NamedSharding(
            mesh, P(None, bspec, *([None] * max(leaf.ndim - 2, 0)))
        )

    return jax.tree_util.tree_map_with_path(mk, cache)


def out_shardings_like(tree: Any, mesh: Mesh) -> Any:
    """Replicated output shardings for scalars/metrics."""
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree
    )
