"""Fault tolerance & straggler mitigation for the cluster layer.

At thousand-node scale the scheduler IS the recovery mechanism (DESIGN.md §5):

* **heartbeats**: every slice (sub-mesh) posts a heartbeat; a missed-deadline
  monitor marks the slice failed,
* **failure handling**: jobs on a failed slice are preempted back to the
  queue with their last-checkpoint progress (work since the last checkpoint
  is lost — the simulator charges it); the repartitioning policy then picks a
  configuration for the *surviving* slots, i.e. the paper's mechanism doubles
  as elastic down-scaling,
* **stragglers**: a slice whose observed service rate falls below
  ``straggler_factor`` of nominal is drained and its jobs re-dispatched
  (speculative re-execution is pointless under MIG-style isolation — the
  paper's preemption machinery already moves work for free),
* **elastic resume**: checkpoint restore onto a different mesh is exercised
  in tests/test_checkpoint.py via sharding-targeted restore.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HeartbeatMonitor", "FailureModel", "StragglerDetector"]


@dataclasses.dataclass
class FailureModel:
    """Poisson slice failures + deterministic repair times (simulation)."""

    mtbf_minutes: float = 7 * 24 * 60.0  # per-slice mean time between failures
    repair_minutes: float = 30.0
    checkpoint_interval_min: float = 10.0  # job progress lost since last ckpt
    seed: int = 0

    def sample_failures(
        self, num_slices: int, horizon_min: float
    ) -> List[Tuple[float, int, float]]:
        """Returns [(t_fail, slice_idx, t_repaired)] sorted by time."""
        rng = np.random.default_rng(self.seed)
        events = []
        for s in range(num_slices):
            t = 0.0
            while True:
                t += rng.exponential(self.mtbf_minutes)
                if t >= horizon_min:
                    break
                events.append((t, s, t + self.repair_minutes))
        events.sort()
        return events

    def lost_work(self, progress_since_ckpt: float) -> float:
        """Work lost on failure = progress since the last checkpoint."""
        return min(progress_since_ckpt, self.checkpoint_interval_min)


class HeartbeatMonitor:
    """Deadline-based liveness: slice must beat every ``interval`` minutes."""

    def __init__(self, interval_min: float = 1.0, misses_to_fail: int = 3) -> None:
        self.interval = interval_min
        self.misses_to_fail = misses_to_fail
        self.last_beat: Dict[int, float] = {}
        self.failed: set = set()

    def beat(self, slice_idx: int, t: float) -> None:
        self.last_beat[slice_idx] = t
        self.failed.discard(slice_idx)

    def check(self, t: float) -> List[int]:
        """Slices newly declared failed at time t."""
        newly = []
        for s, last in self.last_beat.items():
            if s in self.failed:
                continue
            if t - last > self.interval * self.misses_to_fail:
                self.failed.add(s)
                newly.append(s)
        return newly


class StragglerDetector:
    """EWMA service-rate tracking; flags slices below factor x nominal."""

    def __init__(self, straggler_factor: float = 0.7, alpha: float = 0.3) -> None:
        self.factor = straggler_factor
        self.alpha = alpha
        self.rate_ewma: Dict[int, float] = {}

    def observe(self, slice_idx: int, observed_rate: float, nominal_rate: float) -> bool:
        """Update EWMA; returns True if the slice is now a straggler."""
        prev = self.rate_ewma.get(slice_idx, nominal_rate)
        ewma = self.alpha * observed_rate + (1 - self.alpha) * prev
        self.rate_ewma[slice_idx] = ewma
        return ewma < self.factor * nominal_rate

    def reset(self, slice_idx: int) -> None:
        self.rate_ewma.pop(slice_idx, None)
