"""Best-effort sharding hints usable from model code.

``hint(x, *axes)`` applies ``with_sharding_constraint`` with the requested
logical axes when (a) tracing under a mesh context, (b) every named axis
exists on that mesh, and (c) the dim divides evenly — otherwise it is a
no-op.  This lets substrate code (scan carries, MoE buffers) pin the layouts
GSPMD propagation gets wrong without coupling model code to any mesh.

Axis tokens: "dp" (all data-parallel axes: pod+data), "data", "model", None.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["hint"]


def _mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - very old jax
        return None
    if mesh is None or not mesh.axis_names:
        return None
    return mesh


def hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain array dims to mesh axes; silently no-op when impossible."""
    mesh = _mesh_axes()
    if mesh is None:
        return x
    names = mesh.axis_names
    shape = dict(zip(names, mesh.shape.values(), strict=True)) if hasattr(mesh, "shape") else {}

    spec = []
    for dim, ax in zip(x.shape, axes, strict=True):
        if ax == "dp":
            cand = tuple(a for a in ("pod", "data") if a in names)
            ax = cand if len(cand) > 1 else (cand[0] if cand else None)
        if ax is None:
            spec.append(None)
            continue
        ax_t = ax if isinstance(ax, tuple) else (ax,)
        if not all(a in names for a in ax_t):
            spec.append(None)
            continue
        size = int(np.prod([shape.get(a, 1) for a in ax_t]))
        spec.append(ax if dim % max(size, 1) == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
