"""Distributed runtime: sharding rules, collectives, compression, fault
tolerance.  Meshes themselves are built in :mod:`repro.launch.mesh`.
"""

from repro.distributed.sharding import (
    param_shardings,
    batch_shardings,
    cache_shardings,
    DP_AXES,
)

__all__ = ["param_shardings", "batch_shardings", "cache_shardings", "DP_AXES"]
