"""Checkpoint store: flat-key npz shards + JSON manifest.

Design (scaled-down from multi-host object stores, same layout discipline):

* the parameter/optimizer pytree is flattened to ``path/to/leaf`` keys,
* leaves are written in volume-bounded npz *shards* so no single file
  explodes and writes parallelize,
* a JSON manifest records tree structure, shapes, dtypes, step and the
  writing mesh for audit,
* **elastic resume**: restore takes the *target* sharding tree — leaves are
  re-laid-out via ``jax.device_put``, so a checkpoint written on one mesh
  (e.g. 16x16) restores onto another (e.g. 2x16x16 or a CPU smoke mesh),
* async: ``CheckpointManager.save_async`` hands the host copy to a writer
  thread; training continues (fault-tolerance drill in tests).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_SHARD_BYTES = 1 << 30  # 1 GiB per npz shard

# npz stores ml_dtypes arrays as raw void; store them as unsigned views and
# re-view from the manifest's logical dtype on restore.
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path
        )
        arr = np.asarray(leaf)
        flat[key] = arr
    return flat


def _to_storable(v: np.ndarray) -> np.ndarray:
    pair = _VIEW_DTYPES.get(str(v.dtype))
    return v.view(pair[1]) if pair is not None else v


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    pair = _VIEW_DTYPES.get(logical_dtype)
    return arr.view(pair[0]) if pair is not None else arr


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Write one checkpoint; returns its directory."""
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    flat = _flatten(tree)

    shards: List[Dict[str, np.ndarray]] = [{}]
    sizes = [0]
    for k, v in flat.items():
        if sizes[-1] + v.nbytes > _SHARD_BYTES and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][k] = v
        sizes[-1] += v.nbytes

    manifest = {
        "step": step,
        "keys": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype), "shard": si}
            for si, sh in enumerate(shards)
            for k, v in sh.items()
        },
        "num_shards": len(shards),
        "extra": extra or {},
        "written_at": time.time(),
    }
    for si, sh in enumerate(shards):
        np.savez(
            os.path.join(tmp_dir, f"shard_{si:04d}.npz"),
            **{k: _to_storable(v) for k, v in sh.items()},
        )
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp_dir, ckpt_dir)  # atomic publish
    return ckpt_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    target_tree: Any,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (optional tree of NamedSharding) enables elastic resume:
    each leaf is device_put with the *target* layout regardless of the mesh
    that wrote the checkpoint.
    """
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    shard_files = [
        np.load(os.path.join(ckpt_dir, f"shard_{si:04d}.npz"))
        for si in range(manifest["num_shards"])
    ]
    flat: Dict[str, np.ndarray] = {}
    for k, info in manifest["keys"].items():
        flat[k] = _from_storable(shard_files[info["shard"]][k], info["dtype"])

    leaves_with_path = jax.tree_util.tree_leaves_with_path(target_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves_with_path)
    )
    out_leaves = []
    for (path, leaf), shd in zip(leaves_with_path, shard_leaves, strict=True):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != target {want_shape}")
        want_dtype = leaf.dtype
        # cast via jnp: numpy lacks cast kernels for ml_dtypes (bf16) arrays
        arr_j = jax.numpy.asarray(arr)
        if arr_j.dtype != want_dtype:
            arr_j = arr_j.astype(want_dtype)
        out_leaves.append(jax.device_put(arr_j, shd) if shd is not None else arr_j)
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class CheckpointManager:
    """Async writer + retention policy (keep last N)."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # pragma: no cover - surfaced in wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
