"""Fleet dispatchers: route arriving jobs to MIG-capable devices.

The default fleet execution is *online* (see :mod:`repro.fleet.simulator`):
per-device simulation engines are co-advanced to each arrival on a merged
event clock, and the dispatcher observes **real** device state — actual
outstanding work, queue depth, the current partition, and any in-flight
repartition — through :class:`EngineDeviceState` views over live engine
snapshots.  The legacy *fluid* mode (``dispatch_info="fluid"``) instead
walks the arrival stream once against a cheap backlog estimate that drains
at the device's peak slot count — the first-order model the MIG cluster
schedulers use for placement scoring (Tan et al.; Zambianco et al.).  The
``dispatchers`` sweep grid measures the online-vs-fluid gap.

Every dispatcher consumes one typed argument, a :class:`DispatchContext`:
the arriving job, the arrival instant, and a device-state view per fleet
member.  Both execution modes build the same context type — the fluid mode
fills it with :class:`DeviceLoadState` estimates, the online mode with
:class:`EngineDeviceState` engine views — so a dispatcher is written once
against :class:`DeviceState` and the context says (``ctx.online``) which
fidelity it is getting.  The pre-context call shape ``pick(job, t,
states)`` is still accepted through a deprecation shim
(:func:`as_context_dispatcher`), so external dispatchers keep working and
existing sweep cells hash identically.

Dispatchers (all deterministic):

* ``round-robin``         — arrival index modulo fleet size (the baseline);
* ``least-loaded``        — smallest normalized backlog (backlog / peak slots);
* ``energy-greedy``       — smallest *marginal power* for one more busy slot
  at the device's estimated utilization: exploits the concave Fig. 3 curve
  by packing onto already-hot devices and preferring low-power devices when
  everything is idle;
* ``state-aware``         — online-only: minimizes an expected-start-delay
  proxy built from real state (normalized backlog + remaining repartition
  stall + a congestion step when no slice is free), breaking ties toward
  the cheaper marginal watt;
* ``fragmentation-aware`` — online-only: the state-aware delay proxy plus a
  slice-fit term (can the device place the request's slice class right
  now?) and a post-placement fragmentation penalty over the free-slot
  geometry (DESIGN.md §9) — the 2512.16099-style serving dispatcher.
"""

from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    runtime_checkable,
)

from repro.core.jobs import Job
from repro.core.slices import FreeSlotGeometry, free_slot_geometry
from repro.fleet.devices import DeviceProfile

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.engine import SimulationEngine

__all__ = [
    "DeviceState",
    "DeviceLoadState",
    "EngineDeviceState",
    "DispatchContext",
    "Dispatcher",
    "StateAwareDispatcher",
    "FragmentationAwareDispatcher",
    "DISPATCHERS",
    "make_dispatcher",
    "as_context_dispatcher",
    "dispatch_jobs",
    "DispatchTrace",
]

# horizon over which an estimated backlog is smeared into busy slots for the
# energy-greedy marginal-power estimate (minutes)
_ENERGY_LOOKAHEAD_MIN = 30.0


def job_demand_slots(job: Job) -> int:
    """Slice width a job "wants": its elasticity cap, else 1 slot.

    Capped jobs gain nothing beyond their cap, so the cap is the natural
    slice class to place them on (serving tenants are generated this way —
    the tenant's model footprint maps to a capped elasticity).  Linear and
    sublinear jobs accept any slice, so their placement demand is the
    minimal 1 slot.
    """
    cap = getattr(job.elasticity, "cap", None)
    return int(cap) if cap else 1


@runtime_checkable
class DeviceState(Protocol):
    """What a dispatcher may observe about one fleet device.

    Both state views implement this surface.  The fluid
    :class:`DeviceLoadState` answers the real-state members with
    conservative defaults (no queue, no repartition, no geometry) — the
    honest encoding of "the fluid model cannot see this"; dispatchers that
    *require* real answers declare ``requires_online`` and are rejected in
    fluid mode before they can be misled.
    """

    index: int
    profile: DeviceProfile
    dispatched: int

    @property
    def backlog_1g_min(self) -> float: ...

    @property
    def normalized_load(self) -> float: ...

    def est_busy_slots(self) -> float: ...

    @property
    def queue_depth(self) -> int: ...

    @property
    def repartition_remaining_min(self) -> float: ...

    @property
    def stalled_fraction(self) -> float: ...

    @property
    def free_slices(self) -> int: ...

    def free_geometry(self) -> Optional[FreeSlotGeometry]: ...


@dataclasses.dataclass
class DeviceLoadState:
    """Dispatcher-visible fluid estimate of one device's outstanding work."""

    index: int
    profile: DeviceProfile
    backlog_1g_min: float = 0.0  # outstanding work, 1g-slice-minutes
    last_t: float = 0.0
    dispatched: int = 0

    def drain_to(self, t: float) -> None:
        """Advance the fluid model: backlog drains at peak slot rate."""
        dt = max(t - self.last_t, 0.0)
        self.backlog_1g_min = max(
            self.backlog_1g_min - dt * self.profile.total_slots, 0.0
        )
        self.last_t = max(self.last_t, t)

    @property
    def normalized_load(self) -> float:
        """Backlog in device-minutes (backlog over peak drain rate)."""
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self) -> float:
        """Backlog smeared over the lookahead window, capped at the device."""
        slots = self.backlog_1g_min / _ENERGY_LOOKAHEAD_MIN
        return min(slots, float(self.profile.total_slots))

    # -- real-state surface: the fluid model cannot see any of it --------
    @property
    def queue_depth(self) -> int:
        return 0

    @property
    def repartition_remaining_min(self) -> float:
        return 0.0

    @property
    def stalled_slots(self) -> int:
        return 0

    @property
    def stalled_fraction(self) -> float:
        return 0.0

    @property
    def free_slices(self) -> int:
        return self.profile.configs[self.profile.default_config].num_slices

    def free_geometry(self) -> Optional[FreeSlotGeometry]:
        return None


class EngineDeviceState:
    """Live, real-state view of one device for online dispatch.

    Exposes the same :class:`DeviceState` surface the fluid
    :class:`DeviceLoadState` offers, so every dispatcher runs unmodified in
    both modes — but here the numbers are read off the device's live engine
    snapshot: the backlog is the *actual* outstanding work of jobs in the
    system, and the online-only signals (queue depth, in-flight
    repartition, free slices and free-slot geometry on the current
    partition) exist only on this view.

    A device's simulator clock sits at its *last processed event*, which
    may lag the arrival being routed by a different amount per device.
    :meth:`observe_at` sets the observation instant: between events the
    backlog drains linearly at the snapshot's ``service_rate_1g_per_min``
    (and a repartition stall shrinks at unit rate), so the view projects
    both to exactly ``t`` — every device is compared at the same simulated
    time without touching the simulation itself.  Job membership (queue
    depth, free slices) cannot change between events, so those need no
    projection.
    """

    def __init__(self, index: int, profile: DeviceProfile, engine: "SimulationEngine") -> None:
        self.index = index
        self.profile = profile
        self.engine = engine
        self.dispatched = 0
        self._t_obs: "float | None" = None
        self._cache_stamp = -1
        self._cache_snap = None

    def observe_at(self, t: float) -> None:
        """Project subsequent reads to the instant ``t`` (>= the device clock)."""
        self._t_obs = t

    @property
    def _snap(self):
        # one snapshot per engine advance: the sim state only changes when
        # events process, so a pick() reading several properties — and the
        # trace record right after — reuse a single O(active) scan
        stamp = self.engine.events_processed
        if self._cache_snap is None or stamp != self._cache_stamp:
            self._cache_snap = self.engine.sim.snapshot()
            self._cache_stamp = stamp
        return self._cache_snap

    @property
    def _gap_min(self) -> float:
        """Minutes between the device clock and the observation instant."""
        if self._t_obs is None:
            return 0.0
        return max(self._t_obs - self._snap.t, 0.0)

    @property
    def backlog_1g_min(self) -> float:
        """Outstanding work (1g-minutes), projected to the observed instant."""
        snap = self._snap
        return max(
            snap.backlog_1g_min - snap.service_rate_1g_per_min * self._gap_min,
            0.0,
        )

    @property
    def normalized_load(self) -> float:
        """Backlog in device-minutes (backlog over peak drain rate)."""
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self) -> float:
        """Backlog smeared over the lookahead window, capped at the device."""
        return min(
            self.backlog_1g_min / _ENERGY_LOOKAHEAD_MIN,
            float(self.profile.total_slots),
        )

    # -- online-only signals (what the fluid estimate cannot see) --------
    @property
    def queue_depth(self) -> int:
        """Jobs waiting (in system, not running) at the observed instant."""
        return self._snap.queue_depth

    @property
    def repartition_remaining_min(self) -> float:
        """Minutes of repartition stall left at the observed instant (0 if none)."""
        return max(self._snap.repartition_remaining_min - self._gap_min, 0.0)

    @property
    def stalled_slots(self) -> int:
        """Slot footprint of the in-flight repartition (0 when idle).

        Under partial repartitioning only the rebuilt slice instances
        stall — a device mid-reconfiguration with most of its slots
        surviving is a far better routing target than one fully drained.
        """
        if self.repartition_remaining_min <= 0.0:
            return 0
        return self._snap.stalled_slots

    @property
    def stalled_fraction(self) -> float:
        """``stalled_slots`` over the device's total slots, in [0, 1]."""
        return min(self.stalled_slots / self.profile.total_slots, 1.0)

    @property
    def free_slices(self) -> int:
        """Unoccupied slices of the *current* partition (0 mid-repartition)."""
        snap = self._snap
        if snap.repartitioning:
            return 0
        return max(snap.num_slices - snap.running, 0)

    @property
    def partition(self):
        """The device's current :class:`~repro.core.slices.Partition`."""
        return self.profile.configs[self._snap.config_id]

    def free_geometry(self) -> Optional[FreeSlotGeometry]:
        """Free-slot geometry of the current partition (DESIGN.md §9).

        ``None`` mid-repartition: the partition is in flux and its free
        cells are not placeable until the rebuild lands.
        """
        snap = self._snap
        if snap.repartitioning:
            return None
        return free_slot_geometry(
            self.partition,
            snap.occupied_slices,
            total_slots=self.profile.total_slots,
            slice_sizes=self.profile.slice_sizes,
        )


@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """Everything a dispatcher observes when routing one arrival.

    One typed argument instead of the historical ``(job, t, states)``
    triple-with-two-meanings: ``devices`` holds one :class:`DeviceState`
    per fleet member (fluid estimates or live engine views), and
    ``online`` says which — replacing the implicit contract where a
    dispatcher had to know which execution mode it was wired into.
    """

    t: float
    job: Job
    devices: Sequence[DeviceState]
    online: bool = True

    def __len__(self) -> int:
        return len(self.devices)

    def indices(self) -> range:
        return range(len(self.devices))

    def marginal_watts(self, i: int) -> float:
        """Marginal power (W) of one more busy slot on device ``i``."""
        st = self.devices[i]
        power = st.profile.power
        busy = st.est_busy_slots()
        total = float(st.profile.total_slots)
        return power.power_watts(min(busy + 1.0, total)) - power.power_watts(busy)


class Dispatcher(Protocol):
    """Routing strategy: picks a device index per arriving job."""

    name: str

    def pick(self, ctx: DispatchContext) -> int:
        """Device index for the arrival described by ``ctx``."""
        ...


class RoundRobinDispatcher:
    """Arrival index modulo fleet size — the order-only baseline."""

    name = "round-robin"

    def __init__(self) -> None:
        self._k = 0

    def pick(self, ctx: DispatchContext) -> int:
        """Next device in rotation, ignoring load and hardware."""
        i = self._k % len(ctx.devices)
        self._k += 1
        return i


class LeastLoadedDispatcher:
    """Smallest normalized backlog (backlog over peak slot count)."""

    name = "least-loaded"

    def pick(self, ctx: DispatchContext) -> int:
        """Device with the least estimated work per unit of capacity."""
        return min(
            ctx.indices(), key=lambda i: (ctx.devices[i].normalized_load, i)
        )


class EnergyGreedyDispatcher:
    """Marginal-power packing over the concave per-device power curves.

    Pure marginal-power packing degenerates: a saturated device has marginal
    power ~0 and would absorb every job forever while the rest of the fleet
    idles and tardiness grows without bound.  The spill threshold caps the
    estimated backlog a device may hold before it stops being a packing
    candidate; a fully saturated fleet falls back to least-loaded.
    """

    name = "energy-greedy"

    #: estimated backlog (device-minutes) beyond which a device stops
    #: accepting packed work and the dispatcher spills to the next device
    SPILL_BACKLOG_MIN = 30.0

    def pick(self, ctx: DispatchContext) -> int:
        """Open device with the cheapest marginal watt for one more slot."""
        open_devices = [
            i for i in ctx.indices()
            if ctx.devices[i].normalized_load < self.SPILL_BACKLOG_MIN
        ]
        if not open_devices:  # whole fleet saturated: protect tardiness
            return min(
                ctx.indices(), key=lambda i: (ctx.devices[i].normalized_load, i)
            )
        return min(open_devices, key=lambda i: (ctx.marginal_watts(i), i))


class StateAwareDispatcher:
    """Online-only routing on real device state (queue, partition, stalls).

    Scores each device by an expected-start-delay proxy the fluid estimate
    cannot compute:

    ``delay = normalized_load + repartition_remaining · stalled_fraction
    + congestion``

    where ``normalized_load`` is the device's *actual* outstanding work over
    its peak drain rate, ``repartition_remaining`` the minutes an in-flight
    repartition keeps slots stalled — weighted by the snapshot's
    ``stalled_slots`` share of the device, because under partial
    repartitioning the surviving slices keep serving and a mostly-surviving
    transition barely delays an arrival — and ``congestion`` a
    one-device-minute step when the current partition has no free slice
    (the job must wait for a completion or preemption rather than starting
    immediately).  Ties break toward the cheaper marginal watt at the
    device's current busy slots, then the lower index — so on an idle
    fleet it packs like ``energy-greedy``, but never onto a device that is
    visibly congested or mid-way through a full rebuild.

    Requires online dispatch (``requires_online``): the fluid two-phase
    mode has no partition or repartition state to read.
    """

    name = "state-aware"
    requires_online = True

    #: added delay (device-minutes) when no slice of the current partition
    #: is free — the job cannot start before a completion frees one
    CONGESTION_STEP_MIN = 1.0

    def start_delay(self, ctx: DispatchContext, i: int) -> float:
        """The expected-start-delay proxy for device ``i`` (device-minutes)."""
        st = ctx.devices[i]
        delay = (
            st.normalized_load
            + st.repartition_remaining_min * st.stalled_fraction
        )
        if st.free_slices == 0:
            delay += self.CONGESTION_STEP_MIN
        return delay

    def pick(self, ctx: DispatchContext) -> int:
        """Device minimizing (expected start delay, marginal watts, index)."""
        return min(
            ctx.indices(),
            key=lambda i: (self.start_delay(ctx, i), ctx.marginal_watts(i), i),
        )


class FragmentationAwareDispatcher(StateAwareDispatcher):
    """Serving dispatcher: slice-class fit first, fragmentation second.

    Extends the state-aware start-delay proxy with two geometry terms read
    off the device's free-slot geometry (DESIGN.md §9):

    * **misfit** — the arriving request wants a slice of its demand class
      (:func:`job_demand_slots`; serving tenants are capped at their model's
      slice class).  If the widest placeable instance on the device is
      narrower, the request would run slowed by ``demand / placeable``; the
      excess slowdown, scaled by the request's on-class service minutes, is
      charged as extra start delay.  A device that cannot place anything
      (or is mid-repartition) is charged as if the request ran on 1 slot.
    * **fragmentation** — the post-placement fragmentation ratio: the
      geometry is recomputed with the request's would-be instance carved
      out, and its ``1 - max_placeable/free`` (in [0, 1]) is added with a
      small weight.  Between two devices that can both serve the request
      now, prefer the one whose *remaining* free region stays usable for
      the next large request — the 2512.16099 packing rule.

    Ties still break toward the cheaper marginal watt, so on an idle fleet
    it packs onto low-power devices exactly like ``state-aware``.
    """

    name = "fragmentation-aware"
    requires_online = True

    #: weight (device-minutes per unit ratio) of post-placement fragmentation
    FRAG_WEIGHT_MIN = 2.0

    def geometry_delay(self, ctx: DispatchContext, i: int) -> float:
        """Misfit + post-placement fragmentation charge for device ``i``."""
        st = ctx.devices[i]
        demand = min(job_demand_slots(ctx.job), st.profile.total_slots)
        geo = st.free_geometry()
        widest = geo.max_placeable_slots if geo is not None else 0
        fit = max(min(widest, demand), 1)
        # excess service minutes from running below the demand class
        on_class = ctx.job.work / demand
        misfit = ctx.job.work / fit - on_class
        frag_after = 0.0
        if geo is not None and widest >= demand:
            placed = _place_in(geo, demand)
            frag_after = placed.fragmentation
        return misfit + self.FRAG_WEIGHT_MIN * frag_after

    def pick(self, ctx: DispatchContext) -> int:
        """Device minimizing (start delay + geometry terms, watts, index)."""
        return min(
            ctx.indices(),
            key=lambda i: (
                self.start_delay(ctx, i) + self.geometry_delay(ctx, i),
                ctx.marginal_watts(i),
                i,
            ),
        )


def _place_in(geo: FreeSlotGeometry, slots: int) -> FreeSlotGeometry:
    """Geometry after carving a ``slots``-wide instance at its best fit.

    Best fit = the placeable start whose run has the least leftover space
    (first such start on ties) — the packing a placement-aware controller
    would choose.  Requires the instance to be placeable in ``geo``.
    """
    best: Optional[Tuple[int, int, int]] = None  # (leftover, start, run idx)
    for k, (run_start, length) in enumerate(geo.runs):
        sub = FreeSlotGeometry(
            total_slots=geo.total_slots,
            runs=((run_start, length),),
            slice_sizes=geo.slice_sizes,
        )
        for s in sub.placeable_starts(slots):
            cand = (length - slots, s, k)
            if best is None or cand < best:
                best = cand
            break  # leftmost start in a run dominates later ones
    if best is None:
        raise ValueError(f"no placeable start for a {slots}-slot instance")
    _, start, k = best
    run_start, length = geo.runs[k]
    new_runs: List[Tuple[int, int]] = list(geo.runs[:k])
    if start > run_start:
        new_runs.append((run_start, start - run_start))
    tail = run_start + length - (start + slots)
    if tail > 0:
        new_runs.append((start + slots, tail))
    new_runs.extend(geo.runs[k + 1:])
    return FreeSlotGeometry(
        total_slots=geo.total_slots,
        runs=tuple(new_runs),
        slice_sizes=geo.slice_sizes,
    )


DISPATCHERS: Dict[str, Callable[[], Dispatcher]] = {
    "round-robin": RoundRobinDispatcher,
    "least-loaded": LeastLoadedDispatcher,
    "energy-greedy": EnergyGreedyDispatcher,
    "state-aware": StateAwareDispatcher,
    "fragmentation-aware": FragmentationAwareDispatcher,
}


class _LegacyDispatcherAdapter:
    """Wraps a pre-context dispatcher (``pick(job, t, states)``) as one.

    The adapter forwards ``name`` / ``requires_online`` so registry checks
    and trace labels see the wrapped dispatcher's identity.
    """

    def __init__(self, inner) -> None:
        self.inner = inner
        self.name = getattr(inner, "name", type(inner).__name__)
        self.requires_online = getattr(inner, "requires_online", False)

    def pick(self, ctx: DispatchContext) -> int:
        return self.inner.pick(ctx.job, ctx.t, ctx.devices)


def as_context_dispatcher(dispatcher) -> Dispatcher:
    """Return a dispatcher guaranteed to accept :class:`DispatchContext`.

    Registry dispatchers pass through; an object whose ``pick`` still has
    the pre-context ``(job, t, states)`` arity is wrapped in a deprecation
    shim.  This keeps external dispatchers working while every internal
    call site speaks the context API.
    """
    try:
        params = [
            p
            for p in inspect.signature(dispatcher.pick).parameters.values()
            if p.kind
            in (inspect.Parameter.POSITIONAL_ONLY, inspect.Parameter.POSITIONAL_OR_KEYWORD)
        ]
    except (TypeError, ValueError):  # builtins/partials: assume context API
        return dispatcher
    if len(params) >= 3:
        warnings.warn(
            f"dispatcher {getattr(dispatcher, 'name', dispatcher)!r} uses the "
            "deprecated pick(job, t, states) signature; migrate to "
            "pick(ctx: DispatchContext)",
            DeprecationWarning,
            stacklevel=2,
        )
        return _LegacyDispatcherAdapter(dispatcher)
    return dispatcher


def make_dispatcher(name: str) -> Dispatcher:
    """Fresh dispatcher instance by registry name (they carry state)."""
    try:
        return DISPATCHERS[name]()
    except KeyError as e:
        raise KeyError(
            f"unknown dispatcher {name!r}; registered: {sorted(DISPATCHERS)}"
        ) from e


#: dispatch-time load records: (t, per-device backlog in 1g-minutes) after
#: each routing decision — the fleet-aware RL observation reads this.
DispatchTrace = List[Tuple[float, Tuple[float, ...]]]


def dispatch_jobs(
    jobs: Sequence[Job],
    profiles: Sequence[DeviceProfile],
    dispatcher: Dispatcher,
) -> Tuple[List[int], DispatchTrace]:
    """Route every job to a device index; returns (assignments, trace).

    Jobs must be sorted by arrival (workload generators guarantee it); the
    fluid states are drained to each arrival before the dispatcher looks.
    Dispatchers that read real engine state (``requires_online``) cannot
    run against the fluid estimate and are rejected here.
    """
    dispatcher = as_context_dispatcher(dispatcher)
    if getattr(dispatcher, "requires_online", False):
        raise ValueError(
            f"dispatcher {dispatcher.name!r} reads real device state and "
            "cannot run in fluid mode"
        )
    states = [DeviceLoadState(index=i, profile=p) for i, p in enumerate(profiles)]
    assignments: List[int] = []
    trace: DispatchTrace = []
    prev_arrival = 0.0
    for job in jobs:
        if job.arrival < prev_arrival - 1e-9:
            raise ValueError("dispatch_jobs requires arrival-sorted jobs")
        prev_arrival = job.arrival
        for st in states:
            st.drain_to(job.arrival)
        ctx = DispatchContext(t=job.arrival, job=job, devices=states, online=False)
        i = dispatcher.pick(ctx)
        if not (0 <= i < len(states)):
            raise IndexError(f"dispatcher {dispatcher.name} picked device {i}")
        states[i].backlog_1g_min += job.work
        states[i].dispatched += 1
        assignments.append(i)
        trace.append((job.arrival, tuple(st.backlog_1g_min for st in states)))
    return assignments, trace
