"""Fleet dispatchers: route arriving jobs to MIG-capable devices.

The fleet simulation is two-phase (see :mod:`repro.fleet.simulator`): the
dispatcher walks the merged arrival stream once, deciding a device for each
job from a cheap deterministic *estimate* of per-device load, then each
device simulates its subset exactly.  The estimate is a fluid backlog in
1g-slice-minutes that drains at the device's peak slot count — the same
first-order model the MIG cluster schedulers use for placement scoring
(Tan et al.; Zambianco et al.), and deliberately independent of the
per-device scheduler so dispatch order is reproducible.

Dispatchers:

* ``round-robin``   — arrival index modulo fleet size (the baseline);
* ``least-loaded``  — smallest normalized backlog (backlog / peak slots);
* ``energy-greedy`` — smallest *marginal power* for one more busy slot at
  the device's estimated utilization: exploits the concave Fig. 3 curve by
  packing onto already-hot devices and preferring low-power devices when
  everything is idle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Protocol, Sequence, Tuple

from repro.core.jobs import Job
from repro.fleet.devices import DeviceProfile

__all__ = [
    "DeviceLoadState",
    "Dispatcher",
    "DISPATCHERS",
    "make_dispatcher",
    "dispatch_jobs",
    "DispatchTrace",
]

# horizon over which an estimated backlog is smeared into busy slots for the
# energy-greedy marginal-power estimate (minutes)
_ENERGY_LOOKAHEAD_MIN = 30.0


@dataclasses.dataclass
class DeviceLoadState:
    """Dispatcher-visible fluid estimate of one device's outstanding work."""

    index: int
    profile: DeviceProfile
    backlog_1g_min: float = 0.0  # outstanding work, 1g-slice-minutes
    last_t: float = 0.0
    dispatched: int = 0

    def drain_to(self, t: float) -> None:
        """Advance the fluid model: backlog drains at peak slot rate."""
        dt = max(t - self.last_t, 0.0)
        self.backlog_1g_min = max(
            self.backlog_1g_min - dt * self.profile.total_slots, 0.0
        )
        self.last_t = max(self.last_t, t)

    @property
    def normalized_load(self) -> float:
        """Backlog in device-minutes (backlog over peak drain rate)."""
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self) -> float:
        """Backlog smeared over the lookahead window, capped at the device."""
        slots = self.backlog_1g_min / _ENERGY_LOOKAHEAD_MIN
        return min(slots, float(self.profile.total_slots))


class Dispatcher(Protocol):
    """Routing strategy: picks a device index per arriving job."""

    name: str

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Device index for ``job`` arriving at ``t`` (states already drained)."""
        ...


class RoundRobinDispatcher:
    """Arrival index modulo fleet size — the order-only baseline."""

    name = "round-robin"

    def __init__(self) -> None:
        self._k = 0

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Next device in rotation, ignoring load and hardware."""
        i = self._k % len(states)
        self._k += 1
        return i


class LeastLoadedDispatcher:
    """Smallest normalized backlog (backlog over peak slot count)."""

    name = "least-loaded"

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Device with the least estimated work per unit of capacity."""
        return min(range(len(states)), key=lambda i: (states[i].normalized_load, i))


class EnergyGreedyDispatcher:
    """Marginal-power packing over the concave per-device power curves.

    Pure marginal-power packing degenerates: a saturated device has marginal
    power ~0 and would absorb every job forever while the rest of the fleet
    idles and tardiness grows without bound.  The spill threshold caps the
    estimated backlog a device may hold before it stops being a packing
    candidate; a fully saturated fleet falls back to least-loaded.
    """

    name = "energy-greedy"

    #: estimated backlog (device-minutes) beyond which a device stops
    #: accepting packed work and the dispatcher spills to the next device
    SPILL_BACKLOG_MIN = 30.0

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Open device with the cheapest marginal watt for one more slot."""
        def marginal_watts(i: int) -> float:
            st = states[i]
            power = st.profile.power
            busy = st.est_busy_slots()
            total = float(st.profile.total_slots)
            return power.power_watts(min(busy + 1.0, total)) - power.power_watts(busy)

        open_devices = [
            i for i in range(len(states))
            if states[i].normalized_load < self.SPILL_BACKLOG_MIN
        ]
        if not open_devices:  # whole fleet saturated: protect tardiness
            return min(range(len(states)), key=lambda i: (states[i].normalized_load, i))
        return min(open_devices, key=lambda i: (marginal_watts(i), i))


DISPATCHERS: Dict[str, Callable[[], Dispatcher]] = {
    "round-robin": RoundRobinDispatcher,
    "least-loaded": LeastLoadedDispatcher,
    "energy-greedy": EnergyGreedyDispatcher,
}


def make_dispatcher(name: str) -> Dispatcher:
    """Fresh dispatcher instance by registry name (they carry state)."""
    try:
        return DISPATCHERS[name]()
    except KeyError as e:
        raise KeyError(
            f"unknown dispatcher {name!r}; registered: {sorted(DISPATCHERS)}"
        ) from e


#: dispatch-time load records: (t, per-device backlog in 1g-minutes) after
#: each routing decision — the fleet-aware RL observation reads this.
DispatchTrace = List[Tuple[float, Tuple[float, ...]]]


def dispatch_jobs(
    jobs: Sequence[Job],
    profiles: Sequence[DeviceProfile],
    dispatcher: Dispatcher,
) -> Tuple[List[int], DispatchTrace]:
    """Route every job to a device index; returns (assignments, trace).

    Jobs must be sorted by arrival (workload generators guarantee it); the
    fluid states are drained to each arrival before the dispatcher looks.
    """
    states = [DeviceLoadState(index=i, profile=p) for i, p in enumerate(profiles)]
    assignments: List[int] = []
    trace: DispatchTrace = []
    prev_arrival = 0.0
    for job in jobs:
        if job.arrival < prev_arrival - 1e-9:
            raise ValueError("dispatch_jobs requires arrival-sorted jobs")
        prev_arrival = job.arrival
        for st in states:
            st.drain_to(job.arrival)
        i = dispatcher.pick(job, job.arrival, states)
        if not (0 <= i < len(states)):
            raise IndexError(f"dispatcher {dispatcher.name} picked device {i}")
        states[i].backlog_1g_min += job.work
        states[i].dispatched += 1
        assignments.append(i)
        trace.append((job.arrival, tuple(st.backlog_1g_min for st in states)))
    return assignments, trace
