"""Fleet dispatchers: route arriving jobs to MIG-capable devices.

The default fleet execution is *online* (see :mod:`repro.fleet.simulator`):
per-device simulation engines are co-advanced to each arrival on a merged
event clock, and the dispatcher observes **real** device state — actual
outstanding work, queue depth, the current partition, and any in-flight
repartition — through :class:`EngineDeviceState` views over live engine
snapshots.  The legacy *fluid* mode (``dispatch_info="fluid"``) instead
walks the arrival stream once against a cheap backlog estimate that drains
at the device's peak slot count — the first-order model the MIG cluster
schedulers use for placement scoring (Tan et al.; Zambianco et al.).  The
``dispatchers`` sweep grid measures the online-vs-fluid gap.

Dispatchers (all deterministic; a dispatcher sees whichever state view the
execution mode provides):

* ``round-robin``   — arrival index modulo fleet size (the baseline);
* ``least-loaded``  — smallest normalized backlog (backlog / peak slots);
* ``energy-greedy`` — smallest *marginal power* for one more busy slot at
  the device's estimated utilization: exploits the concave Fig. 3 curve by
  packing onto already-hot devices and preferring low-power devices when
  everything is idle;
* ``state-aware``   — online-only: minimizes an expected-start-delay proxy
  built from real state (normalized backlog + remaining repartition stall
  + a congestion step when no slice is free), breaking ties toward the
  cheaper marginal watt.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List, Protocol, Sequence, Tuple

from repro.core.jobs import Job
from repro.fleet.devices import DeviceProfile

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.engine import SimulationEngine

__all__ = [
    "DeviceLoadState",
    "EngineDeviceState",
    "Dispatcher",
    "StateAwareDispatcher",
    "DISPATCHERS",
    "make_dispatcher",
    "dispatch_jobs",
    "DispatchTrace",
]

# horizon over which an estimated backlog is smeared into busy slots for the
# energy-greedy marginal-power estimate (minutes)
_ENERGY_LOOKAHEAD_MIN = 30.0


@dataclasses.dataclass
class DeviceLoadState:
    """Dispatcher-visible fluid estimate of one device's outstanding work."""

    index: int
    profile: DeviceProfile
    backlog_1g_min: float = 0.0  # outstanding work, 1g-slice-minutes
    last_t: float = 0.0
    dispatched: int = 0

    def drain_to(self, t: float) -> None:
        """Advance the fluid model: backlog drains at peak slot rate."""
        dt = max(t - self.last_t, 0.0)
        self.backlog_1g_min = max(
            self.backlog_1g_min - dt * self.profile.total_slots, 0.0
        )
        self.last_t = max(self.last_t, t)

    @property
    def normalized_load(self) -> float:
        """Backlog in device-minutes (backlog over peak drain rate)."""
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self) -> float:
        """Backlog smeared over the lookahead window, capped at the device."""
        slots = self.backlog_1g_min / _ENERGY_LOOKAHEAD_MIN
        return min(slots, float(self.profile.total_slots))


class EngineDeviceState:
    """Live, real-state view of one device for online dispatch.

    Exposes the same surface the fluid :class:`DeviceLoadState` offers
    (``backlog_1g_min`` / ``normalized_load`` / ``est_busy_slots``) so every
    dispatcher runs unmodified in both modes — but here the numbers are read
    off the device's live engine snapshot: the backlog is the *actual*
    outstanding work of jobs in the system, and the online-only signals
    (queue depth, in-flight repartition, free slices on the current
    partition) exist only on this view.

    A device's simulator clock sits at its *last processed event*, which
    may lag the arrival being routed by a different amount per device.
    :meth:`observe_at` sets the observation instant: between events the
    backlog drains linearly at the snapshot's ``service_rate_1g_per_min``
    (and a repartition stall shrinks at unit rate), so the view projects
    both to exactly ``t`` — every device is compared at the same simulated
    time without touching the simulation itself.  Job membership (queue
    depth, free slices) cannot change between events, so those need no
    projection.
    """

    def __init__(self, index: int, profile: DeviceProfile, engine: "SimulationEngine") -> None:
        self.index = index
        self.profile = profile
        self.engine = engine
        self.dispatched = 0
        self._t_obs: "float | None" = None
        self._cache_stamp = -1
        self._cache_snap = None

    def observe_at(self, t: float) -> None:
        """Project subsequent reads to the instant ``t`` (>= the device clock)."""
        self._t_obs = t

    @property
    def _snap(self):
        # one snapshot per engine advance: the sim state only changes when
        # events process, so a pick() reading several properties — and the
        # trace record right after — reuse a single O(active) scan
        stamp = self.engine.events_processed
        if self._cache_snap is None or stamp != self._cache_stamp:
            self._cache_snap = self.engine.sim.snapshot()
            self._cache_stamp = stamp
        return self._cache_snap

    @property
    def _gap_min(self) -> float:
        """Minutes between the device clock and the observation instant."""
        if self._t_obs is None:
            return 0.0
        return max(self._t_obs - self._snap.t, 0.0)

    @property
    def backlog_1g_min(self) -> float:
        """Outstanding work (1g-minutes), projected to the observed instant."""
        snap = self._snap
        return max(
            snap.backlog_1g_min - snap.service_rate_1g_per_min * self._gap_min,
            0.0,
        )

    @property
    def normalized_load(self) -> float:
        """Backlog in device-minutes (backlog over peak drain rate)."""
        return self.backlog_1g_min / self.profile.total_slots

    def est_busy_slots(self) -> float:
        """Backlog smeared over the lookahead window, capped at the device."""
        return min(
            self.backlog_1g_min / _ENERGY_LOOKAHEAD_MIN,
            float(self.profile.total_slots),
        )

    # -- online-only signals (what the fluid estimate cannot see) --------
    @property
    def queue_depth(self) -> int:
        """Jobs waiting (in system, not running) at the observed instant."""
        return self._snap.queue_depth

    @property
    def repartition_remaining_min(self) -> float:
        """Minutes of repartition stall left at the observed instant (0 if none)."""
        return max(self._snap.repartition_remaining_min - self._gap_min, 0.0)

    @property
    def stalled_slots(self) -> int:
        """Slot footprint of the in-flight repartition (0 when idle).

        Under partial repartitioning only the rebuilt slice instances
        stall — a device mid-reconfiguration with most of its slots
        surviving is a far better routing target than one fully drained.
        """
        if self.repartition_remaining_min <= 0.0:
            return 0
        return self._snap.stalled_slots

    @property
    def stalled_fraction(self) -> float:
        """``stalled_slots`` over the device's total slots, in [0, 1]."""
        return min(self.stalled_slots / self.profile.total_slots, 1.0)

    @property
    def free_slices(self) -> int:
        """Unoccupied slices of the *current* partition (0 mid-repartition)."""
        snap = self._snap
        if snap.repartitioning:
            return 0
        return max(snap.num_slices - snap.running, 0)


class Dispatcher(Protocol):
    """Routing strategy: picks a device index per arriving job."""

    name: str

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Device index for ``job`` arriving at ``t`` (states already drained)."""
        ...


class RoundRobinDispatcher:
    """Arrival index modulo fleet size — the order-only baseline."""

    name = "round-robin"

    def __init__(self) -> None:
        self._k = 0

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Next device in rotation, ignoring load and hardware."""
        i = self._k % len(states)
        self._k += 1
        return i


class LeastLoadedDispatcher:
    """Smallest normalized backlog (backlog over peak slot count)."""

    name = "least-loaded"

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Device with the least estimated work per unit of capacity."""
        return min(range(len(states)), key=lambda i: (states[i].normalized_load, i))


class EnergyGreedyDispatcher:
    """Marginal-power packing over the concave per-device power curves.

    Pure marginal-power packing degenerates: a saturated device has marginal
    power ~0 and would absorb every job forever while the rest of the fleet
    idles and tardiness grows without bound.  The spill threshold caps the
    estimated backlog a device may hold before it stops being a packing
    candidate; a fully saturated fleet falls back to least-loaded.
    """

    name = "energy-greedy"

    #: estimated backlog (device-minutes) beyond which a device stops
    #: accepting packed work and the dispatcher spills to the next device
    SPILL_BACKLOG_MIN = 30.0

    def pick(self, job: Job, t: float, states: Sequence[DeviceLoadState]) -> int:
        """Open device with the cheapest marginal watt for one more slot."""
        def marginal_watts(i: int) -> float:
            st = states[i]
            power = st.profile.power
            busy = st.est_busy_slots()
            total = float(st.profile.total_slots)
            return power.power_watts(min(busy + 1.0, total)) - power.power_watts(busy)

        open_devices = [
            i for i in range(len(states))
            if states[i].normalized_load < self.SPILL_BACKLOG_MIN
        ]
        if not open_devices:  # whole fleet saturated: protect tardiness
            return min(range(len(states)), key=lambda i: (states[i].normalized_load, i))
        return min(open_devices, key=lambda i: (marginal_watts(i), i))


class StateAwareDispatcher:
    """Online-only routing on real device state (queue, partition, stalls).

    Scores each device by an expected-start-delay proxy the fluid estimate
    cannot compute:

    ``delay = normalized_load + repartition_remaining · stalled_fraction
    + congestion``

    where ``normalized_load`` is the device's *actual* outstanding work over
    its peak drain rate, ``repartition_remaining`` the minutes an in-flight
    repartition keeps slots stalled — weighted by the snapshot's
    ``stalled_slots`` share of the device, because under partial
    repartitioning the surviving slices keep serving and a mostly-surviving
    transition barely delays an arrival — and ``congestion`` a
    one-device-minute step when the current partition has no free slice
    (the job must wait for a completion or preemption rather than starting
    immediately).  Ties break toward the cheaper marginal watt at the
    device's current busy slots, then the lower index — so on an idle
    fleet it packs like ``energy-greedy``, but never onto a device that is
    visibly congested or mid-way through a full rebuild.

    Requires online dispatch (``requires_online``): the fluid two-phase
    mode has no partition or repartition state to read.
    """

    name = "state-aware"
    requires_online = True

    #: added delay (device-minutes) when no slice of the current partition
    #: is free — the job cannot start before a completion frees one
    CONGESTION_STEP_MIN = 1.0

    def pick(self, job: Job, t: float, states: Sequence["EngineDeviceState"]) -> int:
        """Device minimizing (expected start delay, marginal watts, index)."""
        def key(i: int):
            st = states[i]
            delay = (
                st.normalized_load
                + st.repartition_remaining_min * st.stalled_fraction
            )
            if st.free_slices == 0:
                delay += self.CONGESTION_STEP_MIN
            power = st.profile.power
            busy = st.est_busy_slots()
            total = float(st.profile.total_slots)
            marginal = power.power_watts(min(busy + 1.0, total)) - power.power_watts(busy)
            return (delay, marginal, i)

        return min(range(len(states)), key=key)


DISPATCHERS: Dict[str, Callable[[], Dispatcher]] = {
    "round-robin": RoundRobinDispatcher,
    "least-loaded": LeastLoadedDispatcher,
    "energy-greedy": EnergyGreedyDispatcher,
    "state-aware": StateAwareDispatcher,
}


def make_dispatcher(name: str) -> Dispatcher:
    """Fresh dispatcher instance by registry name (they carry state)."""
    try:
        return DISPATCHERS[name]()
    except KeyError as e:
        raise KeyError(
            f"unknown dispatcher {name!r}; registered: {sorted(DISPATCHERS)}"
        ) from e


#: dispatch-time load records: (t, per-device backlog in 1g-minutes) after
#: each routing decision — the fleet-aware RL observation reads this.
DispatchTrace = List[Tuple[float, Tuple[float, ...]]]


def dispatch_jobs(
    jobs: Sequence[Job],
    profiles: Sequence[DeviceProfile],
    dispatcher: Dispatcher,
) -> Tuple[List[int], DispatchTrace]:
    """Route every job to a device index; returns (assignments, trace).

    Jobs must be sorted by arrival (workload generators guarantee it); the
    fluid states are drained to each arrival before the dispatcher looks.
    Dispatchers that read real engine state (``requires_online``) cannot
    run against the fluid estimate and are rejected here.
    """
    if getattr(dispatcher, "requires_online", False):
        raise ValueError(
            f"dispatcher {dispatcher.name!r} reads real device state and "
            "cannot run in fluid mode"
        )
    states = [DeviceLoadState(index=i, profile=p) for i, p in enumerate(profiles)]
    assignments: List[int] = []
    trace: DispatchTrace = []
    prev_arrival = 0.0
    for job in jobs:
        if job.arrival < prev_arrival - 1e-9:
            raise ValueError("dispatch_jobs requires arrival-sorted jobs")
        prev_arrival = job.arrival
        for st in states:
            st.drain_to(job.arrival)
        i = dispatcher.pick(job, job.arrival, states)
        if not (0 <= i < len(states)):
            raise IndexError(f"dispatcher {dispatcher.name} picked device {i}")
        states[i].backlog_1g_min += job.work
        states[i].dispatched += 1
        assignments.append(i)
        trace.append((job.arrival, tuple(st.backlog_1g_min for st in states)))
    return assignments, trace
