"""Fleet-scale MIG simulation: N heterogeneous GPUs behind one dispatcher.

The paper (§IV-§V) schedules a single MIG-capable GPU; a production fleet
routes traffic across many of them.  This package adds that layer without
touching the per-GPU physics: a pluggable dispatcher splits the arrival
stream (:mod:`repro.fleet.dispatch`), each device runs the unchanged
event-driven :class:`~repro.core.simulator.MIGSimulator` with its own power
curve and partition table (:mod:`repro.fleet.devices`), and the per-device
results are aggregated into fleet-level ET/energy/tardiness metrics
(:mod:`repro.fleet.simulator`).

A 1-device fleet is bit-identical to the single-MIG paper path — pinned by
``tests/test_fleet.py`` and the ``fleet_scaling`` sweep baseline.
"""

from repro.fleet.devices import DEVICE_PROFILES, DeviceProfile, device_profile
from repro.fleet.dispatch import (
    DISPATCHERS,
    DeviceLoadState,
    DeviceState,
    DispatchContext,
    Dispatcher,
    EngineDeviceState,
    FragmentationAwareDispatcher,
    StateAwareDispatcher,
    as_context_dispatcher,
    dispatch_jobs,
    make_dispatcher,
)
from repro.fleet.simulator import (
    DeviceAdaptedPolicy,
    FleetDeviceSpec,
    FleetResult,
    FleetSimulator,
    FleetSpec,
    FleetView,
    aggregate_sim_results,
)

__all__ = [
    "DEVICE_PROFILES",
    "DeviceAdaptedPolicy",
    "DeviceProfile",
    "device_profile",
    "DISPATCHERS",
    "DeviceLoadState",
    "DeviceState",
    "DispatchContext",
    "Dispatcher",
    "EngineDeviceState",
    "FragmentationAwareDispatcher",
    "StateAwareDispatcher",
    "as_context_dispatcher",
    "dispatch_jobs",
    "make_dispatcher",
    "FleetDeviceSpec",
    "FleetResult",
    "FleetSimulator",
    "FleetSpec",
    "FleetView",
    "aggregate_sim_results",
]
