"""Device profiles: the per-GPU hardware identity of a fleet member.

A :class:`DeviceProfile` bundles what the single-GPU layers keep implicit —
the Fig. 3 power curve and the Fig. 1 partition table — so a fleet can mix
A100-class and A30-class devices (or the TPU-pod analogue) while each
per-device :class:`~repro.core.simulator.MIGSimulator` stays unchanged.

Profiles are referenced by name in sweep cells (a profile object is not
JSON); the registry is the single source of truth for that mapping.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple

from repro.core.power import A100_250W, A30_165W, TPU_V5E_POD, PowerModel
from repro.core.slices import (
    A30_CONFIGS,
    MIG_CONFIGS,
    Partition,
    table_slice_sizes,
    validate_config_table,
)

__all__ = ["DeviceProfile", "DEVICE_PROFILES", "device_profile"]


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A MIG-capable device type: power curve + partition table."""

    name: str
    power: PowerModel
    configs: Mapping[int, Partition]
    default_config: int  # a sensible mixed layout valid for this table

    def __post_init__(self) -> None:
        # re-validates the table under this profile's name so a bad fleet
        # config fails with "<profile> table, config N ..." (not the bare
        # config id the table's import-time check reports)
        validate_config_table(
            dict(self.configs),
            max_slots=self.total_slots,
            max_memory_gb=max(p.total_memory_gb for p in self.configs.values()),
            name=self.name,
        )
        if self.default_config not in self.configs:
            raise AssertionError(
                f"{self.name} table, default config {self.default_config} "
                f"not in table ids {sorted(self.configs)}"
            )

    @property
    def total_slots(self) -> int:
        """Peak parallel compute slots (the full-GPU partition size)."""
        return max(p.total_slots for p in self.configs.values())

    @property
    def slice_sizes(self) -> Tuple[int, ...]:
        """Distinct slice widths this device can place (ascending)."""
        return table_slice_sizes(dict(self.configs))

    def config_ids(self) -> Tuple[int, ...]:
        """Valid partition ids of this device's table, ascending."""
        return tuple(sorted(self.configs))


DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    p.name: p
    for p in [
        DeviceProfile("a100-250w", A100_250W, MIG_CONFIGS, default_config=3),
        DeviceProfile("a30-165w", A30_165W, A30_CONFIGS, default_config=2),
        DeviceProfile("tpu-v5e-pod", TPU_V5E_POD, MIG_CONFIGS, default_config=3),
    ]
}


def device_profile(name: str) -> DeviceProfile:
    """Look up a registered :class:`DeviceProfile` by name."""
    try:
        return DEVICE_PROFILES[name]
    except KeyError as e:
        raise KeyError(
            f"unknown device profile {name!r}; registered: {sorted(DEVICE_PROFILES)}"
        ) from e
