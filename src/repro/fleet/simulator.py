"""Fleet-level MIG simulation: N heterogeneous GPUs behind one dispatcher.

Execution model — **online** (the default, ``dispatch_info="online"``):
every device gets its own steppable :class:`~repro.core.engine.SimulationEngine`
and the fleet co-advances them on a merged event clock.  At each arrival
every engine is run up to (but not through) the arrival instant, the
pluggable dispatcher (:mod:`repro.fleet.dispatch`) observes **real** device
state — actual outstanding work, queue depth, the current partition, any
in-flight repartition — through live engine snapshots, and the job is
injected into the chosen device's engine.  When the stream ends the engines
drain independently.

The legacy **fluid** mode (``dispatch_info="fluid"``) is the two-phase
pre-split this replaced: the arrival stream is walked once against a fluid
per-device backlog estimate, then each device simulates its subset from
scratch.  It is kept as an explicit mode so the online-vs-fluid gap stays a
measurable number (the ``dispatchers`` sweep grid / EXPERIMENTS.md).

Per-device :class:`~repro.core.metrics.SimResult`\\ s are then aggregated
into fleet totals.  The load-bearing invariant — pinned by tests and the
``fleet_scaling`` CI baseline — is that a **1-device fleet is bit-identical
to the single-MIG path** in *both* modes: one device receives the job list
unchanged (event-for-event, whichever mode delivers it), and
``aggregate_sim_results`` of one result *is* that result.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.engine import SimulationEngine
from repro.core.jobs import Job
from repro.core.metrics import SimResult, merge_tenant_stats
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator, RepartitionPolicy
from repro.core.slices import MIG_CONFIGS, Partition
from repro.fleet.devices import DeviceProfile, device_profile
from repro.fleet.dispatch import (
    DispatchTrace,
    DispatchContext,
    EngineDeviceState,
    as_context_dispatcher,
    dispatch_jobs,
    make_dispatcher,
)

__all__ = [
    "DeviceAdaptedPolicy",
    "FleetDeviceSpec",
    "FleetSpec",
    "FleetResult",
    "FleetStream",
    "FleetView",
    "FleetSimulator",
    "aggregate_sim_results",
]

#: valid ``FleetSpec.dispatch_info`` values
DISPATCH_INFO_MODES = ("online", "fluid")


@dataclasses.dataclass(frozen=True)
class FleetDeviceSpec:
    """One fleet member: a profile name plus optional per-device overrides."""

    profile: str
    scheduler: Optional[str] = None  # None -> the fleet default
    initial_config: Optional[int] = None  # None -> the policy's choice


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A fleet: device list, dispatcher, in-device scheduler, dispatch mode.

    ``dispatch_info`` selects what the dispatcher observes: ``"online"``
    (default) co-advances per-device engines and exposes real state;
    ``"fluid"`` is the legacy backlog-estimate pre-split.  The toggle is
    *deprecated as an API surface*: dispatchers no longer see it — both
    modes hand them the same :class:`~repro.fleet.dispatch.DispatchContext`
    (with ``ctx.online`` set accordingly) — and it survives only so that
    existing sweep cells, which encode it under the ``fleet.info`` key,
    keep hashing byte-identically.
    ``repartition_mode`` is applied to every device simulator — ``"partial"``
    (slot-placed transitions, the default) or ``"drain"`` (legacy full
    drain); see :class:`repro.core.simulator.MIGSimulator`.
    """

    devices: Tuple[FleetDeviceSpec, ...]
    dispatcher: str = "round-robin"
    scheduler: str = "EDF-SS"
    dispatch_info: str = "online"
    repartition_mode: str = "partial"

    @staticmethod
    def of(profiles: Sequence[str], dispatcher: str = "round-robin",
           scheduler: str = "EDF-SS", dispatch_info: str = "online",
           repartition_mode: str = "partial") -> "FleetSpec":
        """Shorthand: a fleet from profile names with no per-device overrides."""
        return FleetSpec(
            devices=tuple(FleetDeviceSpec(profile=p) for p in profiles),
            dispatcher=dispatcher,
            scheduler=scheduler,
            dispatch_info=dispatch_info,
            repartition_mode=repartition_mode,
        )


@dataclasses.dataclass
class FleetResult:
    """Aggregate + per-device outcome of one fleet run."""

    aggregate: SimResult
    per_device: List[SimResult]
    dispatch_counts: List[int]
    trace: DispatchTrace

    @property
    def num_devices(self) -> int:
        """Fleet size of the run that produced this result."""
        return len(self.per_device)


class FleetView:
    """Read-only fleet-load lookup for fleet-aware observations.

    Wraps the dispatch-time trace (one per-device backlog record per routed
    job — *real* backlogs in online mode, fluid estimates in fluid mode):
    ``load_share(i, t)`` is device ``i``'s share of the fleet backlog at the
    last routing decision before ``t``, ``total_load_norm(t)`` the fleet
    backlog normalized to ``norm_min`` device-minutes and clipped to [0, 1].

    In online mode the view also holds the live engines: *while the
    arrival stream is open* (the engines are being co-advanced together), a
    lookup at or past the newest trace record reads the engines' current
    snapshots instead of the last record — mid-run observers (per-device RL
    features, streaming telemetry) see the device state as it is now, not
    as it was at the previous arrival.  Once the stream closes the engines
    drain independently (their clocks diverge), so lookups fall back to the
    recorded trace — the same post-run behavior as fluid mode.
    """

    def __init__(self, trace: DispatchTrace, profiles: Sequence[DeviceProfile],
                 norm_min: float = 120.0,
                 engines: Optional[Sequence[SimulationEngine]] = None) -> None:
        # the trace list is shared with the running FleetSimulator in online
        # mode (append-only); index lazily so mid-run reads see fresh records
        self._trace = trace
        self._profiles = list(profiles)
        self._norm_min = norm_min
        self._engines = list(engines) if engines is not None else None

    def _at(self, t: float) -> Optional[Tuple[float, ...]]:
        if (
            self._engines is not None
            and all(e.stream_open for e in self._engines)
            and (not self._trace or t >= self._trace[-1][0])
        ):
            return tuple(
                e.sim.snapshot().backlog_1g_min for e in self._engines
            )
        i = bisect.bisect_right(self._trace, t, key=lambda rec: rec[0]) - 1
        return self._trace[i][1] if i >= 0 else None

    def load_share(self, device_index: int, t: float) -> float:
        """Device's fraction of the fleet backlog just before ``t``."""
        rec = self._at(t)
        if rec is None:
            return 0.0
        total = sum(rec)
        return rec[device_index] / total if total > 0.0 else 0.0

    def total_load_norm(self, t: float) -> float:
        """Fleet backlog in device-minutes, normalized+clipped to [0, 1]."""
        rec = self._at(t)
        if rec is None:
            return 0.0
        device_minutes = sum(
            b / p.total_slots for b, p in zip(rec, self._profiles, strict=True)
        )
        return min(device_minutes / self._norm_min, 1.0)


def aggregate_sim_results(per_device: Sequence[SimResult]) -> SimResult:
    """Fleet totals from per-device results.

    For one device the input is returned unchanged — this is what makes the
    1-GPU fleet bit-identical to the single-MIG path by construction rather
    than by floating-point luck.
    """
    if not per_device:
        raise ValueError("no device results")
    if len(per_device) == 1:
        return per_device[0]
    num_jobs = sum(r.num_jobs for r in per_device)
    total_tard = sum(r.total_tardiness for r in per_device)
    return SimResult(
        energy_wh=sum(r.energy_wh for r in per_device),
        avg_tardiness=total_tard / max(num_jobs, 1),
        num_jobs=num_jobs,
        total_tardiness=total_tard,
        preemptions=sum(r.preemptions for r in per_device),
        repartitions=sum(r.repartitions for r in per_device),
        max_tardiness=max(r.max_tardiness for r in per_device),
        deadline_misses=sum(r.deadline_misses for r in per_device),
        busy_slot_minutes=sum(r.busy_slot_minutes for r in per_device),
        extra={
            "makespan_min": max(r.extra.get("makespan_min", 0.0) for r in per_device),
            "tardiness_integral": sum(
                r.extra.get("tardiness_integral", 0.0) for r in per_device
            ),
        },
        tenants=merge_tenant_stats(r.tenants for r in per_device),
    )


class DeviceAdaptedPolicy:
    """Maps a policy's config choices onto a non-A100 device's table.

    Every registered dynamic policy (daynight, heuristic, DQN) emits ids in
    the paper's A100 Fig. 1 space; on a device with a different table those
    ids would KeyError mid-run.  An out-of-table choice is mapped to the
    device config whose *slice count* is closest to the requested A100
    layout's — the policy decides how finely partitioned the GPU should be,
    and that intent survives the translation.  In-table choices pass through
    untouched, so the wrapper is the identity on A100 devices.
    """

    def __init__(self, inner: RepartitionPolicy, configs: "dict[int, Partition]") -> None:
        self.inner = inner
        self.configs = dict(configs)
        self.initial_config = self._map(inner.initial_config)

    def _map(self, choice: Optional[int]) -> Optional[int]:
        if choice is None or choice in self.configs:
            return choice
        ref = MIG_CONFIGS.get(choice)
        if ref is None:
            return choice  # unknown everywhere: let the simulator raise
        want = ref.num_slices
        return min(
            self.configs,
            key=lambda cid: (abs(self.configs[cid].num_slices - want), cid),
        )

    def decide(self, t: float, sim: MIGSimulator) -> Optional[int]:
        """Inner policy's choice, translated onto this device's table."""
        return self._map(self.inner.decide(t, sim))

    def next_timer(self, t: float) -> Optional[float]:
        """Pass through the inner policy's timer chain unchanged."""
        return self.inner.next_timer(t)


#: per-device policy source: ``factory(device_index, profile) -> policy``
PolicyFactory = Callable[[int, DeviceProfile], RepartitionPolicy]


class FleetSimulator:
    """Run a :class:`FleetSpec` over a job stream.

    Policies are built per device via ``policy_factory`` (policy instances
    carry per-run state and must never be shared across devices).  The last
    run's per-device simulators stay on ``self.sims`` (and, in online mode,
    their engines on ``self.engines``) for inspection — the RL layer reads
    their state through :func:`repro.core.rl.env.fleet_state_features`.
    """

    def __init__(self, spec: FleetSpec, mig_enabled: bool = True) -> None:
        if not spec.devices:
            raise ValueError("fleet needs at least one device")
        if spec.dispatch_info not in DISPATCH_INFO_MODES:
            raise ValueError(
                f"unknown dispatch_info {spec.dispatch_info!r}; "
                f"valid: {DISPATCH_INFO_MODES}"
            )
        self.spec = spec
        self.mig_enabled = mig_enabled
        self.profiles = [device_profile(d.profile) for d in spec.devices]
        self.sims: List[MIGSimulator] = []
        self.engines: List[SimulationEngine] = []
        self.view: Optional[FleetView] = None

    def _device_policy(self, i: int, prof: DeviceProfile,
                       policy_factory: PolicyFactory) -> RepartitionPolicy:
        policy = policy_factory(i, prof)
        if set(prof.configs) != set(MIG_CONFIGS):
            # non-A100 table: translate the policy's A100-space choices
            policy = DeviceAdaptedPolicy(policy, prof.configs)
        return policy

    def run(
        self,
        jobs: Sequence[Job],
        policy_factory: PolicyFactory,
    ) -> FleetResult:
        """Dispatch ``jobs`` across the fleet and simulate every device.

        Returns the aggregated :class:`FleetResult`; per-device simulators
        stay on ``self.sims`` for inspection.
        """
        if self.spec.dispatch_info == "fluid":
            return self._run_fluid(jobs, policy_factory)
        return self._run_online(jobs, policy_factory)

    # ------------------------------------------------------------------
    def open_stream(self, policy_factory: PolicyFactory) -> "FleetStream":
        """Open an incremental submission stream over this fleet.

        The streaming core of online mode, exposed: the scheduler *service*
        (``repro.service``) submits, cancels, and co-advances through the
        returned :class:`FleetStream` one operation at a time, while
        :meth:`run` remains the batch wrapper that feeds a whole job list
        through the same code path (bit-identical by construction).
        """
        stream = FleetStream(self, policy_factory)
        self.engines = stream.engines
        self.sims = [e.sim for e in stream.engines]
        self.view = stream.view
        return stream

    def _run_online(self, jobs: Sequence[Job], policy_factory: PolicyFactory) -> FleetResult:
        """Co-advance one engine per device on the merged arrival clock."""
        stream = self.open_stream(policy_factory)
        for job in jobs:
            stream.submit(job)
        stream.close()
        return stream.result()

    # ------------------------------------------------------------------
    def _run_fluid(self, jobs: Sequence[Job], policy_factory: PolicyFactory) -> FleetResult:
        """Legacy two-phase pre-split over the fluid backlog estimate.

        ``dispatch_jobs`` rejects dispatchers that require real engine
        state (``state-aware``) with a clear error.
        """
        dispatcher = make_dispatcher(self.spec.dispatcher)
        assignments, trace = dispatch_jobs(jobs, self.profiles, dispatcher)
        self.view = FleetView(trace, self.profiles)

        self.sims = []
        self.engines = []
        per_device: List[SimResult] = []
        counts = [0] * len(self.profiles)
        for a in assignments:
            counts[a] += 1
        for i, (dev, prof) in enumerate(zip(self.spec.devices, self.profiles, strict=True)):
            subset = [job for job, a in zip(jobs, assignments, strict=True) if a == i]
            sim = MIGSimulator(
                make_scheduler(dev.scheduler or self.spec.scheduler),
                power_model=prof.power,
                mig_enabled=self.mig_enabled,
                config_table=prof.configs,
                repartition_mode=self.spec.repartition_mode,
            )
            res = sim.run(
                subset,
                policy=self._device_policy(i, prof, policy_factory),
                initial_config=dev.initial_config,
            )
            self.sims.append(sim)
            per_device.append(res)
        return self._finish(per_device, counts, trace)

    # ------------------------------------------------------------------
    def _finish(
        self, per_device: List[SimResult], counts: List[int], trace: DispatchTrace
    ) -> FleetResult:
        return _finish_result(self.profiles, per_device, counts, trace)


def _finish_result(
    profiles: Sequence[DeviceProfile],
    per_device: List[SimResult],
    counts: List[int],
    trace: DispatchTrace,
) -> FleetResult:
    aggregate = aggregate_sim_results(per_device)
    if len(per_device) > 1:
        # Per-device energy only covers [0, device makespan] (the single-GPU
        # convention).  Devices the dispatcher starved still draw idle power
        # until the fleet drains; report that separately so packing
        # dispatchers aren't credited with turning idle silicon off.
        fleet_makespan = aggregate.extra["makespan_min"]
        idle_gap_wh = sum(
            prof.power.idle_watts
            * max(fleet_makespan - res.extra.get("makespan_min", 0.0), 0.0)
            / 60.0
            for prof, res in zip(profiles, per_device, strict=True)
        )
        aggregate = dataclasses.replace(
            aggregate,
            extra={**aggregate.extra, "fleet_idle_gap_wh": idle_gap_wh},
        )
    return FleetResult(
        aggregate=aggregate,
        per_device=per_device,
        dispatch_counts=counts,
        trace=trace,
    )


class FleetStream:
    """Incremental online-dispatch session over a fleet (one op at a time).

    Built by :meth:`FleetSimulator.open_stream`.  Owns one stream-open
    :class:`~repro.core.engine.SimulationEngine` per device plus the
    dispatcher and the dispatch trace; :meth:`submit` performs exactly one
    iteration of the batch loop (co-advance to the arrival, observe, pick,
    inject), so a stream fed a whole sorted job list then closed is
    bit-identical to :meth:`FleetSimulator.run` — pinned by
    ``tests/test_service.py``.  The additions over the batch path:

    * :meth:`cancel` routes a cancellation to the engine that owns the job
      (the stream remembers every routing decision);
    * :meth:`run_until` co-advances all engines to a bound with no arrival
      (the service's idle tick);
    * the whole object pickles (engines, dispatcher state, owner map, trace)
      for service checkpoints, exactly like a single engine does.
    """

    def __init__(self, fleet: FleetSimulator, policy_factory: PolicyFactory) -> None:
        spec = fleet.spec
        self.dispatcher = as_context_dispatcher(make_dispatcher(spec.dispatcher))
        self.profiles = fleet.profiles
        engines: List[SimulationEngine] = []
        for i, (dev, prof) in enumerate(zip(spec.devices, fleet.profiles, strict=True)):
            sim = MIGSimulator(
                make_scheduler(dev.scheduler or spec.scheduler),
                power_model=prof.power,
                mig_enabled=fleet.mig_enabled,
                config_table=prof.configs,
                repartition_mode=spec.repartition_mode,
            )
            engines.append(
                SimulationEngine(
                    sim,
                    policy=fleet._device_policy(i, prof, policy_factory),
                    initial_config=dev.initial_config,
                    stream_open=True,
                )
            )
        self.engines = engines
        self.states = [
            EngineDeviceState(i, prof, engine)
            for i, (prof, engine) in enumerate(zip(fleet.profiles, engines, strict=True))
        ]
        self.trace: DispatchTrace = []
        self.view = FleetView(self.trace, fleet.profiles, engines=engines)
        self.counts = [0] * len(engines)
        self.owner: "dict[int, int]" = {}  # job_id -> device index
        self.closed = False
        self._prev_arrival = 0.0

    def submit(self, job: Job) -> int:
        """Dispatch one arrival; returns the chosen device index."""
        if self.closed:
            raise RuntimeError(
                f"cannot submit job {job.job_id}: the fleet stream is closed"
            )
        if job.arrival < self._prev_arrival - 1e-9:
            raise ValueError("fleet dispatch requires arrival-sorted jobs")
        self._prev_arrival = job.arrival
        # advance every device past all events before the arrival, then
        # project each view to the arrival instant itself (a device's
        # clock rests at its last event; between events state evolves
        # linearly, so the projection is exact) — the dispatcher
        # compares every device at the same simulated time t⁻
        for engine, st in zip(self.engines, self.states, strict=True):
            engine.run_until(job.arrival, inclusive=False)
            st.observe_at(job.arrival)
        ctx = DispatchContext(
            t=job.arrival, job=job, devices=self.states, online=True
        )
        i = self.dispatcher.pick(ctx)
        if not (0 <= i < len(self.states)):
            raise IndexError(f"dispatcher {self.dispatcher.name} picked device {i}")
        self.engines[i].inject(job)
        self.counts[i] += 1
        self.states[i].dispatched += 1
        self.owner[job.job_id] = i
        # record the post-decision backlog: the injected arrival is not
        # processed yet, so the routed job's work is added explicitly —
        # same "backlog after each routing decision" contract as the
        # fluid trace
        self.trace.append(
            (
                job.arrival,
                tuple(
                    st.backlog_1g_min + (job.work if k == i else 0.0)
                    for k, st in enumerate(self.states)
                ),
            )
        )
        return i

    def cancel(self, job_id: int) -> str:
        """Cancel a previously submitted job on whichever device owns it."""
        i = self.owner.get(job_id)
        if i is None:
            raise ValueError(
                f"cannot cancel job {job_id}: it was never dispatched on "
                f"this fleet stream; check `status` for its disposition"
            )
        return self.engines[i].cancel(job_id)

    def run_until(self, t: float) -> int:
        """Co-advance every engine up to (not through) ``t``; total events.

        The same exclusive bound as the pre-arrival co-advance, so a tick at
        ``t`` followed by a submit at ``t`` is indistinguishable from the
        submit alone — ticks never perturb replay determinism.
        """
        self._prev_arrival = max(self._prev_arrival, t)
        return sum(e.run_until(t, inclusive=False) for e in self.engines)

    def close(self) -> None:
        """End the stream and drain every device to completion."""
        for engine in self.engines:
            engine.close_stream()
        for engine in self.engines:
            engine.drain()
        self.closed = True

    def result(self) -> FleetResult:
        """Aggregate results; only valid after :meth:`close`."""
        if not self.closed:
            raise RuntimeError("fleet stream still open; close() it first")
        per_device = [engine.result() for engine in self.engines]
        return _finish_result(self.profiles, per_device, self.counts, self.trace)
