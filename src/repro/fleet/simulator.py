"""Fleet-level MIG simulation: N heterogeneous GPUs behind one dispatcher.

Execution model (two phases, both deterministic):

1. *Dispatch* — the merged arrival stream is walked once; the pluggable
   dispatcher (:mod:`repro.fleet.dispatch`) routes each job to a device from
   a fluid per-device backlog estimate.
2. *Simulate* — each device runs its job subset through its own
   :class:`~repro.core.simulator.MIGSimulator` (own scheduler, repartition
   policy, power model, and partition table), exactly as the single-GPU
   paper path does.

Per-device :class:`~repro.core.metrics.SimResult`\\ s are then aggregated
into fleet totals.  The load-bearing invariant — pinned by tests and the
``fleet_scaling`` CI baseline — is that a **1-device fleet is bit-identical
to the single-MIG path**: one device receives the job list unchanged, runs
the identical simulator, and ``aggregate_sim_results`` of one result *is*
that result.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.jobs import Job
from repro.core.metrics import SimResult
from repro.core.schedulers import make_scheduler
from repro.core.simulator import MIGSimulator, RepartitionPolicy
from repro.core.slices import MIG_CONFIGS, Partition
from repro.fleet.devices import DeviceProfile, device_profile
from repro.fleet.dispatch import DispatchTrace, dispatch_jobs, make_dispatcher

__all__ = [
    "DeviceAdaptedPolicy",
    "FleetDeviceSpec",
    "FleetSpec",
    "FleetResult",
    "FleetView",
    "FleetSimulator",
    "aggregate_sim_results",
]


@dataclasses.dataclass(frozen=True)
class FleetDeviceSpec:
    """One fleet member: a profile name plus optional per-device overrides."""

    profile: str
    scheduler: Optional[str] = None  # None -> the fleet default
    initial_config: Optional[int] = None  # None -> the policy's choice


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A fleet: device list, dispatcher, and default in-device scheduler."""

    devices: Tuple[FleetDeviceSpec, ...]
    dispatcher: str = "round-robin"
    scheduler: str = "EDF-SS"

    @staticmethod
    def of(profiles: Sequence[str], dispatcher: str = "round-robin",
           scheduler: str = "EDF-SS") -> "FleetSpec":
        """Shorthand: a fleet from profile names with no per-device overrides."""
        return FleetSpec(
            devices=tuple(FleetDeviceSpec(profile=p) for p in profiles),
            dispatcher=dispatcher,
            scheduler=scheduler,
        )


@dataclasses.dataclass
class FleetResult:
    """Aggregate + per-device outcome of one fleet run."""

    aggregate: SimResult
    per_device: List[SimResult]
    dispatch_counts: List[int]
    trace: DispatchTrace

    @property
    def num_devices(self) -> int:
        """Fleet size of the run that produced this result."""
        return len(self.per_device)


class FleetView:
    """Read-only dispatch-time load lookup for fleet-aware observations.

    Wraps the dispatch trace: ``load_share(i, t)`` is device ``i``'s share of
    the fleet's estimated backlog at the last routing decision before ``t``,
    ``total_load_norm(t)`` the fleet backlog normalized to ``norm_min``
    device-minutes and clipped to [0, 1].
    """

    def __init__(self, trace: DispatchTrace, profiles: Sequence[DeviceProfile],
                 norm_min: float = 120.0) -> None:
        self._times = [t for t, _ in trace]
        self._backlogs = [b for _, b in trace]
        self._profiles = list(profiles)
        self._norm_min = norm_min

    def _at(self, t: float) -> Optional[Tuple[float, ...]]:
        i = bisect.bisect_right(self._times, t) - 1
        return self._backlogs[i] if i >= 0 else None

    def load_share(self, device_index: int, t: float) -> float:
        """Device's fraction of the estimated fleet backlog just before ``t``."""
        rec = self._at(t)
        if rec is None:
            return 0.0
        total = sum(rec)
        return rec[device_index] / total if total > 0.0 else 0.0

    def total_load_norm(self, t: float) -> float:
        """Fleet backlog in device-minutes, normalized+clipped to [0, 1]."""
        rec = self._at(t)
        if rec is None:
            return 0.0
        device_minutes = sum(
            b / p.total_slots for b, p in zip(rec, self._profiles)
        )
        return min(device_minutes / self._norm_min, 1.0)


def aggregate_sim_results(per_device: Sequence[SimResult]) -> SimResult:
    """Fleet totals from per-device results.

    For one device the input is returned unchanged — this is what makes the
    1-GPU fleet bit-identical to the single-MIG path by construction rather
    than by floating-point luck.
    """
    if not per_device:
        raise ValueError("no device results")
    if len(per_device) == 1:
        return per_device[0]
    num_jobs = sum(r.num_jobs for r in per_device)
    total_tard = sum(r.total_tardiness for r in per_device)
    return SimResult(
        energy_wh=sum(r.energy_wh for r in per_device),
        avg_tardiness=total_tard / max(num_jobs, 1),
        num_jobs=num_jobs,
        total_tardiness=total_tard,
        preemptions=sum(r.preemptions for r in per_device),
        repartitions=sum(r.repartitions for r in per_device),
        max_tardiness=max(r.max_tardiness for r in per_device),
        deadline_misses=sum(r.deadline_misses for r in per_device),
        busy_slot_minutes=sum(r.busy_slot_minutes for r in per_device),
        extra={
            "makespan_min": max(r.extra.get("makespan_min", 0.0) for r in per_device),
            "tardiness_integral": sum(
                r.extra.get("tardiness_integral", 0.0) for r in per_device
            ),
        },
    )


class DeviceAdaptedPolicy:
    """Maps a policy's config choices onto a non-A100 device's table.

    Every registered dynamic policy (daynight, heuristic, DQN) emits ids in
    the paper's A100 Fig. 1 space; on a device with a different table those
    ids would KeyError mid-run.  An out-of-table choice is mapped to the
    device config whose *slice count* is closest to the requested A100
    layout's — the policy decides how finely partitioned the GPU should be,
    and that intent survives the translation.  In-table choices pass through
    untouched, so the wrapper is the identity on A100 devices.
    """

    def __init__(self, inner: RepartitionPolicy, configs: "dict[int, Partition]") -> None:
        self.inner = inner
        self.configs = dict(configs)
        self.initial_config = self._map(inner.initial_config)

    def _map(self, choice: Optional[int]) -> Optional[int]:
        if choice is None or choice in self.configs:
            return choice
        ref = MIG_CONFIGS.get(choice)
        if ref is None:
            return choice  # unknown everywhere: let the simulator raise
        want = ref.num_slices
        return min(
            self.configs,
            key=lambda cid: (abs(self.configs[cid].num_slices - want), cid),
        )

    def decide(self, t: float, sim: MIGSimulator) -> Optional[int]:
        """Inner policy's choice, translated onto this device's table."""
        return self._map(self.inner.decide(t, sim))

    def next_timer(self, t: float) -> Optional[float]:
        """Pass through the inner policy's timer chain unchanged."""
        return self.inner.next_timer(t)


#: per-device policy source: ``factory(device_index, profile) -> policy``
PolicyFactory = Callable[[int, DeviceProfile], RepartitionPolicy]


class FleetSimulator:
    """Run a :class:`FleetSpec` over a job stream.

    Policies are built per device via ``policy_factory`` (policy instances
    carry per-run state and must never be shared across devices).  The last
    run's per-device simulators stay on ``self.sims`` for inspection — the
    RL layer reads their queue state through
    :func:`repro.core.rl.env.fleet_state_features`.
    """

    def __init__(self, spec: FleetSpec, mig_enabled: bool = True) -> None:
        if not spec.devices:
            raise ValueError("fleet needs at least one device")
        self.spec = spec
        self.mig_enabled = mig_enabled
        self.profiles = [device_profile(d.profile) for d in spec.devices]
        self.sims: List[MIGSimulator] = []
        self.view: Optional[FleetView] = None

    def run(
        self,
        jobs: Sequence[Job],
        policy_factory: PolicyFactory,
        decision_hook: Optional[Callable[[int, float, MIGSimulator], None]] = None,
    ) -> FleetResult:
        """Dispatch ``jobs`` across the fleet and simulate every device.

        ``decision_hook(device_index, t, sim)`` fires at each per-device
        decision point (the fleet-aware RL observation path).  Returns the
        aggregated :class:`FleetResult`; per-device simulators stay on
        ``self.sims`` for inspection.
        """
        dispatcher = make_dispatcher(self.spec.dispatcher)
        assignments, trace = dispatch_jobs(jobs, self.profiles, dispatcher)
        self.view = FleetView(trace, self.profiles)

        self.sims = []
        per_device: List[SimResult] = []
        counts = [0] * len(self.profiles)
        for a in assignments:
            counts[a] += 1
        for i, (dev, prof) in enumerate(zip(self.spec.devices, self.profiles)):
            subset = [job for job, a in zip(jobs, assignments) if a == i]
            sim = MIGSimulator(
                make_scheduler(dev.scheduler or self.spec.scheduler),
                power_model=prof.power,
                mig_enabled=self.mig_enabled,
                config_table=prof.configs,
            )
            hook = None
            if decision_hook is not None:
                hook = (lambda idx: lambda t, s: decision_hook(idx, t, s))(i)
            policy = policy_factory(i, prof)
            if set(prof.configs) != set(MIG_CONFIGS):
                # non-A100 table: translate the policy's A100-space choices
                policy = DeviceAdaptedPolicy(policy, prof.configs)
            res = sim.run(
                subset,
                policy=policy,
                initial_config=dev.initial_config,
                decision_hook=hook,
            )
            self.sims.append(sim)
            per_device.append(res)
        aggregate = aggregate_sim_results(per_device)
        if len(per_device) > 1:
            # Per-device energy only covers [0, device makespan] (the single-GPU
            # convention).  Devices the dispatcher starved still draw idle power
            # until the fleet drains; report that separately so packing
            # dispatchers aren't credited with turning idle silicon off.
            fleet_makespan = aggregate.extra["makespan_min"]
            idle_gap_wh = sum(
                prof.power.idle_watts
                * max(fleet_makespan - res.extra.get("makespan_min", 0.0), 0.0)
                / 60.0
                for prof, res in zip(self.profiles, per_device)
            )
            aggregate = dataclasses.replace(
                aggregate,
                extra={**aggregate.extra, "fleet_idle_gap_wh": idle_gap_wh},
            )
        return FleetResult(
            aggregate=aggregate,
            per_device=per_device,
            dispatch_counts=counts,
            trace=trace,
        )
