"""On-disk memo cache for completed sweep cells.

One JSON file per cell under the cache directory, named
``<content-hash>.<SIM_VERSION>.json``.  Writes are atomic (tmp + rename) so
a crashed worker can never leave a torn entry, and the parent persists each
result the moment it arrives — a re-run after an interrupt recomputes only
the missing cells.

Version safety: the simulator version is part of the *filename* (and
recorded inside the payload, as a guard against hand-copied files), so
detecting entries from a different ``SIM_VERSION`` is a single ``listdir``
— no marker files, no fast paths that can be defeated.  Resuming a sweep
over a cache holding foreign-version entries raises :class:`StaleCacheError`
instead of silently proceeding: the hash already separates versions, but a
half-migrated cache directory is almost always a
bumped-``SIM_VERSION``-without-regenerated-baselines mistake the operator
should see loudly (``python -m repro.sweep --purge-stale-cache`` clears it).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

from repro.core.simulator import SIM_VERSION

__all__ = ["SweepCache", "StaleCacheError", "DEFAULT_CACHE_DIR"]

DEFAULT_CACHE_DIR = os.path.join("artifacts", "sweeps", "cache")


class StaleCacheError(RuntimeError):
    """The cache holds entries computed under a different ``SIM_VERSION``."""


def _split_entry_name(name: str) -> Optional[Tuple[str, str]]:
    """``(key, version)`` from an entry filename, or None for non-entries.

    Pre-versioned-layout files (``<hash>.json``) report version ``""`` so
    they read as foreign and get refused/purged rather than ignored.
    """
    if not name.endswith(".json"):
        return None
    stem = name[: -len(".json")]
    key, _, version = stem.partition(".")
    return key, version


class SweepCache:
    """Content-addressed on-disk memo of finished cells (see module doc)."""

    def __init__(self, root: str = DEFAULT_CACHE_DIR) -> None:
        self.root = root
        self.hits = 0
        self.misses = 0
        self._checked = False

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.{SIM_VERSION}.json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Return the memoized result dict for ``key``, or None."""
        try:
            with open(self._path(key)) as f:
                payload = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            self.misses += 1
            return None
        if payload.get("sim_version") != SIM_VERSION:
            # the filename already pins the version; this guards files
            # hand-copied across differently-versioned cache directories
            self.misses += 1
            return None
        self.hits += 1
        return payload["result"]

    def put(self, key: str, cell: Dict[str, Any], result: Dict[str, Any]) -> None:
        """Atomically persist one finished cell under the current version."""
        os.makedirs(self.root, exist_ok=True)
        payload = {"sim_version": SIM_VERSION, "cell": cell, "result": result}
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self._path(key))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ------------------------------------------------------------------
    # SIM_VERSION hygiene

    def scan_versions(self) -> Dict[str, int]:
        """``{sim_version: entry count}`` read off the entry filenames."""
        versions: Dict[str, int] = {}
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return versions
        for n in names:
            parsed = _split_entry_name(n)
            if parsed is None:
                continue
            versions[parsed[1]] = versions.get(parsed[1], 0) + 1
        return versions

    def check_version(self) -> None:
        """Refuse to resume over entries from a different ``SIM_VERSION``.

        A pure filename scan (one listdir, no file reads), so it runs on
        every resume; once a process has seen a clean directory it skips the
        re-scan (entries it writes afterwards are all current-version).
        """
        if self._checked:
            return
        stale = {v: c for v, c in self.scan_versions().items() if v != SIM_VERSION}
        if stale:
            detail = ", ".join(f"{c} cells at {v!r}" for v, c in sorted(stale.items()))
            raise StaleCacheError(
                f"sweep cache {self.root!r} holds entries from a different "
                f"simulator version ({detail}; current SIM_VERSION is "
                f"{SIM_VERSION!r}).  Resuming would silently mix simulation "
                f"semantics.  Run `python -m repro.sweep --purge-stale-cache` "
                f"to drop the stale entries, or `--no-resume` to recompute "
                f"without reading the cache."
            )
        self._checked = True

    def purge_stale(self) -> int:
        """Delete entries whose filename version differs; returns the count."""
        removed = 0
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return removed
        for n in names:
            parsed = _split_entry_name(n)
            if parsed is not None and parsed[1] != SIM_VERSION:
                os.unlink(os.path.join(self.root, n))
                removed += 1
        return removed

    def __len__(self) -> int:
        try:
            return sum(1 for n in os.listdir(self.root) if n.endswith(".json"))
        except FileNotFoundError:
            return 0
