"""On-disk memo cache for completed sweep cells.

One JSON file per cell under the cache directory, named by the cell's
content hash (params + simulator version tag).  Writes are atomic
(tmp + rename) so a crashed worker can never leave a torn entry, and the
parent persists each result the moment it arrives — a re-run after an
interrupt recomputes only the missing cells.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

from repro.core.simulator import SIM_VERSION

__all__ = ["SweepCache", "DEFAULT_CACHE_DIR"]

DEFAULT_CACHE_DIR = os.path.join("artifacts", "sweeps", "cache")


class SweepCache:
    def __init__(self, root: str = DEFAULT_CACHE_DIR) -> None:
        self.root = root
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Return the memoized result dict for ``key``, or None."""
        try:
            with open(self._path(key)) as f:
                payload = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            self.misses += 1
            return None
        if payload.get("sim_version") != SIM_VERSION:
            # hash already covers the version; this guards hand-copied files
            self.misses += 1
            return None
        self.hits += 1
        return payload["result"]

    def put(self, key: str, cell: Dict[str, Any], result: Dict[str, Any]) -> None:
        os.makedirs(self.root, exist_ok=True)
        payload = {"sim_version": SIM_VERSION, "cell": cell, "result": result}
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self._path(key))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def __len__(self) -> int:
        try:
            return sum(1 for n in os.listdir(self.root) if n.endswith(".json"))
        except FileNotFoundError:
            return 0
