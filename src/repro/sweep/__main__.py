"""CLI for the sweep engine.

::

    python -m repro.sweep --list
    python -m repro.sweep fleet_scaling --workers 4
    python -m repro.sweep --grid table2_schedulers --workers 4
    python -m repro.sweep smoke --scale 0.1 --workers 2 \\
        --check-baseline benchmarks/baselines/smoke_sweep.jsonl

Grids are named positionally or via the repeatable ``--grid`` flag.
``--resume`` (default) serves previously computed cells from the on-disk
cache; ``--no-resume`` recomputes everything (results are still persisted).
Resuming refuses (exit 2) when the cache holds cells from a different
``SIM_VERSION`` — ``--purge-stale-cache`` drops them first.
``--check-baseline`` re-reads the freshly written JSONL artifact and compares
it cell-by-cell against a checked-in baseline with a float tolerance; a
mismatch exits non-zero (the CI regression gate).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List

from repro.sweep.cache import DEFAULT_CACHE_DIR, StaleCacheError, SweepCache
from repro.sweep.grids import GRIDS, run_grid


def _fmt(v: Any) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def print_rows(name: str, rows: List[Dict[str, Any]]) -> None:
    if not rows:
        print(f"# {name}: no rows")
        return
    keys = list(rows[0].keys())
    print(f"### {name}")
    print(",".join(keys))
    for r in rows:
        print(",".join(_fmt(r.get(k)) for k in keys))
    print()


def _values_close(a: Any, b: Any, rtol: float) -> bool:
    if isinstance(a, float) or isinstance(b, float):
        fa, fb = float(a), float(b)
        return abs(fa - fb) <= rtol * max(abs(fa), abs(fb), 1.0)
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(
            _values_close(a[k], b[k], rtol) for k in a
        )
    if isinstance(a, list) and isinstance(b, list):
        return len(a) == len(b) and all(
            _values_close(x, y, rtol) for x, y in zip(a, b, strict=True)
        )
    return a == b


def check_baseline(jsonl_path: str, baseline_path: str, rtol: float) -> int:
    """Compare a sweep JSONL artifact against a baseline; returns #mismatches."""
    with open(jsonl_path) as f:
        got = [json.loads(line) for line in f if line.strip()]
    with open(baseline_path) as f:
        want = [json.loads(line) for line in f if line.strip()]
    mismatches = 0
    by_hash = {rec["hash"]: rec for rec in got}
    for rec in want:
        mine = by_hash.get(rec["hash"])
        if mine is None:
            print(f"BASELINE MISS: no cell with hash {rec['hash'][:12]}…")
            mismatches += 1
            continue
        if not _values_close(mine["result"], rec["result"], rtol):
            print(
                f"BASELINE DIFF at hash {rec['hash'][:12]}…:\n"
                f"  want {json.dumps(rec['result'], sort_keys=True)[:300]}\n"
                f"  got  {json.dumps(mine['result'], sort_keys=True)[:300]}"
            )
            mismatches += 1
    if len(got) != len(want):
        print(f"BASELINE SIZE: baseline has {len(want)} cells, run has {len(got)}")
        mismatches += 1
    return mismatches


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep")
    ap.add_argument("grids", nargs="*", metavar="GRID",
                    help="grid name(s) to run (same namespace as --grid)")
    ap.add_argument("--grid", action="append", default=None,
                    help="grid name (repeatable), or 'all'; default table2_schedulers")
    ap.add_argument("--list", action="store_true", help="list available grids")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="iteration-count multiplier (1.0 = CI-sized)")
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes; <=1 runs inline")
    ap.add_argument("--resume", dest="resume", action="store_true", default=True,
                    help="serve completed cells from the cache (default)")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="ignore cached cells; recompute everything")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk cache entirely")
    ap.add_argument("--cache-dir", default=None, help="cache directory override")
    ap.add_argument("--purge-stale-cache", action="store_true",
                    help="delete cached cells from other SIM_VERSIONs, then run")
    ap.add_argument("--artifacts-dir", default=None,
                    help="JSONL artifact directory (default artifacts/sweeps)")
    ap.add_argument("--check-baseline", default=None, metavar="JSONL",
                    help="diff the artifact against this baseline; exit 1 on drift")
    ap.add_argument("--rtol", type=float, default=1e-9,
                    help="relative float tolerance for --check-baseline")
    args = ap.parse_args(argv)

    if args.list:
        for name, grid in sorted(GRIDS.items()):
            print(f"{name:24s} {grid.doc}")
        return 0

    names = list(args.grids) + list(args.grid or [])
    explicit_grids = bool(names)
    if not names:
        names = ["table2_schedulers"]
    if "all" in names:
        names = [n for n in GRIDS if n != "smoke"]
    unknown = [n for n in names if n not in GRIDS]
    if unknown:
        ap.error(f"unknown grid(s) {unknown}; available: {', '.join(sorted(GRIDS))}")
    if args.check_baseline and not os.path.exists(args.check_baseline):
        ap.error(f"baseline file not found: {args.check_baseline}")
    if args.check_baseline and len(names) > 1:
        # one baseline file cannot describe several grids; diffing each grid
        # against it would guarantee spurious mismatches for all but one
        ap.error(
            "--check-baseline takes exactly one grid per invocation "
            f"(got {len(names)}: {', '.join(names)})"
        )

    cache: Any = True
    if args.no_cache:
        cache = False
    elif args.cache_dir:
        cache = args.cache_dir

    if args.purge_stale_cache and not args.no_cache:
        purge_dir = args.cache_dir or DEFAULT_CACHE_DIR
        removed = SweepCache(purge_dir).purge_stale()
        print(f"# purged {removed} stale cache entries from {purge_dir}",
              file=sys.stderr)
        if not explicit_grids:
            # bare `--purge-stale-cache` (the StaleCacheError remediation)
            # is purge-only — don't surprise the user with a default sweep
            return 0

    kwargs: Dict[str, Any] = {}
    if args.artifacts_dir is not None:
        kwargs["artifacts_dir"] = args.artifacts_dir

    failed = 0
    for name in names:
        t0 = time.time()  # lint: waive[DT002] progress-log timing only
        try:
            rows, outcome = run_grid(
                name,
                scale=args.scale,
                workers=args.workers,
                cache=cache,
                resume=args.resume,
                progress=lambda m: print(m, file=sys.stderr),
                **kwargs,
            )
        except StaleCacheError as e:
            print(f"ERROR: {e}", file=sys.stderr)
            return 2
        print_rows(name, rows)
        print(
            f"# {name}: {outcome.total} cells "
            f"({outcome.cached_count} cached, {outcome.computed_count} computed) "
            f"in {time.time() - t0:.1f}s -> {outcome.jsonl_path}",  # lint: waive[DT002] progress log
            file=sys.stderr,
        )
        if args.check_baseline:
            n_bad = check_baseline(outcome.jsonl_path, args.check_baseline, args.rtol)
            if n_bad:
                print(f"# {name}: {n_bad} baseline mismatches", file=sys.stderr)
                failed += n_bad
            else:
                print(f"# {name}: matches baseline {args.check_baseline}",
                      file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
