"""Declarative sweep grids for the paper's tables and figures.

Each grid is a :class:`GridDef`: ``build(scale)`` enumerates the cells
(policy x scheduler x config x WorkloadSpec x seed) and ``aggregate``
reduces per-cell results to the table's rows.  ``benchmarks/paper_tables.py``
is a thin wrapper over this registry; the CLI (``python -m repro.sweep``)
runs the same grids directly.

Cell enumeration order is load-bearing: float accumulation is
order-sensitive, and these builders walk the exact nested-loop order of the
original serial benchmarks so the sweep path reproduces their numbers
bit-for-bit at any worker count.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.metrics import SimResult, et_table
from repro.core.workload import WorkloadSpec
from repro.sweep.cells import (
    Cell,
    group_results,
    make_cell,
    make_fleet_cell,
    make_scenario_cell,
    result_to_sim_result,
)
from repro.sweep.runner import DEFAULT_ARTIFACTS_DIR, SweepOutcome, run_cells

__all__ = [
    "GridDef",
    "GRIDS",
    "POLICY_FAMILIES",
    "run_grid",
    "summarize_results",
    "DQN_PARAMS_PATH",
]

ALGOS = ["EDF-FS", "EDF-SS", "LLF", "LALF"]
DQN_PARAMS_PATH = os.path.join("artifacts", "dqn_params.npz")

#: scenario_matrix row order — fixed here (not registry-sorted) so adding a
#: scenario later cannot silently reshuffle the checked-in baseline.
SCENARIO_ORDER = (
    "paper-diurnal",
    "trace-scaled",
    "bursty-mmpp",
    "heavy-tail-lognormal",
    "heavy-tail-pareto",
    "weekend-flat",
)

Rows = List[Dict[str, Any]]


@dataclasses.dataclass(frozen=True)
class GridDef:
    """A declarative sweep: cell enumeration + result aggregation."""

    name: str
    doc: str
    build: Callable[[float], List[Cell]]
    aggregate: Callable[[List[Cell], List[Dict[str, Any]]], Rows]


def summarize_results(results: Sequence[SimResult]) -> Dict[str, float]:
    """Mean of the headline per-run metrics (matches Tables II/III columns)."""
    n = max(len(results), 1)
    return {
        "energy_wh": sum(r.energy_wh for r in results) / n,
        "avg_tardiness": sum(r.avg_tardiness for r in results) / n,
        "preemptions": sum(r.preemptions for r in results) / n,
        "repartitions": sum(r.repartitions for r in results) / n,
        "deadline_misses": sum(r.deadline_misses for r in results) / n,
    }


def _basket_specs() -> List[WorkloadSpec]:
    """The Table II experiment basket (§V-B)."""
    return [
        WorkloadSpec(),
        WorkloadSpec(horizon_min=480.0, constant_rate=0.1),
        WorkloadSpec(horizon_min=480.0, constant_rate=0.5),
        WorkloadSpec(inference_split=0.2),
    ]


def _iters(base: int, scale: float, floor: int = 1) -> int:
    return max(int(base * scale), floor)


# ----------------------------------------------------------------------
# Table II


def _table2_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    cells: List[Cell] = []
    for si, spec in enumerate(_basket_specs()):
        for cfg in range(1, 13):
            for n in ALGOS:
                for k in range(iters):
                    cells.append(
                        make_cell(
                            experiment="table2_schedulers",
                            group=n,
                            scheduler=n,
                            workload=spec,
                            seed=9000 * si + 17 * cfg + k,
                            policy="static",
                            policy_kwargs={"config_id": cfg},
                        )
                    )
    return cells


def _table2_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    per = group_results(cells, results)
    table, _a = et_table(per)
    return [
        {"algorithm": n, "ET": table[n], **summarize_results(per[n])} for n in ALGOS
    ]


# ----------------------------------------------------------------------
# Fig. 4 — restricted vs unrestricted EDF-SS preemptions, per config


def _fig4_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    spec = WorkloadSpec()
    cells: List[Cell] = []
    for cfg in range(1, 13):
        for n in ("EDF-SS", "EDF-SS-unrestricted"):
            for k in range(iters):
                cells.append(
                    make_cell(
                        experiment="fig4_preemption",
                        group=f"cfg{cfg}:{n}",
                        scheduler=n,
                        workload=spec,
                        seed=100 * cfg + k,
                        policy="static",
                        policy_kwargs={"config_id": cfg},
                    )
                )
    return cells


def _fig4_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    grouped = group_results(cells, results)
    rows: Rows = []
    for cfg in range(1, 13):
        rec: Dict[str, Any] = {"config": cfg}
        per = {n: grouped[f"cfg{cfg}:{n}"] for n in ("EDF-SS", "EDF-SS-unrestricted")}
        for n, rs in per.items():
            key = "restricted" if n == "EDF-SS" else "unrestricted"
            rec[f"preempt_{key}"] = sum(r.preemptions for r in rs) / len(rs)
        t, _ = et_table(per)
        rec["et_restricted"] = t["EDF-SS"]
        rec["et_unrestricted"] = t["EDF-SS-unrestricted"]
        rec["reduction_pct"] = 100.0 * (
            1 - rec["preempt_restricted"] / max(rec["preempt_unrestricted"], 1e-9)
        )
        rows.append(rec)
    return rows


# ----------------------------------------------------------------------
# Fig. 6 — utilization histogram per algorithm


def _fig6_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    spec = WorkloadSpec(horizon_min=480.0, constant_rate=0.5)
    return [
        make_cell(
            experiment="fig6_utilization",
            group=n,
            scheduler=n,
            workload=spec,
            seed=600 + s,
            policy="static",
            policy_kwargs={"config_id": 4},
        )
        for n in ALGOS
        for s in range(iters)
    ]


def _fig6_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    rows: Rows = []
    for n in ALGOS:
        hist: Dict[int, float] = {}
        total = 0.0
        for cell, result in zip(cells, results, strict=True):
            if cell["group"] != n:
                continue
            for k, v in result["util_histogram"].items():
                k = int(k)
                hist[k] = hist.get(k, 0.0) + v
                total += v
        row: Dict[str, Any] = {"algorithm": n}
        for k in range(8):
            row[f"util_{k}"] = 100.0 * hist.get(k, 0.0) / max(total, 1e-9)
        rows.append(row)
    return rows


# ----------------------------------------------------------------------
# Figs. 7-10 — ET per configuration across arrival rates / inference splits


def _sweep_spec_cells(
    experiment: str, specs: List[Tuple[Any, WorkloadSpec]], seed_base: int, scale: float
) -> List[Cell]:
    iters = _iters(2, scale)
    cells: List[Cell] = []
    for label, spec in specs:
        for cfg in range(1, 13):
            for n in ALGOS:
                for k in range(iters):
                    cells.append(
                        make_cell(
                            experiment=experiment,
                            group=f"{label}:cfg{cfg}:{n}",
                            scheduler=n,
                            workload=spec,
                            seed=seed_base * cfg + k,
                            policy="static",
                            policy_kwargs={"config_id": cfg},
                        )
                    )
    return cells


def _sweep_spec_aggregate(
    cells: List[Cell],
    results: List[Dict[str, Any]],
    labels: List[Tuple[Any, str]],
) -> Rows:
    grouped = group_results(cells, results)
    rows: Rows = []
    for label, column in labels:
        for cfg in range(1, 13):
            per = {n: grouped[f"{label}:cfg{cfg}:{n}"] for n in ALGOS}
            t, _ = et_table(per)
            rows.append({column: label, "config": cfg, **{n: t[n] for n in ALGOS}})
    return rows


_FIG7_RATES = (0.1, 0.5, 0.75)
_FIG9_SPLITS = (0.2, 0.8)


def _fig7_cells(scale: float) -> List[Cell]:
    specs = [
        (rate, WorkloadSpec(horizon_min=480.0, constant_rate=rate))
        for rate in _FIG7_RATES
    ]
    return _sweep_spec_cells("fig7_fig8_arrival", specs, 300, scale)


def _fig7_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    return _sweep_spec_aggregate(
        cells, results, [(rate, "rate") for rate in _FIG7_RATES]
    )


def _fig9_cells(scale: float) -> List[Cell]:
    specs = [
        (split, WorkloadSpec(inference_split=split)) for split in _FIG9_SPLITS
    ]
    return _sweep_spec_cells("fig9_fig10_split", specs, 500, scale)


def _fig9_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    return _sweep_spec_aggregate(
        cells, results, [(split, "inference_split") for split in _FIG9_SPLITS]
    )


# ----------------------------------------------------------------------
# Table III — repartitioning models


def _table3_models(include_dqn: Optional[bool] = None) -> List[Tuple[str, Dict[str, Any]]]:
    """(model name, cell overrides) in Table III row order."""
    models: List[Tuple[str, Dict[str, Any]]] = [
        ("NoMIG", {"policy": "nomig", "mig_enabled": False}),
        ("StaticMIG", {"policy": "static", "policy_kwargs": {"config_id": 3}}),
        ("DayNightMIG", {"policy": "daynight"}),
        ("DynamicMIG-heuristic", {"policy": "heuristic"}),
    ]
    if include_dqn is None:
        include_dqn = os.path.exists(DQN_PARAMS_PATH)
    if include_dqn:
        models.append(
            ("DynamicMIG-DQN", {"policy": "dqn", "policy_kwargs": {"params_path": DQN_PARAMS_PATH}})
        )
    return models


def _table3_cells(scale: float) -> List[Cell]:
    iters = _iters(10, scale, floor=2)
    spec = WorkloadSpec()
    seeds = [40_000 + k for k in range(iters)]
    cells: List[Cell] = []
    for name, overrides in _table3_models():
        for s in seeds:
            cells.append(
                make_cell(
                    experiment="table3_repartitioning",
                    group=name,
                    scheduler="EDF-SS",
                    workload=spec,
                    seed=s,
                    **overrides,
                )
            )
    return cells


def _table3_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    per = group_results(cells, results)
    table, _a = et_table(per)
    rows: Rows = []
    for name in per:
        s = summarize_results(per[name])
        rows.append(
            {
                "model": name,
                "ET": table[name],
                "improvement_vs_NoMIG_pct": 100 * (1 - table[name] / table["NoMIG"]),
                **s,
            }
        )
    return rows


# ----------------------------------------------------------------------
# Fig. 11 — preferred configurations per 4h interval under the dynamic policy


def _fig11_policy() -> Dict[str, Any]:
    if os.path.exists(DQN_PARAMS_PATH):
        return {"policy": "dqn", "policy_kwargs": {"params_path": DQN_PARAMS_PATH}}
    return {"policy": "heuristic"}


def _fig11_cells(scale: float) -> List[Cell]:
    iters = _iters(6, scale, floor=2)
    spec = WorkloadSpec()
    overrides = _fig11_policy()
    return [
        make_cell(
            experiment="fig11_preferences",
            group="dynamic",
            scheduler="EDF-SS",
            workload=spec,
            seed=77_000 + s,
            **overrides,
        )
        for s in range(iters)
    ]


def _fig11_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    occupancy: Dict[int, Dict[int, float]] = {b: {} for b in range(6)}
    for result in results:
        trace = [(t, int(c)) for t, c in result["config_trace"]]
        trace = [*trace, (24 * 60.0, trace[-1][1])]
        for (t0, c), (t1, _) in zip(trace, trace[1:], strict=False):
            t0c, t1c = min(t0, 1440.0), min(t1, 1440.0)
            while t0c < t1c:
                b = int(t0c // 240) % 6
                upper = min((int(t0c // 240) + 1) * 240.0, t1c)
                occupancy[b][c] = occupancy[b].get(c, 0.0) + (upper - t0c)
                t0c = upper
    rows: Rows = []
    for b in range(6):
        tot = sum(occupancy[b].values()) or 1.0
        row: Dict[str, Any] = {"interval": f"{b*4:02d}:00-{b*4+4:02d}:00"}
        for c in range(1, 13):
            row[f"cfg{c}_pct"] = 100.0 * occupancy[b].get(c, 0.0) / tot
        rows.append(row)
    return rows


# ----------------------------------------------------------------------
# fleet_scaling — N heterogeneous GPUs x dispatcher, paper-diurnal scenario.
# The 1xA100/round-robin cells double as the fleet-vs-single bit-identity
# anchor: their aggregates must equal the single-MIG path at the same seeds.

_FLEETS: List[Tuple[str, List[str]]] = [
    ("1xA100", ["a100-250w"]),
    ("2xA100", ["a100-250w"] * 2),
    ("4xA100", ["a100-250w"] * 4),
    ("2xA100+2xA30", ["a100-250w", "a100-250w", "a30-165w", "a30-165w"]),
]
_FLEET_DISPATCHERS = ("round-robin", "least-loaded", "energy-greedy", "state-aware")


def _fleet_scaling_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    cells: List[Cell] = []
    for fname, profiles in _FLEETS:
        for disp in _FLEET_DISPATCHERS:
            for k in range(iters):
                cells.append(
                    make_fleet_cell(
                        experiment="fleet_scaling",
                        group=f"{fname}:{disp}",
                        profiles=profiles,
                        dispatcher=disp,
                        scheduler="EDF-SS",
                        scenario="paper-diurnal",
                        seed=31_000 + k,
                        policy="static",
                        policy_kwargs={"config_id": 3},
                    )
                )
    return cells


def _fleet_scaling_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    per = group_results(cells, results)
    table, _a = et_table(per)
    rows: Rows = []
    for fname, profiles in _FLEETS:
        for disp in _FLEET_DISPATCHERS:
            g = f"{fname}:{disp}"
            rows.append(
                {
                    "fleet": fname,
                    "devices": len(profiles),
                    "dispatcher": disp,
                    "ET": table[g],
                    **summarize_results(per[g]),
                }
            )
    return rows


# ----------------------------------------------------------------------
# dispatchers — online (real engine state) vs fluid (backlog estimate)
# routing, per dispatcher, on multi-GPU fleets.  The measurable form of the
# engine refactor's semantics change: dispatch decisions now see true
# per-device queue/partition/repartition state at each arrival, and this
# grid reports what that information is worth.  ``state-aware`` reads
# signals the fluid estimate cannot produce, so it only has online rows.

#: the multi-device rows of _FLEETS (a 1-device fleet routes identically in
#: both modes, so it would only pad the grid)
_DISPATCHER_FLEETS: List[Tuple[str, List[str]]] = [
    (fname, profiles) for fname, profiles in _FLEETS
    if fname in ("4xA100", "2xA100+2xA30")
]


def _dispatchers_cells(scale: float) -> List[Cell]:
    # the validated mode list lives on the fleet layer; imported lazily so
    # plain single-GPU sweeps keep their import-light workers
    from repro.fleet.simulator import DISPATCH_INFO_MODES

    iters = _iters(2, scale)
    cells: List[Cell] = []
    for fname, profiles in _DISPATCHER_FLEETS:
        for disp in _FLEET_DISPATCHERS:
            for info in DISPATCH_INFO_MODES:
                if disp == "state-aware" and info == "fluid":
                    continue  # needs real state by construction
                for k in range(iters):
                    cells.append(
                        make_fleet_cell(
                            experiment="dispatchers",
                            group=f"{fname}:{disp}:{info}",
                            profiles=profiles,
                            dispatcher=disp,
                            scheduler="EDF-SS",
                            scenario="paper-diurnal",
                            seed=87_000 + k,
                            policy="static",
                            policy_kwargs={"config_id": 3},
                            dispatch_info=info,
                        )
                    )
    return cells


def _dispatchers_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    grouped = group_results(cells, results)
    rows: Rows = []
    for fname, _profiles in _DISPATCHER_FLEETS:
        # shared ET scale factor per fleet across every dispatcher x mode
        per = {
            g: rs for g, rs in grouped.items() if g.startswith(f"{fname}:")
        }
        t, a = et_table(per)
        for disp in _FLEET_DISPATCHERS:
            et_online = t[f"{fname}:{disp}:online"]
            et_fluid = t.get(f"{fname}:{disp}:fluid")
            row: Dict[str, Any] = {
                "fleet": fname,
                "dispatcher": disp,
                "et_a": a,
                "ET_online": et_online,
                "ET_fluid": et_fluid,
                "online_gain_pct": (
                    100.0 * (1.0 - et_online / et_fluid)
                    if et_fluid is not None
                    else None
                ),
                **{
                    f"{k}_online": v
                    for k, v in summarize_results(
                        per[f"{fname}:{disp}:online"]
                    ).items()
                },
            }
            rows.append(row)
    return rows


# ----------------------------------------------------------------------
# scenario_matrix — every registered scenario x the four schedulers


def _scenario_matrix_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    cells: List[Cell] = []
    for si, sname in enumerate(SCENARIO_ORDER):
        for n in ALGOS:
            for k in range(iters):
                cells.append(
                    make_scenario_cell(
                        experiment="scenario_matrix",
                        group=f"{sname}:{n}",
                        scheduler=n,
                        scenario=sname,
                        seed=52_000 + 101 * si + k,
                        policy="static",
                        policy_kwargs={"config_id": 3},
                    )
                )
    return cells


def _scenario_matrix_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    grouped = group_results(cells, results)
    rows: Rows = []
    for sname in SCENARIO_ORDER:
        per = {n: grouped[f"{sname}:{n}"] for n in ALGOS}
        t, _ = et_table(per)
        all_rs = [r for n in ALGOS for r in per[n]]
        rows.append(
            {
                "scenario": sname,
                **{n: t[n] for n in ALGOS},
                "energy_wh": sum(r.energy_wh for r in all_rs) / len(all_rs),
                "avg_tardiness": sum(r.avg_tardiness for r in all_rs) / len(all_rs),
                "num_jobs": sum(r.num_jobs for r in all_rs) / len(all_rs),
            }
        )
    return rows


# ----------------------------------------------------------------------
# repartition_policies — every repartitioning policy family x scenario.
# The measurable form of the paper's closing conjecture: the predictive
# controller (repro.forecast) lines up against no-MIG, static, day/night and
# the queue heuristic on every registered scenario; the DQN joins whenever
# trained weights exist (artifacts are not checked in, so CI compares the
# five deterministic families).  EXPERIMENTS.md §Predictive-controller is
# rendered from this grid's checked-in baseline.

#: (family name, cell overrides) — fixed row order; forecast cells carry the
#: scenario name so the day-model is fitted on the same workload it controls.
POLICY_FAMILIES: List[Tuple[str, Dict[str, Any]]] = [
    ("NoMIG", {"policy": "nomig", "mig_enabled": False}),
    ("StaticMIG", {"policy": "static", "policy_kwargs": {"config_id": 3}}),
    ("DayNightMIG", {"policy": "daynight"}),
    ("Heuristic", {"policy": "heuristic"}),
    ("Forecast", {"policy": "forecast"}),
]


def _repartition_policy_models() -> List[Tuple[str, Dict[str, Any]]]:
    models = list(POLICY_FAMILIES)
    if os.path.exists(DQN_PARAMS_PATH):
        models.append(
            ("DQN", {"policy": "dqn", "policy_kwargs": {"params_path": DQN_PARAMS_PATH}})
        )
    return models


def _repartition_policies_cells(scale: float) -> List[Cell]:
    iters = _iters(4, scale, floor=4)
    cells: List[Cell] = []
    for si, sname in enumerate(SCENARIO_ORDER):
        for fname, overrides in _repartition_policy_models():
            overrides = {k: dict(v) if isinstance(v, dict) else v for k, v in overrides.items()}
            if overrides.get("policy") == "forecast":
                overrides["policy_kwargs"] = {"scenario": sname}
            for k in range(iters):
                cells.append(
                    make_scenario_cell(
                        experiment="repartition_policies",
                        group=f"{sname}:{fname}",
                        scheduler="EDF-SS",
                        scenario=sname,
                        seed=61_200 + 97 * si + k,
                        **overrides,
                    )
                )
    return cells


def _repartition_policies_aggregate(
    cells: List[Cell], results: List[Dict[str, Any]]
) -> Rows:
    grouped = group_results(cells, results)
    # families come from the cells being aggregated, NOT the local
    # filesystem: a checked-in 5-family baseline must aggregate identically
    # on a machine that happens to have DQN weights on disk
    families: List[str] = []
    for cell in cells:
        fam = cell["group"].split(":", 1)[1]
        if fam not in families:
            families.append(fam)
    rows: Rows = []
    for sname in SCENARIO_ORDER:
        per = {f: grouped[f"{sname}:{f}"] for f in families}
        t, a = et_table(per)
        row: Dict[str, Any] = {"scenario": sname, "et_a": a}
        for f in families:
            rs = per[f]
            row[f"ET_{f}"] = t[f]
            row[f"repartitions_{f}"] = sum(r.repartitions for r in rs) / len(rs)
        row["forecast_beats_static"] = t["Forecast"] < t["StaticMIG"]
        rows.append(row)
    return rows


# ----------------------------------------------------------------------
# repartition_modes — drain vs partial reconfiguration × repartitioning
# policy families × scenarios.  The measurable form of the slot-placement
# fidelity fix: under "partial" only the slice instances that change are
# rebuilt and jobs on surviving instances run through the 4 s stall, so a
# policy family's preemption count can only fall and its ET should hold or
# improve.  Only families that actually repartition are raced (a static
# policy is mode-invariant by construction — pinned by tests instead of
# paid for in CI cells); forecast cells carry the mode in policy_kwargs so
# the MPC lookahead prices the same transition physics the simulator
# charges.  Same seeds across modes: each drain/partial pair sees an
# identical job stream.

#: (family name, cell overrides) — families whose policies repartition
REPARTITION_MODE_FAMILIES: List[Tuple[str, Dict[str, Any]]] = [
    ("DayNightMIG", {"policy": "daynight"}),
    ("Heuristic", {"policy": "heuristic"}),
    ("Forecast", {"policy": "forecast"}),
]

#: the two transition models raced by the grid, in fixed row order
REPARTITION_MODE_ORDER = ("drain", "partial")


def _repartition_modes_cells(scale: float) -> List[Cell]:
    # 8 seeds at any scale: the drain-vs-partial ET deltas are small
    # relative to single-run tardiness noise, and the acceptance property
    # pinned on this grid's baseline (partial strictly cuts preemptions at
    # equal-or-better ET for the forecast family) needs the row averaged
    # over enough days to reflect the systematic effect, not one seed's
    # tardy outlier
    iters = _iters(8, scale, floor=8)
    cells: List[Cell] = []
    for si, sname in enumerate(SCENARIO_ORDER):
        for fname, overrides in REPARTITION_MODE_FAMILIES:
            for mode in REPARTITION_MODE_ORDER:
                ov = {
                    k: dict(v) if isinstance(v, dict) else v
                    for k, v in overrides.items()
                }
                if ov.get("policy") == "forecast":
                    # the controller must price what the simulator charges
                    ov["policy_kwargs"] = {
                        "scenario": sname,
                        "repartition_mode": mode,
                    }
                for k in range(iters):
                    cells.append(
                        make_scenario_cell(
                            experiment="repartition_modes",
                            group=f"{sname}:{fname}:{mode}",
                            scheduler="EDF-SS",
                            scenario=sname,
                            seed=73_500 + 97 * si + k,
                            repartition_mode=mode,
                            **ov,
                        )
                    )
    return cells


def _repartition_modes_aggregate(
    cells: List[Cell], results: List[Dict[str, Any]]
) -> Rows:
    grouped = group_results(cells, results)
    rows: Rows = []
    for sname in SCENARIO_ORDER:
        # shared ET scale factor per scenario across every family × mode,
        # so the drain/partial columns of one row are directly comparable
        per = {g: rs for g, rs in grouped.items() if g.startswith(f"{sname}:")}
        t, a = et_table(per)
        for fname, _ in REPARTITION_MODE_FAMILIES:
            by_mode = {
                mode: per[f"{sname}:{fname}:{mode}"]
                for mode in REPARTITION_MODE_ORDER
            }
            row: Dict[str, Any] = {"scenario": sname, "family": fname, "et_a": a}
            for mode in REPARTITION_MODE_ORDER:
                rs = by_mode[mode]
                row[f"ET_{mode}"] = t[f"{sname}:{fname}:{mode}"]
                row[f"preemptions_{mode}"] = sum(r.preemptions for r in rs) / len(rs)
                row[f"repartitions_{mode}"] = sum(r.repartitions for r in rs) / len(rs)
            row["partial_cuts_preemptions"] = (
                row["preemptions_partial"] < row["preemptions_drain"]
            )
            row["partial_et_gain_pct"] = 100.0 * (
                1.0 - row["ET_partial"] / max(row["ET_drain"], 1e-12)
            )
            rows.append(row)
    return rows


# ----------------------------------------------------------------------
# serving_matrix — multi-tenant SLO serving: fleets x dispatchers x mixes.
# The serving acceptance row (fragmentation-aware beats least-loaded on
# fleet SLO attainment at equal-or-better energy) lives in this grid's
# checked-in baseline and is pinned by tests/test_serving.py.

_SERVING_FLEETS: List[Tuple[str, List[str]]] = [
    ("4xA100", ["a100-250w"] * 4),
    ("2xA100+2xA30", ["a100-250w", "a100-250w", "a30-165w", "a30-165w"]),
]
#: energy-greedy is omitted: it is SLO-oblivious by design and saturates a
#: packing target long before latency SLOs survive — the serving question
#: is geometry vs load-only routing
_SERVING_DISPATCHERS = (
    "round-robin",
    "least-loaded",
    "state-aware",
    "fragmentation-aware",
)
#: (mix, load_scale): day-average offered load tuned so the fleet runs hot
#: enough that routing quality decides SLO attainment without saturating
_SERVING_MIXES = (
    ("balanced", 2.0),
    ("small-heavy", 1.4),
    ("large-heavy", 1.2),
)


def _serving_matrix_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    cells: List[Cell] = []
    for fname, profiles in _SERVING_FLEETS:
        for mix, load in _SERVING_MIXES:
            for disp in _SERVING_DISPATCHERS:
                for k in range(iters):
                    cells.append(
                        make_fleet_cell(
                            experiment="serving_matrix",
                            group=f"{fname}:{mix}:{disp}",
                            profiles=profiles,
                            dispatcher=disp,
                            scheduler="EDF-SS",
                            scenario="multi-tenant-serving",
                            scenario_kwargs={"mix": mix, "load_scale": load},
                            seed=93_000 + k,
                            policy="static",
                            policy_kwargs={"config_id": 3},
                        )
                    )
    return cells


def _serving_matrix_aggregate(cells: List[Cell], results: List[Dict[str, Any]]) -> Rows:
    from repro.core.metrics import merge_tenant_stats, slo_attainment

    grouped = group_results(cells, results)
    rows: Rows = []
    for fname, _profiles in _SERVING_FLEETS:
        for mix, load in _SERVING_MIXES:
            # shared ET scale factor per (fleet, mix) across dispatchers
            per = {
                d: grouped[f"{fname}:{mix}:{d}"] for d in _SERVING_DISPATCHERS
            }
            t, a = et_table(per)
            for disp in _SERVING_DISPATCHERS:
                rs = per[disp]
                tenants = merge_tenant_stats(r.tenants for r in rs)
                rows.append(
                    {
                        "fleet": fname,
                        "mix": mix,
                        "load_scale": load,
                        "dispatcher": disp,
                        "slo_attainment": slo_attainment(tenants),
                        "ET": t[disp],
                        "et_a": a,
                        "tenant_attainment": {
                            name: st.attainment
                            for name, st in sorted(tenants.items())
                        },
                        "tenant_mean_latency_min": {
                            name: st.mean_latency_min
                            for name, st in sorted(tenants.items())
                        },
                        **summarize_results(rs),
                    }
                )
    return rows


# ----------------------------------------------------------------------
# smoke — a compact CI grid (subset of the Table II basket)


def _smoke_cells(scale: float) -> List[Cell]:
    iters = _iters(2, scale)
    specs = [WorkloadSpec(), WorkloadSpec(horizon_min=480.0, constant_rate=0.5)]
    cells: List[Cell] = []
    for si, spec in enumerate(specs):
        for cfg in (1, 3, 6, 12):
            for n in ALGOS:
                for k in range(iters):
                    cells.append(
                        make_cell(
                            experiment="smoke",
                            group=n,
                            scheduler=n,
                            workload=spec,
                            seed=1000 * si + 17 * cfg + k,
                            policy="static",
                            policy_kwargs={"config_id": cfg},
                        )
                    )
    return cells


GRIDS: Dict[str, GridDef] = {
    g.name: g
    for g in [
        GridDef("table2_schedulers", "Table II: ET of the four schedulers", _table2_cells, _table2_aggregate),
        GridDef("fig4_preemption", "Fig. 4: restricted vs unrestricted EDF-SS", _fig4_cells, _fig4_aggregate),
        GridDef("fig6_utilization", "Fig. 6: utilization histogram per algorithm", _fig6_cells, _fig6_aggregate),
        GridDef("fig7_fig8_arrival", "Figs. 7-8: ET per config across arrival rates", _fig7_cells, _fig7_aggregate),
        GridDef("fig9_fig10_split", "Figs. 9-10: ET per config across inference splits", _fig9_cells, _fig9_aggregate),
        GridDef("table3_repartitioning", "Table III: repartitioning models", _table3_cells, _table3_aggregate),
        GridDef("fig11_preferences", "Fig. 11: preferred configs per 4h interval", _fig11_cells, _fig11_aggregate),
        GridDef("fleet_scaling", "Fleet: N heterogeneous GPUs x dispatcher", _fleet_scaling_cells, _fleet_scaling_aggregate),
        GridDef("dispatchers", "Online (real-state) vs fluid (estimate) dispatch per dispatcher", _dispatchers_cells, _dispatchers_aggregate),
        GridDef("scenario_matrix", "Scenario library x the four schedulers", _scenario_matrix_cells, _scenario_matrix_aggregate),
        GridDef("repartition_policies", "Policy families x scenarios (incl. predictive controller)", _repartition_policies_cells, _repartition_policies_aggregate),
        GridDef("repartition_modes", "Drain vs partial reconfiguration per policy family x scenario", _repartition_modes_cells, _repartition_modes_aggregate),
        GridDef("serving_matrix", "Multi-tenant SLO serving: fleets x dispatchers x tenant mixes", _serving_matrix_cells, _serving_matrix_aggregate),
        GridDef("smoke", "CI smoke grid: Table II subset", _smoke_cells, _table2_aggregate),
    ]
}


def run_grid(
    name: str,
    *,
    scale: float = 1.0,
    workers: int = 0,
    cache: Any = True,
    resume: bool = True,
    artifacts_dir: Optional[str] = DEFAULT_ARTIFACTS_DIR,
    progress: Optional[Callable[[str], None]] = None,
) -> Tuple[Rows, SweepOutcome]:
    """Run a named grid end-to-end; returns (table rows, sweep outcome)."""
    if name not in GRIDS:
        raise KeyError(f"unknown grid {name!r}; available: {sorted(GRIDS)}")
    grid = GRIDS[name]
    cells = grid.build(scale)
    outcome = run_cells(
        name,
        cells,
        workers=workers,
        cache=cache,
        resume=resume,
        artifacts_dir=artifacts_dir,
        progress=progress,
    )
    return grid.aggregate(outcome.cells, outcome.results), outcome
