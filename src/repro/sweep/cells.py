"""Sweep cells: the unit of work of the parallel sweep engine.

A *cell* is one simulator run, described entirely by JSON-serializable data:

``{experiment, group, scheduler, policy, policy_kwargs, workload, seed,
mig_enabled, initial_config}``

* ``experiment`` names the grid (e.g. ``table2_schedulers``) and ``group``
  the aggregation bucket inside it (e.g. the algorithm name);
* ``policy`` + ``policy_kwargs`` name a registered repartitioning policy so
  cells can cross process boundaries (a :class:`RepartitionPolicy` instance
  is not picklable in general, a spec always is);
* ``workload`` is the fully-resolved :class:`WorkloadSpec` field dict;
* ``seed`` drives :func:`generate_jobs`, making the cell deterministic.

``cell_hash`` is a content hash over the cell params plus the simulator
version tag (:data:`repro.core.simulator.SIM_VERSION`); the on-disk cache
keys on it, so a semantics bump invalidates every memoized result at once.

This module deliberately imports only the numpy-based core (no jax) so
worker processes start fast; the DQN policy imports ``repro.core.rl``
lazily inside its factory.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

from repro.core.metrics import SimResult, TenantSLOStats
from repro.core.scenarios import generate_scenario, resolve_scenario_kwargs
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    REPARTITION_MODES,
    SIM_VERSION,
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    RepartitionPolicy,
    StaticPolicy,
)
from repro.core.workload import WorkloadSpec, generate_jobs

__all__ = [
    "POLICIES",
    "CellSpec",
    "canonical_json",
    "cell_hash",
    "cell_jobs",
    "cell_repartition_mode",
    "make_cell",
    "make_fleet_cell",
    "make_policy",
    "make_scenario_cell",
    "result_to_sim_result",
    "run_cell",
    "workload_to_dict",
]

Cell = Dict[str, Any]


def cell_repartition_mode(cell: Cell) -> str:
    """The transition model a cell runs under.

    Cells built since ``mig-sim-4`` carry the key explicitly; a cell without
    it predates slot placement and replays under the legacy full-drain model
    (that compatibility rule is what lets the drain path reproduce old
    baselines bit-identically).
    """
    return cell.get("repartition_mode", "drain")


def _cell_policy_kwargs(cell: Cell) -> Dict[str, Any]:
    """The cell's policy kwargs, with mode-coupled defaults resolved.

    The forecast controller's MPC lookahead must price the same transition
    physics the simulator charges, so unless the cell pins the policy's
    ``repartition_mode`` explicitly it inherits the cell's simulator mode —
    in particular, legacy (pre-mig-sim-4) forecast cells replay with drain
    pricing, exactly as they originally ran.
    """
    kwargs = dict(cell.get("policy_kwargs") or {})
    if cell.get("policy") == "forecast":
        kwargs.setdefault("repartition_mode", cell_repartition_mode(cell))
    return kwargs


# ----------------------------------------------------------------------
# policy registry (name -> factory taking the cell's policy_kwargs)

def _dqn_policy(
    params_path: str,
    initial_config: int = 2,
    decision_interval_min: Optional[float] = None,
) -> RepartitionPolicy:
    """Greedy DQN policy; ``decision_interval_min`` evaluates on the fixed
    cadence the batched trainer trains under (repro.core.rl.batched_train)."""
    from repro.core.rl import DQNConfig, DQNLearner, greedy_policy
    from repro.core.rl.env import FEATURE_DIM

    learner = DQNLearner(DQNConfig(state_dim=FEATURE_DIM))
    learner.load(params_path)
    return greedy_policy(
        learner,
        initial_config=initial_config,
        decision_interval_min=decision_interval_min,
    )


def _heuristic_policy() -> RepartitionPolicy:
    from repro.launch.cluster_sim import queue_heuristic_policy

    return queue_heuristic_policy()


def _forecast_policy(
    scenario: str = "paper-diurnal",
    train_seeds: int = 8,
    harmonics: int = 3,
    scenario_kwargs: Optional[Mapping[str, Any]] = None,
    **policy_kwargs: Any,
) -> RepartitionPolicy:
    """Predictive MPC controller, forecaster fitted on ``scenario``.

    The Fourier day-model fit is deterministic and cached per process
    (:func:`repro.forecast.fit_scenario_forecaster`), so sweep workers pay
    the training-day generation once; the policy instance itself is fresh
    per cell (it carries EWMA/dwell state).
    """
    from repro.forecast import ArrivalForecaster, ForecastPolicy, fit_scenario_forecaster

    model = fit_scenario_forecaster(
        scenario=scenario,
        train_seeds=train_seeds,
        harmonics=harmonics,
        scenario_kwargs=tuple(sorted(dict(scenario_kwargs or {}).items())),
    )
    return ForecastPolicy(ArrivalForecaster(model), **policy_kwargs)


POLICIES: Dict[str, Callable[..., RepartitionPolicy]] = {
    "static": lambda config_id=3: StaticPolicy(config_id),
    "nomig": lambda: NoMIGPolicy(),
    "daynight": lambda day_config=6, night_config=2: DayNightPolicy(
        day_config, night_config
    ),
    "heuristic": _heuristic_policy,
    "dqn": _dqn_policy,
    "forecast": _forecast_policy,
}


def make_policy(name: str, kwargs: Optional[Mapping[str, Any]] = None) -> RepartitionPolicy:
    """Fresh policy instance from the registry (instances carry run state)."""
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; registered: {sorted(POLICIES)}")
    # underscore-prefixed kwargs are hash-only annotations (e.g. the weights
    # digest), not factory arguments
    clean = {k: v for k, v in dict(kwargs or {}).items() if not k.startswith("_")}
    return POLICIES[name](**clean)


# ----------------------------------------------------------------------
# cell construction + hashing

def file_digest(path: str) -> str:
    """Content digest of an auxiliary input file ('' when absent)."""
    try:
        with open(path, "rb") as f:
            return hashlib.sha256(f.read()).hexdigest()
    except OSError:
        return ""


def workload_to_dict(spec: WorkloadSpec) -> Dict[str, Any]:
    """All WorkloadSpec fields, fully resolved (defaults included).

    Resolving defaults into the cell means the hash captures the *values* the
    simulation saw — a changed default can never alias a stale cache entry.
    """
    return dataclasses.asdict(spec)


def _base_cell(
    *,
    experiment: str,
    group: str,
    scheduler: str,
    seed: int,
    policy: str,
    policy_kwargs: Optional[Mapping[str, Any]],
    mig_enabled: bool,
    repartition_mode: str,
    backend: str = "oracle",
    backend_kwargs: Optional[Mapping[str, Any]] = None,
) -> Cell:
    """The fields every cell shares; workload/scenario keys are added on top.

    ``backend`` selects the simulation engine: ``"oracle"`` (the event-driven
    :class:`MIGSimulator`, the default) adds *no* keys — existing cell hashes
    and baselines are untouched — while ``"batched"`` stamps the cell with
    ``backend`` plus its resolved ``backend_kwargs`` (``dt_min``), so oracle
    and batched runs of the same physics never alias one cache entry.
    """
    if repartition_mode not in REPARTITION_MODES:
        raise ValueError(
            f"unknown repartition_mode {repartition_mode!r}; "
            f"valid: {REPARTITION_MODES}"
        )
    if backend not in ("oracle", "batched"):
        raise ValueError(
            f"unknown backend {backend!r}; valid: ('oracle', 'batched')"
        )
    if backend == "oracle" and backend_kwargs:
        raise ValueError("backend_kwargs only apply to the batched backend")
    policy_kwargs = dict(policy_kwargs or {})
    # Policies that load weights from disk are only content-addressable if the
    # weights themselves enter the hash: a retrained checkpoint at the same
    # path must miss the cache, not silently serve stale results.
    if "params_path" in policy_kwargs:
        policy_kwargs["_params_digest"] = file_digest(policy_kwargs["params_path"])
    cell: Cell = {
        "experiment": experiment,
        "group": group,
        "scheduler": scheduler,
        "policy": policy,
        "policy_kwargs": policy_kwargs,
        "seed": int(seed),
        "mig_enabled": bool(mig_enabled),
        # resolved explicitly into the cell (the hash must capture the mode
        # the simulator ran under); cells *without* the key are pre-mig-sim-4
        # and replay under the legacy drain model (see run_cell)
        "repartition_mode": repartition_mode,
    }
    if backend == "batched":
        # resolved like workload defaults: the hash must capture the timestep
        # the discretization ran at (jax-free import; see batched.__init__)
        from repro.core.batched import DEFAULT_DT_MIN

        kw = dict(backend_kwargs or {})
        kw["dt_min"] = float(kw.get("dt_min", DEFAULT_DT_MIN))
        cell["backend"] = "batched"
        cell["backend_kwargs"] = kw
    return cell


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One declarative description of any sweep cell — the single build path.

    Historically three keyword-sprawl constructors (``make_cell`` /
    ``make_scenario_cell`` / ``make_fleet_cell``) each assembled cell dicts
    with overlapping-but-divergent parameter lists.  ``CellSpec`` holds the
    union once, validates the combinations, and :meth:`to_cell` emits the
    dict with exactly the historical key-presence rules — so every
    pre-existing cell hash is unchanged (pinned by
    ``tests/test_sweep.py::test_cellspec_preserves_baseline_hashes``).  The
    legacy constructors survive as thin wrappers.

    Job stream: exactly one of ``workload`` (a raw :class:`WorkloadSpec`)
    or ``scenario`` (a registered scenario name; ``scenario_kwargs`` are
    resolved against its defaults into the cell).  Fleet cells
    (``fleet_profiles`` set) require a scenario stream and a dispatcher;
    ``dispatch_info`` enters the cell under the legacy ``fleet.info`` key.
    """

    experiment: str
    group: str
    scheduler: str
    seed: int
    # --- job stream (exactly one) -------------------------------------
    workload: Optional[WorkloadSpec] = None
    scenario: Optional[str] = None
    scenario_kwargs: Optional[Mapping[str, Any]] = None
    # --- policy + physics ---------------------------------------------
    policy: str = "static"
    policy_kwargs: Optional[Mapping[str, Any]] = None
    mig_enabled: bool = True
    repartition_mode: str = "partial"
    # --- execution backend --------------------------------------------
    backend: str = "oracle"
    backend_kwargs: Optional[Mapping[str, Any]] = None
    # --- fleet ----------------------------------------------------------
    fleet_profiles: Optional[Sequence[str]] = None
    dispatcher: Optional[str] = None
    dispatch_info: str = "online"

    def to_cell(self) -> Cell:
        """Build the JSON cell dict (validates field combinations)."""
        if (self.workload is None) == (self.scenario is None):
            raise ValueError(
                "CellSpec needs exactly one job stream: workload or scenario"
            )
        if self.scenario_kwargs is not None and self.scenario is None:
            raise ValueError("scenario_kwargs require a scenario stream")
        is_fleet = self.fleet_profiles is not None
        if is_fleet and not self.fleet_profiles:
            raise ValueError("fleet_profiles must name at least one device")
        if is_fleet and self.scenario is None:
            raise ValueError("fleet cells take a scenario stream, not a raw workload")
        if is_fleet and self.dispatcher is None:
            raise ValueError("fleet cells require a dispatcher")
        if not is_fleet and self.dispatcher is not None:
            raise ValueError("dispatcher only applies to fleet cells")
        if is_fleet and self.backend != "oracle":
            raise ValueError("fleet cells only run on the oracle backend")
        cell = _base_cell(
            experiment=self.experiment,
            group=self.group,
            scheduler=self.scheduler,
            seed=self.seed,
            policy=self.policy,
            policy_kwargs=self.policy_kwargs,
            mig_enabled=self.mig_enabled,
            repartition_mode=self.repartition_mode,
            backend=self.backend,
            backend_kwargs=self.backend_kwargs,
        )
        if self.workload is not None:
            cell["workload"] = workload_to_dict(self.workload)
        else:
            cell["scenario"] = {
                "name": self.scenario,
                "kwargs": resolve_scenario_kwargs(self.scenario, self.scenario_kwargs),
            }
        if is_fleet:
            cell["fleet"] = {
                "devices": [{"profile": p} for p in self.fleet_profiles],
                "dispatcher": self.dispatcher,
                "info": self.dispatch_info,
            }
        return cell


def make_cell(
    *,
    experiment: str,
    group: str,
    scheduler: str,
    workload: WorkloadSpec,
    seed: int,
    policy: str = "static",
    policy_kwargs: Optional[Mapping[str, Any]] = None,
    mig_enabled: bool = True,
    repartition_mode: str = "partial",
    backend: str = "oracle",
    backend_kwargs: Optional[Mapping[str, Any]] = None,
) -> Cell:
    """A single-GPU cell whose jobs come from a raw :class:`WorkloadSpec`.

    Thin wrapper over :class:`CellSpec` (the one build path).
    """
    return CellSpec(
        experiment=experiment,
        group=group,
        scheduler=scheduler,
        seed=seed,
        workload=workload,
        policy=policy,
        policy_kwargs=policy_kwargs,
        mig_enabled=mig_enabled,
        repartition_mode=repartition_mode,
        backend=backend,
        backend_kwargs=backend_kwargs,
    ).to_cell()


def make_scenario_cell(
    *,
    experiment: str,
    group: str,
    scheduler: str,
    scenario: str,
    seed: int,
    scenario_kwargs: Optional[Mapping[str, Any]] = None,
    policy: str = "static",
    policy_kwargs: Optional[Mapping[str, Any]] = None,
    mig_enabled: bool = True,
    repartition_mode: str = "partial",
    backend: str = "oracle",
    backend_kwargs: Optional[Mapping[str, Any]] = None,
) -> Cell:
    """A cell whose jobs come from a registered scenario, not a raw spec.

    Thin wrapper over :class:`CellSpec`; the scenario's knobs are resolved
    against its defaults into the cell — the content hash must capture the
    values the generator saw, exactly as ``workload_to_dict`` resolves
    :class:`WorkloadSpec` defaults.
    """
    return CellSpec(
        experiment=experiment,
        group=group,
        scheduler=scheduler,
        seed=seed,
        scenario=scenario,
        scenario_kwargs=scenario_kwargs,
        policy=policy,
        policy_kwargs=policy_kwargs,
        mig_enabled=mig_enabled,
        repartition_mode=repartition_mode,
        backend=backend,
        backend_kwargs=backend_kwargs,
    ).to_cell()


def make_fleet_cell(
    *,
    experiment: str,
    group: str,
    profiles: Sequence[str],
    dispatcher: str,
    scheduler: str,
    scenario: str,
    seed: int,
    scenario_kwargs: Optional[Mapping[str, Any]] = None,
    policy: str = "static",
    policy_kwargs: Optional[Mapping[str, Any]] = None,
    mig_enabled: bool = True,
    dispatch_info: str = "online",
    repartition_mode: str = "partial",
) -> Cell:
    """A fleet cell: N devices (by profile name) behind a dispatcher.

    Thin wrapper over :class:`CellSpec`; the extra ``fleet`` key routes
    :func:`run_cell` through :class:`repro.fleet.FleetSimulator`.  Every
    device runs ``scheduler`` and an independent instance of the cell's
    repartitioning policy.  ``dispatch_info`` selects what the dispatcher
    observes — ``"online"`` (real co-advanced engine state, the default) or
    ``"fluid"`` (the legacy backlog-estimate pre-split); the resolved value
    always enters the cell so the content hash captures it.
    """
    return CellSpec(
        experiment=experiment,
        group=group,
        scheduler=scheduler,
        seed=seed,
        scenario=scenario,
        scenario_kwargs=scenario_kwargs,
        policy=policy,
        policy_kwargs=policy_kwargs,
        mig_enabled=mig_enabled,
        repartition_mode=repartition_mode,
        fleet_profiles=tuple(profiles),
        dispatcher=dispatcher,
        dispatch_info=dispatch_info,
    ).to_cell()


def canonical_json(obj: Any) -> str:
    """Byte-stable JSON: sorted keys, no whitespace, repr round-trip floats."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


#: cell keys that label the grid rather than the simulation — excluded from
#: the hash so identical physics shares one cache entry across experiments.
_META_KEYS = frozenset({"experiment", "group"})


def cell_hash(cell: Cell, sim_version: str = SIM_VERSION) -> str:
    """Content hash of the cell's physics + simulator version (cache key)."""
    physics = {k: v for k, v in cell.items() if k not in _META_KEYS}
    payload = canonical_json({"cell": physics, "sim_version": sim_version})
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()


# ----------------------------------------------------------------------
# execution

def cell_jobs(cell: Cell) -> List[Any]:
    """Materialize the cell's job stream (scenario cells or raw-spec cells)."""
    if "scenario" in cell:
        sc = cell["scenario"]
        return generate_scenario(sc["name"], seed=cell["seed"], **sc.get("kwargs", {}))
    spec = WorkloadSpec(**cell["workload"])
    return generate_jobs(spec, seed=cell["seed"])


def _tenants_dict(res: SimResult) -> Dict[str, Dict[str, Any]]:
    return {
        name: {
            "jobs": st.jobs,
            "attained": st.attained,
            "latency_sum_min": st.latency_sum_min,
        }
        for name, st in sorted(res.tenants.items())
    }


def _result_dict(
    res: SimResult,
    util_histogram: Mapping[int, float],
    config_trace: Sequence[Any],
    t0: float,
) -> Dict[str, Any]:
    out = {
        "energy_wh": res.energy_wh,
        "avg_tardiness": res.avg_tardiness,
        "num_jobs": res.num_jobs,
        "total_tardiness": res.total_tardiness,
        "preemptions": res.preemptions,
        "repartitions": res.repartitions,
        "max_tardiness": res.max_tardiness,
        "deadline_misses": res.deadline_misses,
        "busy_slot_minutes": res.busy_slot_minutes,
        "extra": dict(res.extra),
        # side-channel state some figures aggregate over:
        "util_histogram": {str(k): v for k, v in util_histogram.items()},
        "config_trace": [[t, c] for t, c in config_trace],
        # lint: waive[DT002] wall telemetry; stripped before baseline compare
        "elapsed_s": time.perf_counter() - t0,
    }
    # only serving workloads emit tenant stats — batch cells keep the exact
    # historical key set, so pre-serving baselines compare byte-identically
    if res.tenants:
        out["tenants"] = _tenants_dict(res)
        out["slo_attainment"] = res.slo_attainment
    return out


def _run_fleet_cell(
    cell: Cell,
    policy_factory: Optional[Callable[[], RepartitionPolicy]] = None,
) -> Dict[str, Any]:
    # lazy import: plain single-GPU sweeps never pay for the fleet layer
    from repro.fleet import FleetDeviceSpec, FleetSimulator, FleetSpec

    f = cell["fleet"]
    spec = FleetSpec(
        devices=tuple(
            FleetDeviceSpec(
                profile=d["profile"],
                scheduler=d.get("scheduler"),
                initial_config=d.get("initial_config"),
            )
            for d in f["devices"]
        ),
        dispatcher=f["dispatcher"],
        scheduler=cell["scheduler"],
        dispatch_info=f.get("info", "online"),
        repartition_mode=cell_repartition_mode(cell),
    )
    if policy_factory is not None:
        def per_device_policy(i, prof):
            return policy_factory()
    else:
        def per_device_policy(i, prof):
            # independent instance per device: policies carry run state
            return make_policy(cell["policy"], _cell_policy_kwargs(cell))

    t0 = time.perf_counter()  # lint: waive[DT002] elapsed_s telemetry only
    jobs = cell_jobs(cell)
    fsim = FleetSimulator(spec, mig_enabled=cell["mig_enabled"])
    fres = fsim.run(jobs, policy_factory=per_device_policy)

    util: Dict[int, float] = {}
    for sim in fsim.sims:
        for k, v in sim.util_histogram.items():
            util[k] = util.get(k, 0.0) + v
    out = _result_dict(fres.aggregate, util, [], t0)
    out["dispatch_counts"] = list(fres.dispatch_counts)
    devices = []
    for d, r in zip(f["devices"], fres.per_device, strict=True):
        entry = {
            "profile": d["profile"],
            "num_jobs": r.num_jobs,
            "energy_wh": r.energy_wh,
            "avg_tardiness": r.avg_tardiness,
            "repartitions": r.repartitions,
        }
        if r.tenants:  # serving cells: per-device SLO breakdown
            entry["tenants"] = _tenants_dict(r)
            entry["slo_attainment"] = r.slo_attainment
        devices.append(entry)
    out["devices"] = devices
    return out


def run_cell(
    cell: Cell,
    policy_factory: Optional[Callable[[], RepartitionPolicy]] = None,
) -> Dict[str, Any]:
    """Execute one cell; returns a JSON-serializable result dict.

    ``policy_factory`` overrides the registry lookup for in-process runs with
    unpicklable ad-hoc policies (e.g. a live DQN agent mid-training); such
    cells bypass the cache at the runner layer.  Cells with a ``fleet`` key
    run through :class:`repro.fleet.FleetSimulator` and report the fleet
    aggregate in the standard result fields.  Cells with ``backend ==
    "batched"`` run through :mod:`repro.sweep.batched` (a one-cell batch
    here; :func:`repro.sweep.runner.run_cells` groups them for real
    vectorization).
    """
    if cell.get("backend") == "batched":
        if policy_factory is not None:
            raise ValueError(
                "ad-hoc policy_factory cells cannot run on the batched "
                "backend (policies must compile; see repro.core.batched)"
            )
        from repro.sweep.batched import run_batched_cells

        return run_batched_cells([cell])[0]
    if "fleet" in cell:
        return _run_fleet_cell(cell, policy_factory)
    jobs = cell_jobs(cell)
    if policy_factory is not None:
        policy = policy_factory()
    else:
        policy = make_policy(cell["policy"], _cell_policy_kwargs(cell))
    sim = MIGSimulator(
        make_scheduler(cell["scheduler"]),
        mig_enabled=cell["mig_enabled"],
        repartition_mode=cell_repartition_mode(cell),
    )
    t0 = time.perf_counter()  # lint: waive[DT002] elapsed_s telemetry only
    res = sim.run(jobs, policy=policy)
    return _result_dict(res, sim.util_histogram, sim.config_trace, t0)


_RESULT_FIELDS = (
    "energy_wh",
    "avg_tardiness",
    "num_jobs",
    "total_tardiness",
    "preemptions",
    "repartitions",
    "max_tardiness",
    "deadline_misses",
    "busy_slot_minutes",
)


def result_to_sim_result(result: Mapping[str, Any]) -> SimResult:
    """Reconstruct the :class:`SimResult` a cell's simulator run returned.

    ``tenants`` is optional: pre-serving results (and every batch cell)
    simply lack the key and round-trip with an empty mapping.
    """
    tenants = {
        name: TenantSLOStats(**st)
        for name, st in dict(result.get("tenants") or {}).items()
    }
    return SimResult(
        **{k: result[k] for k in _RESULT_FIELDS},
        extra=dict(result["extra"]),
        tenants=tenants,
    )


def group_results(
    cells: Sequence[Cell], results: Sequence[Mapping[str, Any]]
) -> Dict[str, List[SimResult]]:
    """Bucket per-cell results by ``cell['group']``, preserving cell order.

    Order preservation matters: float summation is order-sensitive, and the
    legacy serial benchmarks accumulated results in grid order — grouping in
    the same order keeps aggregate numbers bit-identical to the serial path.
    """
    out: Dict[str, List[SimResult]] = {}
    for cell, result in zip(cells, results, strict=True):
        out.setdefault(cell["group"], []).append(result_to_sim_result(result))
    return out
