"""The parallel sweep runner: cache lookup, process fan-out, JSONL artifacts.

Execution model
---------------
* Every cell gets a content hash; memoized results are served from the
  :class:`SweepCache` (the ``--resume`` path — an interrupted sweep re-runs
  only missing cells because each result is persisted as it arrives).
* Misses run through ``run_cell`` — inline for ``workers <= 1``, else fanned
  out over a ``ProcessPoolExecutor``.  Determinism does not depend on the
  worker count: a cell's seed travels inside the cell, and results are
  re-ordered back into grid order before aggregation/serialization.
* The artifact is a byte-stable JSONL file under ``artifacts/sweeps/`` (one
  ``{hash, cell, result}`` line per cell, canonical JSON) — CI diffs it
  against a checked-in baseline.  Wall-clock/cache metadata goes to a
  sidecar ``.meta.json`` so it never perturbs the diff.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import multiprocessing
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.sweep.cache import DEFAULT_CACHE_DIR, SweepCache
from repro.sweep.cells import Cell, canonical_json, cell_hash, run_cell

__all__ = ["SweepOutcome", "run_cells", "DEFAULT_ARTIFACTS_DIR"]

DEFAULT_ARTIFACTS_DIR = os.path.join("artifacts", "sweeps")


@dataclasses.dataclass
class SweepOutcome:
    """Everything one ``run_cells`` call produced, in grid order."""

    name: str
    cells: List[Cell]
    hashes: List[str]
    results: List[Dict[str, Any]]  # grid order, parallel to ``cells``
    cached_count: int
    computed_count: int
    wall_s: float
    jsonl_path: Optional[str]

    @property
    def total(self) -> int:
        """Total cell count (cached + computed)."""
        return len(self.cells)


def _strip_volatile(result: Dict[str, Any]) -> Dict[str, Any]:
    """Drop wall-clock noise so artifacts/cache entries diff cleanly."""
    return {k: v for k, v in result.items() if k != "elapsed_s"}


def run_cells(
    name: str,
    cells: Sequence[Cell],
    *,
    workers: int = 0,
    cache: Union[SweepCache, str, None, bool] = True,
    resume: bool = True,
    artifacts_dir: Optional[str] = DEFAULT_ARTIFACTS_DIR,
    policy_factory: Optional[Callable[[], Any]] = None,
    progress: Optional[Callable[[str], None]] = None,
) -> SweepOutcome:
    """Run a grid of cells; returns results in grid order.

    ``cache``: True -> default dir, a str -> that dir, a SweepCache -> as-is,
    False/None -> no memoization.  ``resume=False`` ignores existing entries
    (recompute everything) but still persists fresh results.

    ``policy_factory`` forces inline execution with an ad-hoc policy and
    bypasses the cache entirely: an arbitrary closure is neither picklable
    nor content-addressable.
    """
    if isinstance(cache, bool):
        cache_obj = SweepCache(DEFAULT_CACHE_DIR) if cache else None
    elif isinstance(cache, str):
        cache_obj = SweepCache(cache)
    else:
        cache_obj = cache
    if policy_factory is not None:
        cache_obj = None

    if cache_obj is not None and resume:
        # refuse to resume over a cache written under a different SIM_VERSION
        # (raises StaleCacheError) — silent semantics-mixing is the one
        # failure mode a content-addressed cache cannot flag per-cell
        cache_obj.check_version()

    t0 = time.perf_counter()  # lint: waive[DT002] meta.json wall_s telemetry only
    cells = list(cells)
    hashes = [cell_hash(c) for c in cells]
    results: List[Optional[Dict[str, Any]]] = [None] * len(cells)

    cached_count = 0
    pending: List[int] = []
    for i, h in enumerate(hashes):
        hit = cache_obj.get(h) if (cache_obj is not None and resume) else None
        if hit is not None:
            results[i] = hit
            cached_count += 1
        else:
            pending.append(i)

    if progress and cells:
        progress(
            f"[{name}] {len(cells)} cells: {cached_count} cached, "
            f"{len(pending)} to compute (workers={max(workers, 1)})"
        )
    computed_count = len(pending)

    # batched-backend cells never enter the worker pool: grouping seeds into
    # one vectorized simulate_batch call *is* their parallelism, and keeping
    # jax in the parent avoids paying its import in every spawned worker.
    # (with an ad-hoc policy_factory they fall through to run_cell, which
    # rejects the combination with a useful error.)
    batched = [
        i for i in pending if cells[i].get("backend") == "batched"
    ] if policy_factory is None else []
    if batched:
        from repro.sweep.batched import run_batched_cells

        if progress:
            progress(f"[{name}] {len(batched)} batched cells run in-process")
        for i, raw in zip(batched, run_batched_cells([cells[i] for i in batched]), strict=True):
            out = _strip_volatile(raw)
            results[i] = out
            if cache_obj is not None:
                cache_obj.put(hashes[i], cells[i], out)
        done_batched = set(batched)
        pending = [i for i in pending if i not in done_batched]

    if pending:
        if policy_factory is not None or workers <= 1:
            for i in pending:
                out = _strip_volatile(run_cell(cells[i], policy_factory=policy_factory))
                results[i] = out
                if cache_obj is not None:
                    cache_obj.put(hashes[i], cells[i], out)
        else:
            max_workers = min(workers, os.cpu_count() or workers, len(pending))
            # spawn, not fork: the parent frequently has jax (and its thread
            # pools) loaded — forking a multithreaded process can deadlock.
            # Workers only import the numpy-based core, so spawn stays cheap.
            ctx = multiprocessing.get_context("spawn")
            with concurrent.futures.ProcessPoolExecutor(
                max_workers=max_workers, mp_context=ctx
            ) as ex:
                futs = {ex.submit(run_cell, cells[i]): i for i in pending}
                done = 0
                for fut in concurrent.futures.as_completed(futs):
                    i = futs[fut]
                    try:
                        out = _strip_volatile(fut.result())
                    except Exception as e:
                        raise RuntimeError(
                            f"sweep cell failed: {canonical_json(cells[i])}"
                        ) from e
                    results[i] = out
                    if cache_obj is not None:
                        cache_obj.put(hashes[i], cells[i], out)
                    done += 1
                    if progress and done % 50 == 0:
                        progress(f"[{name}] {done}/{len(pending)} computed")

    jsonl_path = None
    if artifacts_dir is not None:
        os.makedirs(artifacts_dir, exist_ok=True)
        jsonl_path = os.path.join(artifacts_dir, f"{name}.jsonl")
        tmp = jsonl_path + ".tmp"
        with open(tmp, "w") as f:
            for h, cell, result in zip(hashes, cells, results, strict=True):
                f.write(canonical_json({"hash": h, "cell": cell, "result": result}))
                f.write("\n")
        os.replace(tmp, jsonl_path)
        wall_s = time.perf_counter() - t0  # lint: waive[DT002] meta.json telemetry only
        with open(os.path.join(artifacts_dir, f"{name}.meta.json"), "w") as f:
            json.dump(
                {
                    "name": name,
                    "cells": len(cells),
                    "cached": cached_count,
                    "computed": computed_count,
                    "workers": workers,
                    "wall_s": wall_s,
                },
                f,
                indent=2,
            )
    else:
        wall_s = time.perf_counter() - t0  # lint: waive[DT002] meta.json telemetry only

    return SweepOutcome(
        name=name,
        cells=cells,
        hashes=hashes,
        results=results,  # type: ignore[arg-type]
        cached_count=cached_count,
        computed_count=computed_count,
        wall_s=wall_s,
        jsonl_path=jsonl_path,
    )
