"""Sweep-layer glue for the batched backend: group, vectorize, split.

The runner hands this module every pending cell tagged ``backend ==
"batched"``.  Cells are grouped by their physics-minus-seed fingerprint
(same scenario/policy/mode/backend knobs, different seeds) and each group
runs as ONE :func:`repro.core.batched.simulate_batch` call — the whole
point of the backend: seeds become rows of a ``(B, J)`` array instead of
independent processes.

The per-cell result dicts come back in the oracle vocabulary
(:func:`repro.sweep.cells._result_dict` fields) so caching, artifacts and
aggregation are backend-agnostic; ``config_trace`` is empty for batched
cells (documented in docs/BATCHED_SIM.md §5) and ``elapsed_s`` divides the
group's wall time evenly across its cells.

Unsupported combinations fail loudly *before* any simulation runs:
schedulers other than EDF-FS, fleet cells, and policies that need
per-event simulator state all raise :class:`UnsupportedPolicyError` with a
pointer back to the oracle backend.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence

from repro.sweep.cells import (
    Cell,
    canonical_json,
    cell_jobs,
    cell_repartition_mode,
    make_policy,
)

__all__ = [
    "batched_group_key",
    "is_batched_cell",
    "run_batched_cells",
    "validate_batched_cell",
]


def is_batched_cell(cell: Cell) -> bool:
    """True when the cell asks for the batched backend."""
    return cell.get("backend") == "batched"


def batched_group_key(cell: Cell) -> str:
    """Fingerprint of everything but the seed (and grid labels).

    Cells sharing a key are physically identical rollouts under different
    seeds, so they can advance lock-step in one ``simulate_batch`` call.
    """
    skip = ("experiment", "group", "seed")
    return canonical_json({k: v for k, v in cell.items() if k not in skip})


def validate_batched_cell(cell: Cell) -> None:
    """Reject cells the batched backend cannot run, with guidance.

    Raises :class:`repro.core.batched.UnsupportedPolicyError` so callers can
    distinguish "wrong backend for this cell" from genuine failures.
    """
    from repro.core.batched import UnsupportedPolicyError

    if "fleet" in cell:
        raise UnsupportedPolicyError(
            "fleet cells need the co-advanced dispatcher loop; "
            "run them on the oracle backend"
        )
    if cell.get("scheduler") != "EDF-FS":
        raise UnsupportedPolicyError(
            f"batched backend implements only EDF-FS "
            f"(got {cell.get('scheduler')!r}); run this cell on the oracle"
        )
    if (cell.get("scenario") or {}).get("name") == "multi-tenant-serving":
        raise UnsupportedPolicyError(
            "serving cells carry per-job tenant/SLO metadata the batched "
            "state arrays do not represent; run them on the oracle backend"
        )


def _resolve_dt(cell: Cell) -> float:
    from repro.core.batched import DEFAULT_DT_MIN

    return float((cell.get("backend_kwargs") or {}).get("dt_min", DEFAULT_DT_MIN))


def run_batched_cells(cells: Sequence[Cell]) -> List[Dict[str, Any]]:
    """Run batched cells grouped by physics; results in input order.

    Each group compiles its policy once (:func:`compile_policy` on a fresh
    registry instance, so batched cells honour exactly the defaults oracle
    cells get) and runs one vectorized rollout over its seeds.
    """
    from repro.core.batched import (
        BatchedJobs,
        build_tables,
        compile_policy,
        simulate_batch,
    )

    cells = list(cells)
    groups: Dict[str, List[int]] = {}
    for i, cell in enumerate(cells):
        validate_batched_cell(cell)
        groups.setdefault(batched_group_key(cell), []).append(i)

    tables = build_tables()
    results: List[Dict[str, Any]] = [{} for _ in cells]
    for idx in groups.values():
        # lint: waive[DT002] elapsed_s telemetry; stripped before baseline compare
        t0 = time.perf_counter()
        head = cells[idx[0]]
        job_lists = [cell_jobs(cells[i]) for i in idx]
        jobs = BatchedJobs.from_job_lists(
            job_lists, max_slots=tables.max_slots,
            mig_enabled=head["mig_enabled"],
        )
        policy = compile_policy(
            make_policy(head["policy"], head.get("policy_kwargs")),
            tables, batch=len(idx),
        )
        res = simulate_batch(
            jobs, policy, tables=tables,
            repartition_mode=cell_repartition_mode(head),
            dt_min=_resolve_dt(head),
        )
        elapsed = (time.perf_counter() - t0) / len(idx)  # lint: waive[DT002] telemetry only
        for i, out in zip(idx, res.to_result_dicts(), strict=True):
            out["elapsed_s"] = elapsed
            results[i] = out
    return results
