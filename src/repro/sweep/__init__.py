"""Process-parallel, memoized sweep engine for the paper's experiment grids.

The paper's headline results are all sweeps — scheduler x config x workload
x seed grids pushed through the event-driven :class:`MIGSimulator`.  This
package turns each of them into a declarative grid of JSON cells, fans cells
out over worker processes, memoizes finished cells in a content-addressed
on-disk cache, and writes byte-stable JSONL artifacts for CI to diff.

Quickstart::

    python -m repro.sweep --grid table2_schedulers --workers 4
    python -m repro.sweep --grid smoke --scale 0.1 --workers 2

See :mod:`repro.sweep.grids` for the registry and :mod:`repro.sweep.runner`
for execution semantics.
"""

from repro.sweep.cache import StaleCacheError, SweepCache
from repro.sweep.cells import (
    cell_hash,
    cell_jobs,
    group_results,
    make_cell,
    make_fleet_cell,
    make_policy,
    make_scenario_cell,
    result_to_sim_result,
    run_cell,
)
from repro.sweep.grids import (
    GRIDS,
    POLICY_FAMILIES,
    GridDef,
    run_grid,
    summarize_results,
)
from repro.sweep.runner import SweepOutcome, run_cells

__all__ = [
    "GRIDS",
    "POLICY_FAMILIES",
    "GridDef",
    "StaleCacheError",
    "SweepCache",
    "SweepOutcome",
    "cell_hash",
    "cell_jobs",
    "group_results",
    "make_cell",
    "make_fleet_cell",
    "make_policy",
    "make_scenario_cell",
    "result_to_sim_result",
    "run_cell",
    "run_cells",
    "run_grid",
    "summarize_results",
]
