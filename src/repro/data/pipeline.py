"""Synthetic-but-deterministic LM data pipeline.

Real deployments stream tokenized corpora; this container has no corpus, so
the pipeline synthesizes a Zipf-distributed, seeded token stream that is:

* deterministic in (seed, step, global position) — restart-safe: resuming
  from a checkpoint at step k regenerates exactly the batches k, k+1, ...,
* host-sharded — each process materializes only its addressable slice and
  the global device array is assembled per shard,
* shaped by the arch config (modality stubs included: whisper frame
  embeddings, VLM patch embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig

__all__ = ["SyntheticLM", "make_batch_specs"]


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish token draw (realistic softmax/embedding access patterns)."""
    u = rng.random(size=shape)
    ranks = np.floor(np.exp(u * np.log(vocab))).astype(np.int64)
    return (ranks % vocab).astype(np.int32)


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """Full global batch (single-host container). Deterministic in step."""
        return self.shard_for_step(step, 0, 1)

    def shard_for_step(
        self, step: int, host_index: int, host_count: int
    ) -> Dict[str, np.ndarray]:
        assert self.global_batch % host_count == 0
        b = self.global_batch // host_count
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_index
        )
        cfg = self.cfg
        text_len = self.seq_len - (cfg.vision_tokens if cfg.vision_tokens else 0)
        tokens = _zipf_tokens(rng, (b, text_len + 1), cfg.vocab_size)
        out: Dict[str, np.ndarray] = {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }
        if cfg.encoder is not None:
            out["enc_frames"] = rng.standard_normal(
                (b, cfg.encoder.n_frames, cfg.d_model), dtype=np.float32
            )
        if cfg.vision_tokens:
            out["img_embeds"] = rng.standard_normal(
                (b, cfg.vision_tokens, cfg.d_model), dtype=np.float32
            )
        return out


def make_batch_specs(
    cfg: ArchConfig, global_batch: int, seq_len: int, for_training: bool = True
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run §2)."""
    text_len = seq_len - (cfg.vision_tokens if cfg.vision_tokens else 0)
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32),
    }
    if for_training:
        specs["labels"] = jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32)
    if cfg.encoder is not None:
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_tokens:
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs
