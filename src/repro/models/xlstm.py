"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows the xLSTM paper's residual block structure:

* mLSTM block: LN -> up-proj (2x expansion, gated z branch) -> causal conv4 ->
  q/k from conv path, v from pre-conv path -> per-head scalar i/f gates ->
  chunkwise mLSTM (repro.kernels) -> z-gate -> down-proj.
* sLSTM block: LN -> causal conv4 -> 4-head sLSTM with exponential gating and
  block-diagonal recurrence -> group norm -> down-proj; followed by a 4/3
  GeLU FFN sub-block.

For decode, both carry O(1) recurrent state (matrix / scalar memories), which
is what makes xlstm-350m a ``long_500k``-capable architecture.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ArchConfig
from repro.models.layers import Params, apply_norm, dense, dense_init, norm_init

__all__ = [
    "mlstm_block_init",
    "mlstm_block_apply",
    "mlstm_block_decode",
    "mlstm_state_init",
    "slstm_block_init",
    "slstm_block_apply",
    "slstm_block_decode",
    "slstm_state_init",
]

EXPAND = 2  # mLSTM projection expansion factor
CONV = 4  # causal conv width


def _conv_init(key, width, channels, dtype):
    scale = 1.0 / math.sqrt(width)
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, (width, channels), jnp.float32)
        * scale
    ).astype(dtype)


def _causal_conv(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,T,C), w (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W=4: unrolled taps
        out = out + xp[:, i : i + x.shape[1], :] * w[W - 1 - i][None, None, :]
    return out


# ------------------------------ mLSTM block --------------------------------


def mlstm_block_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    d = cfg.d_model
    di = EXPAND * d
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "w_up": dense_init(ks[0], d, 2 * di, dtype),
        "conv": _conv_init(ks[1], CONV, di, dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_i": dense_init(ks[5], di, H, jnp.float32),
        "w_f": dense_init(ks[6], di, H, jnp.float32),
        "w_down": dense_init(ks[7], di, d, dtype),
        "out_norm": norm_init(di, "rmsnorm", dtype),
    }


def _mlstm_qkvif(p: Params, cfg: ArchConfig, x: jnp.ndarray):
    B, T, _ = x.shape
    di = EXPAND * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    h = apply_norm(p["norm"], x, cfg.norm)
    up = dense(p["w_up"], h)
    xin, z = jnp.split(up, 2, axis=-1)  # (B,T,di) each
    xc = jax.nn.silu(_causal_conv(p["conv"], xin))
    q = dense(p["wq"], xc).reshape(B, T, H, dh)
    k = dense(p["wk"], xc).reshape(B, T, H, dh)
    v = dense(p["wv"], xin).reshape(B, T, H, dh)
    ig = (xc.astype(jnp.float32) @ p["w_i"]).astype(jnp.float32)  # (B,T,H)
    fg = (xc.astype(jnp.float32) @ p["w_f"]).astype(jnp.float32)
    return q, k, v, ig, fg, z, xin


def mlstm_block_apply(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, *, impl: str = "auto"
) -> jnp.ndarray:
    B, T, _ = x.shape
    di = EXPAND * cfg.d_model
    q, k, v, ig, fg, z, _ = _mlstm_qkvif(p, cfg, x)
    h = ops.mlstm(q, k, v, ig, fg, impl=impl)  # (B,T,H,dh)
    h = h.reshape(B, T, di)
    h = apply_norm(p["out_norm"], h, "rmsnorm")
    h = h * jax.nn.silu(z)
    return x + dense(p["w_down"], h)


def mlstm_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    di = EXPAND * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, CONV - 1, di), dtype),
    }


def mlstm_block_decode(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrent step (B, 1, d)."""
    B = x.shape[0]
    di = EXPAND * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    h = apply_norm(p["norm"], x, cfg.norm)
    up = dense(p["w_up"], h)
    xin, z = jnp.split(up, 2, axis=-1)  # (B,1,di)
    # conv over the carried window; taps flipped: window[-1] is the CURRENT
    # token and must pair with w[0] (matches _causal_conv's orientation)
    window = jnp.concatenate([state["conv"], xin.astype(state["conv"].dtype)], axis=1)
    w = jnp.flip(p["conv"], axis=0)
    xc = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    xc = jax.nn.silu(xc)[:, None, :].astype(x.dtype)  # (B,1,di)
    q = dense(p["wq"], xc).reshape(B, H, dh)
    k = dense(p["wk"], xc).reshape(B, H, dh) / math.sqrt(dh)
    v = dense(p["wv"], xin).reshape(B, H, dh)
    ig = (xc.reshape(B, di).astype(jnp.float32) @ p["w_i"])  # (B,H)
    fg = (xc.reshape(B, di).astype(jnp.float32) @ p["w_f"])
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + state["m"], ig)
    i_w = jnp.exp(ig - m_new)[..., None]  # (B,H,1)
    decay = jnp.exp(lf + state["m"] - m_new)[..., None]
    C = decay[..., None] * state["C"] + (i_w[..., None] * k[..., :, None] * v[..., None, :])
    n = decay * state["n"] + i_w[..., 0][..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    hout = (num / den).reshape(B, 1, di).astype(x.dtype)
    hout = apply_norm(p["out_norm"], hout, "rmsnorm")
    hout = hout * jax.nn.silu(z)
    new_state = {
        "C": C,
        "n": n,
        "m": m_new,
        "conv": window[:, 1:, :],
    }
    return x + dense(p["w_down"], hout), new_state


# ------------------------------ sLSTM block --------------------------------


def slstm_block_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    f = int(d * 4 / 3)
    ks = jax.random.split(key, 10)
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "conv": _conv_init(ks[0], CONV, d, dtype),
        "w_i": dense_init(ks[1], d, d, dtype),
        "w_f": dense_init(ks[2], d, d, dtype),
        "w_z": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype),
        "r_i": _stack_r(ks[5], H, dh, dtype),
        "r_f": _stack_r(ks[6], H, dh, dtype),
        "r_z": _stack_r(ks[7], H, dh, dtype),
        "r_o": _stack_r(ks[8], H, dh, dtype),
        "gn": norm_init(d, "rmsnorm", dtype),
        "ffn_norm": norm_init(d, cfg.norm, dtype),
        "w_ffn_up": dense_init(ks[9], d, f, dtype),
        "w_ffn_down": dense_init(jax.random.fold_in(ks[9], 1), f, d, dtype),
    }


def _stack_r(key, H, dh, dtype):
    scale = 1.0 / math.sqrt(dh)
    x = jax.random.truncated_normal(key, -2.0, 2.0, (H, dh, dh), jnp.float32) * scale
    return x.astype(dtype)


def slstm_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, CONV - 1, d), dtype),
    }


def _slstm_step(p: Params, cfg: ArchConfig, carry, gates):
    """One sLSTM time step. gates: precomputed input projections (B, 4d)."""
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    c, n, m, h_prev = carry
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)  # (B,d) each
    hb = h_prev.reshape(-1, H, dh)

    def rec(r):
        return jnp.einsum("bhd,hde->bhe", hb, r.astype(jnp.float32)).reshape(-1, d)

    gi = gi + rec(p["r_i"])
    gf = gf + rec(p["r_f"])
    gz = gz + rec(p["r_z"])
    go = go + rec(p["r_o"])
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    i_w = jnp.exp(gi - m_new)
    f_w = jnp.exp(lf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f_w * c + i_w * z
    n_new = jnp.maximum(f_w * n + i_w, 1e-6)
    h_new = o * (c_new / n_new)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, T, d = x.shape
    h = apply_norm(p["norm"], x, cfg.norm)
    xc = jax.nn.silu(_causal_conv(p["conv"], h))
    # input projections for all gates, all timesteps at once (MXU work)
    gates = jnp.concatenate(
        [
            dense(p["w_i"], xc),
            dense(p["w_f"], xc),
            dense(p["w_z"], h),
            dense(p["w_o"], h),
        ],
        axis=-1,
    ).astype(jnp.float32)  # (B,T,4d)
    from repro.distributed.hints import hint

    carry = (
        hint(jnp.zeros((B, d), jnp.float32), "dp"),
        hint(jnp.ones((B, d), jnp.float32), "dp"),
        hint(jnp.zeros((B, d), jnp.float32), "dp"),
        hint(jnp.zeros((B, d), jnp.float32), "dp"),
    )
    (c, n, m, hT), hs = jax.lax.scan(
        lambda cr, g: _slstm_step(p, cfg, cr, g), carry, jnp.moveaxis(gates, 1, 0)
    )
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,T,d)
    out = x + _slstm_out(p, cfg, hs)
    # FFN sub-block
    hf = apply_norm(p["ffn_norm"], out, cfg.norm)
    return out + dense(p["w_ffn_down"], jax.nn.gelu(dense(p["w_ffn_up"], hf)))


def _slstm_out(p: Params, cfg: ArchConfig, hs: jnp.ndarray) -> jnp.ndarray:
    return apply_norm(p["gn"], hs, "rmsnorm")


def slstm_block_decode(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = x.shape[0]
    d = cfg.d_model
    h = apply_norm(p["norm"], x, cfg.norm)  # (B,1,d)
    window = jnp.concatenate([state["conv"], h.astype(state["conv"].dtype)], axis=1)
    w = jnp.flip(p["conv"], axis=0)  # window[-1]=current pairs with w[0]
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    )[:, None, :].astype(x.dtype)
    gates = jnp.concatenate(
        [
            dense(p["w_i"], xc),
            dense(p["w_f"], xc),
            dense(p["w_z"], h),
            dense(p["w_o"], h),
        ],
        axis=-1,
    ).astype(jnp.float32)[:, 0]  # (B,4d)
    carry = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, hnew), _ = _slstm_step(p, cfg, carry, gates)
    hs = _slstm_out(p, cfg, hnew[:, None, :].astype(x.dtype))
    out = x + hs
    hf = apply_norm(p["ffn_norm"], out, cfg.norm)
    out = out + dense(p["w_ffn_down"], jax.nn.gelu(dense(p["w_ffn_up"], hf)))
    return out, {"c": c, "n": n, "m": m, "h": hnew, "conv": window[:, 1:, :]}
