"""Model substrate: the LM-family architectures served/trained by the
cluster layer (the paper's "jobs"), implemented as pure-functional JAX.

* :mod:`repro.models.config`      — ArchConfig covering all 10 assigned archs
* :mod:`repro.models.layers`      — norms, rope, MLPs, embeddings
* :mod:`repro.models.attention`   — GQA full/sliding-window/cross attention
* :mod:`repro.models.moe`         — top-k router + capacity-truncated dispatch
* :mod:`repro.models.xlstm`       — sLSTM + mLSTM blocks
* :mod:`repro.models.mamba`       — Mamba selective-SSM (Jamba hybrid)
* :mod:`repro.models.transformer` — the block-pattern model builder
"""

from repro.models.config import ArchConfig, MoEConfig, MambaConfig, EncoderConfig
from repro.models.transformer import (
    init_params,
    forward,
    loss_fn,
    init_cache,
    decode_step,
    abstract_params,
)

__all__ = [
    "ArchConfig",
    "MoEConfig",
    "MambaConfig",
    "EncoderConfig",
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "decode_step",
    "abstract_params",
]
