"""Block-pattern model builder: one code path for all 10 assigned archs.

The layer stack is grouped into the architecture's repeating *pattern unit*
(dense: [attn]; gemma3: 5x[local]+[attn]; jamba: mamba/attn/MoE interleave;
xlstm: 7x[mLSTM]+[sLSTM]) and scanned over repeats with stacked parameters —
compile time stays flat in depth, and the roofline extractor lowers a single
unit (``apply_unit``) to recover per-layer costs that `lax.scan` hides from
``cost_analysis`` (trip counts are known statically).

Forward paths:
* :func:`forward`      — full-sequence (training / prefill) -> logits, aux
* :func:`loss_fn`      — next-token cross-entropy, sequence-chunked softmax
* :func:`decode_step`  — one token against carried state (KV cache / SSM state)
* :func:`init_cache`   — per-layer decode state, stacked like the params
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import (
    attn_apply,
    attn_decode,
    attn_init,
    cross_attn_apply,
    cross_attn_init,
    init_kv_cache,
)
from repro.distributed.hints import hint
from repro.models.config import ArchConfig, LayerKind
from repro.models.layers import (
    Params,
    apply_norm,
    dense,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
)

__all__ = [
    "init_params",
    "abstract_params",
    "forward",
    "loss_fn",
    "init_cache",
    "decode_step",
    "apply_unit",
]


def _dtype(cfg: ArchConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


# ============================ initialization ===============================


def _layer_init(key: jax.Array, cfg: ArchConfig, kind: str, is_moe: bool) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {}
    if kind in (LayerKind.ATTN, LayerKind.LOCAL_ATTN):
        p["norm1"] = norm_init(cfg.d_model, cfg.norm, dt)
        p["attn"] = attn_init(ks[0], cfg, dt)
        if cfg.encoder is not None:
            p["cross_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
            p["cross"] = cross_attn_init(ks[1], cfg, dt)
    elif kind == LayerKind.MAMBA:
        p["mixer"] = mamba_mod.mamba_init(ks[0], cfg, dt)
    elif kind == LayerKind.MLSTM:
        p["block"] = xlstm_mod.mlstm_block_init(ks[0], cfg, dt)
        return p  # self-contained (no MLP)
    elif kind == LayerKind.SLSTM:
        p["block"] = xlstm_mod.slstm_block_init(ks[0], cfg, dt)
        return p
    else:  # pragma: no cover
        raise ValueError(kind)
    # MLP / MoE sub-layer
    if is_moe:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
        p["moe"] = moe_mod.moe_init(ks[2], cfg, dt)
    elif cfg.d_ff > 0:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    return p


def _encoder_init(key: jax.Array, cfg: ArchConfig) -> Params:
    """Whisper-style encoder: full bidirectional attention layers."""
    assert cfg.encoder is not None
    dt = _dtype(cfg)
    if cfg.encoder.n_layers == 0:  # cost-mode mini0
        return {
            "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
            "pos": embed_init(jax.random.fold_in(key, 999), cfg.encoder.n_frames, cfg.d_model, dt)
            * 0.02,
        }
    enc_layers = []
    for i in range(cfg.encoder.n_layers):
        ks = jax.random.split(jax.random.fold_in(key, i), 3)
        enc_layers.append(
            {
                "norm1": norm_init(cfg.d_model, cfg.norm, dt),
                "attn": attn_init(ks[0], cfg, dt),
                "norm2": norm_init(cfg.d_model, cfg.norm, dt),
                "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt),
            }
        )
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc_layers)
    return {
        "layers": stacked,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
        "pos": embed_init(jax.random.fold_in(key, 999), cfg.encoder.n_frames, cfg.d_model, dt)
        * 0.02,
    }


def init_params(cfg: ArchConfig, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    dt = _dtype(cfg)
    unit = cfg.pattern_unit()
    repeats = cfg.num_pattern_repeats

    params: Params = {
        "embed": embed_init(jax.random.fold_in(key, 1), cfg.vocab_size, cfg.d_model, dt),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(
            jax.random.fold_in(key, 2), cfg.vocab_size, cfg.d_model, dt
        )
    blocks: Params = {}
    for u, (kind, is_moe) in enumerate(unit):
        per_repeat = [
            _layer_init(
                jax.random.fold_in(key, 1000 + u * 1001 + r), cfg, kind, is_moe
            )
            for r in range(repeats)
        ]
        blocks[f"u{u}"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_repeat)
    params["blocks"] = blocks
    if cfg.encoder is not None:
        params["encoder"] = _encoder_init(jax.random.fold_in(key, 3), cfg)
    return params


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct tree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, 0))


# ============================ forward (full seq) ============================


def apply_unit(
    cfg: ArchConfig,
    unit_params: Tuple[Params, ...],  # params per unit position (unstacked)
    x: jnp.ndarray,
    *,
    enc_out: Optional[jnp.ndarray] = None,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One pattern unit of layers. Returns (x, aux_loss)."""
    unit = cfg.pattern_unit()
    aux = jnp.zeros((), jnp.float32)
    # §Perf hillclimb 1 (confirmed): pin the residual stream to
    # batch-sharded/replicated-d — without this GSPMD's propagation inserts
    # "involuntary full rematerialization" all-gathers between blocks.
    # Iteration 2 (sequence-parallel residuals, hint "dp","model",None) was
    # REFUTED: +4.7x collective bytes — GSPMD cannot fuse the pre-matmul
    # sequence all-gathers, so SP needs explicit shard_map collective-matmul
    # overlap (EXPERIMENTS.md §Perf).
    x = hint(x, "dp", None, None)
    for (kind, is_moe), p in zip(unit, unit_params, strict=True):
        if kind in (LayerKind.ATTN, LayerKind.LOCAL_ATTN):
            window = cfg.sliding_window if kind == LayerKind.LOCAL_ATTN else None
            if cfg.local_global_ratio is None and cfg.sliding_window is not None:
                window = cfg.sliding_window  # uniformly windowed (mixtral)
            h = apply_norm(p["norm1"], x, cfg.norm)
            x = x + attn_apply(p["attn"], cfg, h, window=window, impl=impl)
            if enc_out is not None and "cross" in p:
                h = apply_norm(p["cross_norm"], x, cfg.norm)
                x = x + cross_attn_apply(p["cross"], cfg, h, enc_out, impl=impl)
        elif kind == LayerKind.MAMBA:
            x = mamba_mod.mamba_apply(p["mixer"], cfg, x, impl=impl)
        elif kind == LayerKind.MLSTM:
            x = xlstm_mod.mlstm_block_apply(p["block"], cfg, x, impl=impl)
            continue
        elif kind == LayerKind.SLSTM:
            x = xlstm_mod.slstm_block_apply(p["block"], cfg, x)
            continue
        if is_moe:
            h = apply_norm(p["norm2"], x, cfg.norm)
            mo, a = moe_mod.moe_apply(p["moe"], cfg, h)
            x = x + mo
            aux = aux + a
        elif cfg.d_ff > 0 and "mlp" in p:
            h = apply_norm(p["norm2"], x, cfg.norm)
            x = x + mlp_apply(p["mlp"], h, cfg.activation)
        x = hint(x, "dp", None, None)
    return x, aux


def _run_blocks(
    cfg: ArchConfig,
    blocks: Params,
    x: jnp.ndarray,
    enc_out: Optional[jnp.ndarray],
    impl: str,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    unit_len = len(cfg.pattern_unit())
    if unit_len == 0:  # cost-mode mini0
        return x, jnp.zeros((), jnp.float32)
    stacked = tuple(blocks[f"u{u}"] for u in range(unit_len))

    def body(carry, unit_slice):
        h, aux = carry
        h, a = apply_unit(cfg, unit_slice, h, enc_out=enc_out, impl=impl)
        return (h, aux + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers and cfg.num_pattern_repeats > 1:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), stacked
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        for r in range(cfg.num_pattern_repeats):
            unit_slice = jax.tree_util.tree_map(lambda a_, r=r: a_[r], stacked)
            (x, aux), _ = body((x, aux), unit_slice)
    return x, aux


def _run_encoder(cfg: ArchConfig, params: Params, frames: jnp.ndarray, impl: str) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    enc = params["encoder"]
    x = frames + enc["pos"][None, : frames.shape[1], :].astype(frames.dtype)
    if "layers" not in enc:  # cost-mode mini0
        return apply_norm(enc["final_norm"], x, cfg.norm)

    def body(h, lp):
        a = apply_norm(lp["norm1"], h, cfg.norm)
        h = h + attn_apply(lp["attn"], cfg, a, causal=False, impl=impl)
        a = apply_norm(lp["norm2"], h, cfg.norm)
        h = h + mlp_apply(lp["mlp"], a, cfg.activation)
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm)


def forward(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jnp.ndarray],
    *,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V), aux losses)."""
    tokens = batch["tokens"]
    dt = _dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)

    if cfg.vision_tokens > 0 and "img_embeds" in batch:
        x = jnp.concatenate([batch["img_embeds"].astype(dt), x], axis=1)

    enc_out = None
    if cfg.encoder is not None:
        enc_out = _run_encoder(cfg, params, batch["enc_frames"].astype(dt), impl)

    x, aux = _run_blocks(cfg, params["blocks"], x, enc_out, impl)
    x = apply_norm(params["final_norm"], x, cfg.norm)

    if cfg.vision_tokens > 0 and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1] :, :]

    unembed = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum(
        "bsd,vd->bsv", x, unembed, preferred_element_type=jnp.float32
    )
    if cfg.logit_softcap is not None:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, aux


def loss_fn(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jnp.ndarray],
    *,
    impl: str = "auto",
    loss_chunk: int = 512,
) -> jnp.ndarray:
    """Next-token cross-entropy; softmax computed in sequence chunks so the
    (B, S, V) logits for 256k vocabularies never materialize at once."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    dt = _dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if cfg.vision_tokens > 0 and "img_embeds" in batch:
        x = jnp.concatenate([batch["img_embeds"].astype(dt), x], axis=1)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _run_encoder(cfg, params, batch["enc_frames"].astype(dt), impl)
    x, aux = _run_blocks(cfg, params["blocks"], x, enc_out, impl)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.vision_tokens > 0 and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1] :, :]

    unembed = params["embed"] if cfg.tie_embeddings else params["unembed"]
    B, S, d = x.shape
    chunk = min(loss_chunk, S)
    n_chunks = S // chunk if S % chunk == 0 else 1
    if S % chunk != 0:
        chunk = S

    def chunk_loss(args):
        xc, yc = args  # (B, chunk, d), (B, chunk)
        logits = jnp.einsum(
            "bsd,vd->bsv", xc, unembed, preferred_element_type=jnp.float32
        )
        if cfg.logit_softcap is not None:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    xs = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    ys = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    total = jnp.sum(jax.lax.map(chunk_loss, (xs, ys)))
    return total / (B * S) + aux


# ============================== decode =====================================


def _layer_state_init(
    cfg: ArchConfig, kind: str, batch: int, max_len: int, dt
) -> Params:
    if kind in (LayerKind.ATTN, LayerKind.LOCAL_ATTN):
        # sliding-window layers only ever need `window` cache slots
        if kind == LayerKind.LOCAL_ATTN and cfg.sliding_window is not None:
            L = min(max_len, cfg.sliding_window)
        elif cfg.local_global_ratio is None and cfg.sliding_window is not None:
            L = min(max_len, cfg.sliding_window)
        else:
            L = max_len
        return init_kv_cache(cfg, batch, L, dt)
    if kind == LayerKind.MAMBA:
        return mamba_mod.mamba_state_init(cfg, batch, dt)
    if kind == LayerKind.MLSTM:
        return xlstm_mod.mlstm_state_init(cfg, batch, dt)
    if kind == LayerKind.SLSTM:
        return xlstm_mod.slstm_state_init(cfg, batch, dt)
    raise ValueError(kind)  # pragma: no cover


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int
) -> Params:
    """Decode state stacked per unit position (mirrors the param layout)."""
    dt = _dtype(cfg)
    unit = cfg.pattern_unit()
    repeats = cfg.num_pattern_repeats
    cache: Params = {}
    for u, (kind, _) in enumerate(unit):
        per_repeat = [
            _layer_state_init(cfg, kind, batch, max_len, dt) for _ in range(repeats)
        ]
        cache[f"u{u}"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_repeat)
    return cache


def decode_step(
    cfg: ArchConfig,
    params: Params,
    cache: Params,
    token: jnp.ndarray,  # (B, 1) int32
    index: jnp.ndarray,  # scalar int32 current position
    *,
    enc_out: Optional[jnp.ndarray] = None,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Params]:
    """One decode step; returns (logits (B, 1, V), new cache)."""
    dt = _dtype(cfg)
    unit = cfg.pattern_unit()
    x = jnp.take(params["embed"], token, axis=0).astype(dt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)

    unit_len = len(unit)
    if unit_len == 0:  # cost-mode mini0
        x = apply_norm(params["final_norm"], x, cfg.norm)
        unembed = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,vd->bsv", x, unembed, preferred_element_type=jnp.float32)
        return logits, {}
    stacked_params = tuple(params["blocks"][f"u{u}"] for u in range(unit_len))
    stacked_cache = tuple(cache[f"u{u}"] for u in range(unit_len))

    def body(x, slices):
        p_slices, c_slices = slices
        new_states = []
        for (kind, is_moe), p, st in zip(unit, p_slices, c_slices, strict=True):
            if kind in (LayerKind.ATTN, LayerKind.LOCAL_ATTN):
                window = None
                if kind == LayerKind.LOCAL_ATTN and cfg.sliding_window is not None:
                    window = cfg.sliding_window
                elif cfg.local_global_ratio is None and cfg.sliding_window is not None:
                    window = cfg.sliding_window
                h = apply_norm(p["norm1"], x, cfg.norm)
                L = st["k"].shape[1]
                is_ring = window is not None and L == window
                write_idx = index % L if is_ring else jnp.minimum(index, L - 1)
                fill_len = jnp.minimum(index + 1, L)
                a, st = attn_decode(
                    p["attn"], cfg, h, st, index, write_idx, fill_len, impl=impl
                )
                x = x + a
                if enc_out is not None and "cross" in p:
                    h = apply_norm(p["cross_norm"], x, cfg.norm)
                    x = x + cross_attn_apply(p["cross"], cfg, h, enc_out, impl=impl)
            elif kind == LayerKind.MAMBA:
                x, st = mamba_mod.mamba_decode(p["mixer"], cfg, x, st)
            elif kind == LayerKind.MLSTM:
                x, st = xlstm_mod.mlstm_block_decode(p["block"], cfg, x, st)
                new_states.append(st)
                continue
            elif kind == LayerKind.SLSTM:
                x, st = xlstm_mod.slstm_block_decode(p["block"], cfg, x, st)
                new_states.append(st)
                continue
            if is_moe:
                h = apply_norm(p["norm2"], x, cfg.norm)
                mo, _ = moe_mod.moe_apply(p["moe"], cfg, h)
                x = x + mo
            elif cfg.d_ff > 0 and "mlp" in p:
                h = apply_norm(p["norm2"], x, cfg.norm)
                x = x + mlp_apply(p["mlp"], h, cfg.activation)
            new_states.append(st)
        return x, tuple(new_states)

    if cfg.scan_layers and cfg.num_pattern_repeats > 1:
        x, new_cache = jax.lax.scan(body, x, (stacked_params, stacked_cache))
    else:
        outs = []
        for r in range(cfg.num_pattern_repeats):
            sl = jax.tree_util.tree_map(lambda a, r=r: a[r], (stacked_params, stacked_cache))
            x, ns = body(x, sl)
            outs.append(ns)
        new_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    unembed = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, unembed, preferred_element_type=jnp.float32)
    if cfg.logit_softcap is not None:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    out_cache = {f"u{u}": new_cache[u] for u in range(unit_len)}
    return logits, out_cache
