"""Mixture-of-Experts MLP: top-k router + capacity-truncated sorted dispatch.

Dispatch is sort-based (no (tokens, experts, capacity) one-hot): token copies
are argsorted by expert id, truncated to a fixed per-expert capacity
``C = ceil(T*k/E * capacity_factor)``, gathered to an (E, C, d) buffer,
pushed through a batched expert matmul, and combined back with router
weights.  FLOPs scale with *active* parameters (x capacity factor), which is
what the roofline's MODEL_FLOPS/HLO_FLOPs ratio expects for MoE archs.

Expert parallelism: the expert dimension of the (E, C, d) buffers and the
expert weight stack is sharded over the ``model`` mesh axis (see
repro.distributed.sharding); XLA inserts the dispatch all-to-all.

On TPU the batched expert matmul lowers to the Pallas grouped-matmul kernel
(repro.kernels.gmm); the jnp path below is its einsum equivalent.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, MoEConfig
from repro.models.layers import Params, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    assert cfg.moe is not None
    mc = cfg.moe
    d, fe, E = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = jax.random.split(key, 4)
    glu = cfg.activation in ("swiglu", "geglu")
    p: Params = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_up": _stack_init(ks[1], E, d, fe, dtype),
        "w_down": _stack_init(ks[2], E, fe, d, dtype),
    }
    if glu:
        p["w_gate"] = _stack_init(ks[3], E, d, fe, dtype)
    return p


def _stack_init(key, E, din, dout, dtype):
    scale = 1.0 / math.sqrt(din)
    x = jax.random.truncated_normal(key, -2.0, 2.0, (E, din, dout), jnp.float32)
    return (x * scale).astype(dtype)


def _expert_ffn(p: Params, xs: jnp.ndarray, activation: str) -> jnp.ndarray:
    """Batched expert MLP: xs (E, C, d) -> (E, C, d)."""
    up = jnp.einsum("ecd,edf->ecf", xs, p["w_up"], preferred_element_type=jnp.float32)
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum(
            "ecd,edf->ecf", xs, p["w_gate"], preferred_element_type=jnp.float32
        )
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    h = h.astype(xs.dtype)
    return jnp.einsum(
        "ecf,efd->ecd", h, p["w_down"], preferred_element_type=jnp.float32
    ).astype(xs.dtype)


def moe_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, S, d)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux load-balancing loss (scalar fp32))."""
    mc: MoEConfig = cfg.moe  # type: ignore[assignment]
    B, S, d = x.shape
    T = B * S
    E, k = mc.num_experts, mc.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # --- aux loss (Switch-style load balancing) -------------------------
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    aux = jnp.sum(me * ce) * E * mc.aux_loss_weight

    # --- sorted, capacity-truncated dispatch ----------------------------
    capacity = int(math.ceil(T * k / E * mc.capacity_factor))
    flat_e = top_e.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e)  # stable: groups tokens by expert
    sorted_e = flat_e[order]
    # position of each copy within its expert group
    pos_in_group = jnp.arange(T * k) - jnp.searchsorted(
        sorted_e, sorted_e, side="left"
    )
    keep = pos_in_group < capacity
    # slot within the (E, C) buffer; dropped copies go to a trash slot
    slot = jnp.where(keep, sorted_e * capacity + pos_in_group, E * capacity)
    src_token = order // k  # token index of each sorted copy

    # gather tokens into expert buffers (+1 trash row, dropped at the end)
    from repro.distributed.hints import hint

    buf_idx = jnp.zeros((E * capacity + 1,), jnp.int32).at[slot].set(
        src_token.astype(jnp.int32), mode="drop"
    )
    xs = jnp.take(xt, buf_idx[: E * capacity], axis=0).reshape(E, capacity, d)
    xs = hint(xs, "model")  # EP: expert dim on the model axis (all-to-all)

    ys = _expert_ffn(p, xs, cfg.activation).reshape(E * capacity, d)

    # combine: route each kept copy's output back to its token, weighted
    copy_w = top_w.reshape(-1)[order] * keep.astype(jnp.float32)  # (T*k,)
    copy_out = jnp.take(ys, jnp.minimum(slot, E * capacity - 1), axis=0)
    copy_out = copy_out * copy_w[:, None].astype(copy_out.dtype)
    out = jnp.zeros((T, d), copy_out.dtype).at[src_token].add(copy_out)
    return out.reshape(B, S, d).astype(x.dtype), aux
