"""Mamba (selective SSM) mixer layer — the Jamba hybrid's workhorse.

Standard Mamba-1 block: in-proj (2x expand, gated z branch) -> causal conv4
-> selective (input-dependent) dt/B/C -> selective scan (repro.kernels) ->
z-gate -> out-proj.  Decode carries an O(1) (d_inner, d_state) recurrent
state + conv window, giving Jamba its ``long_500k`` capability.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ArchConfig, MambaConfig
from repro.models.layers import Params, apply_norm, dense, dense_init, norm_init
from repro.models.xlstm import _causal_conv, _conv_init  # shared depthwise conv

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_state_init"]


def _mc(cfg: ArchConfig) -> MambaConfig:
    return cfg.mamba or MambaConfig()


def _dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    mc = _mc(cfg)
    di = mc.expand * cfg.d_model
    dtr = mc.dt_rank or max(cfg.d_model // 16, 1)
    return di, mc.d_state, dtr


def mamba_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    d = cfg.d_model
    di, N, dtr = _dims(cfg)
    mc = _mc(cfg)
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "norm": norm_init(d, cfg.norm, dtype),
        "w_in": dense_init(ks[0], d, 2 * di, dtype),
        "conv": _conv_init(ks[1], mc.d_conv, di, dtype),
        "w_xdbc": dense_init(ks[2], di, dtr + 2 * N, dtype),
        "w_dt": dense_init(ks[3], dtr, di, jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        ks[4], (di,), jnp.float32,
                        minval=math.log(1e-3), maxval=math.log(1e-1),
                    )
                )
            )
        ),
        "log_a": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[5], di, d, dtype),
    }


def _ssm_inputs(p: Params, cfg: ArchConfig, xc: jnp.ndarray):
    """xc (B,T,di) -> dt (B,T,di), B (B,T,N), C (B,T,N)."""
    di, N, dtr = _dims(cfg)
    xdbc = dense(p["w_xdbc"], xc)
    dt_in, Bm, Cm = jnp.split(xdbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in.astype(jnp.float32) @ p["w_dt"]) + p["dt_bias"][None, None]
    )
    return dt, Bm, Cm


def mamba_apply(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, *, impl: str = "auto"
) -> jnp.ndarray:
    di, N, _ = _dims(cfg)
    h = apply_norm(p["norm"], x, cfg.norm)
    xz = dense(p["w_in"], h)
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p["conv"], xin))
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc)
    A = -jnp.exp(p["log_a"])  # (di, N)
    y = ops.mamba_scan(
        xc, dt.astype(xc.dtype), A, Bm, Cm, p["d_skip"], impl=impl
    )
    y = y * jax.nn.silu(z)
    return x + dense(p["w_out"], y)


def mamba_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    di, N, _ = _dims(cfg)
    mc = _mc(cfg)
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
    }


def mamba_decode(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, state: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrent step: x (B,1,d)."""
    B = x.shape[0]
    di, N, _ = _dims(cfg)
    h = apply_norm(p["norm"], x, cfg.norm)
    xz = dense(p["w_in"], h)
    xin, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    window = jnp.concatenate([state["conv"], xin.astype(state["conv"].dtype)], axis=1)
    w = jnp.flip(p["conv"], axis=0)  # window[-1]=current pairs with w[0]
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    )[:, None, :].astype(x.dtype)
    dt, Bm, Cm = _ssm_inputs(p, cfg, xc)  # (B,1,di) (B,1,N) (B,1,N)
    A = -jnp.exp(p["log_a"])
    dtf = dt[:, 0].astype(jnp.float32)  # (B,di)
    dA = jnp.exp(dtf[..., None] * A[None])  # (B,di,N)
    dBx = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0].astype(jnp.float32)[:, None, :]
    h_new = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h_new, Cm[:, 0].astype(jnp.float32))
    y = y + p["d_skip"][None] * xc[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    return x + dense(p["w_out"], y), {"h": h_new, "conv": window[:, 1:, :]}
