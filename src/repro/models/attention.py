"""GQA attention block: init/apply for training (full-sequence) and decode
(single-step against a KV cache), plus cross-attention (enc-dec).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ArchConfig
from repro.models.layers import Params, apply_rope, dense, dense_init, norm_init, apply_norm

__all__ = [
    "attn_init",
    "attn_apply",
    "attn_decode",
    "cross_attn_init",
    "cross_attn_apply",
    "init_kv_cache",
]


def attn_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    return p


def _project_qkv(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    from repro.distributed.hints import hint

    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = hint(dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd), "dp", None, "model", None)
    k = hint(dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd), "dp", None, "model", None)
    v = hint(dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd), "dp", None, "model", None)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, S, d)
    *,
    window: Optional[int] = None,
    causal: bool = True,
    impl: str = "auto",
) -> jnp.ndarray:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = ops.attention(
        q, k, v, causal=causal, window=window, softcap=None, impl=impl
    )
    return dense(p["wo"], out.reshape(B, S, -1))


def init_kv_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype: jnp.dtype
) -> Dict[str, jnp.ndarray]:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def attn_decode(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
    position: jnp.ndarray,  # scalar int32: absolute token position (rope)
    write_idx: jnp.ndarray,  # scalar int32: cache slot (== position, or
    #                          position % window for ring-buffer SWA caches)
    fill_len: jnp.ndarray,  # scalar int32: number of valid cache slots
    *,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step: write k/v at ``write_idx``, attend over valid slots.

    Sliding-window layers size their cache to the window and overwrite slots
    modularly (ring buffer) — attention is permutation-invariant over keys and
    rope is applied at absolute positions before the write, so no window mask
    is needed: eviction IS the mask.
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(position[None, None], (B, 1))
    q, k, v = _project_qkv(p, cfg, x, positions)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), write_idx, axis=1
    )
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), write_idx, axis=1
    )
    # single-token attention against the cache; HBM-bandwidth-bound by design
    out = _decode_attention(q, kc, vc, fill_len)
    return dense(p["wo"], out.reshape(B, 1, -1)), {"k": kc, "v": vc}


def _decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k: jnp.ndarray,  # (B, L, Hkv, D)
    v: jnp.ndarray,
    fill_len: jnp.ndarray,
) -> jnp.ndarray:
    """Single-token attention against a cache; memory-bound einsum path."""
    B, L, Hkv, D = k.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).reshape(B, 1, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    ok = jnp.arange(L) < fill_len
    scores = jnp.where(ok[None, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ------------------------- cross attention (enc-dec) -----------------------


def cross_attn_init(key: jax.Array, cfg: ArchConfig, dtype: jnp.dtype) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


def cross_attn_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, S, d) decoder states
    enc: jnp.ndarray,  # (B, T, d) encoder output
    *,
    impl: str = "auto",
) -> jnp.ndarray:
    B, S, _ = x.shape
    T = enc.shape[1]
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], enc).reshape(B, T, cfg.n_kv_heads, hd)
    v = dense(p["wv"], enc).reshape(B, T, cfg.n_kv_heads, hd)
    out = ops.attention(q, k, v, causal=False, window=None, impl=impl)
    return dense(p["wo"], out.reshape(B, S, -1))
