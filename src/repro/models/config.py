"""Architecture configuration covering every assigned arch family.

One frozen dataclass drives the whole substrate: dense transformers
(nemotron/gemma/stablelm/phi-backbone), MoE (granite/mixtral/jamba), SSM
(xlstm), hybrid (jamba), encoder-decoder (whisper) and VLM stubs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

__all__ = ["ArchConfig", "MoEConfig", "MambaConfig", "EncoderConfig", "LayerKind"]


# layer kinds used by block patterns
class LayerKind:
    ATTN = "attn"            # full (global) attention + MLP
    LOCAL_ATTN = "local"     # sliding-window attention + MLP
    MAMBA = "mamba"          # mamba mixer + MLP
    MLSTM = "mlstm"          # xLSTM matrix-memory block (self-contained)
    SLSTM = "slstm"          # xLSTM scalar-memory block (self-contained)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1  # MoE MLP every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec (whisper): full bidirectional attention."""

    n_layers: int
    n_frames: int  # precomputed frame embeddings (conv frontend is a stub)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "swiglu"  # swiglu | geglu | gelu | sq_relu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    qk_norm: bool = False

    # attention pattern
    sliding_window: Optional[int] = None
    local_global_ratio: Optional[Tuple[int, int]] = None  # (local, global)

    # substrate options
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    block_pattern: Optional[str] = None  # None | "jamba" | "xlstm"
    attn_every_k: int = 8  # jamba: attention layer every k layers
    xlstm_slstm_every: int = 8  # xLSTM[7:1]: one sLSTM block per 8

    # encoder-decoder / multimodal stubs
    encoder: Optional[EncoderConfig] = None
    vision_tokens: int = 0  # VLM: precomputed patch embeddings prepended

    # numerics / compile strategy
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "block"  # none | block
    use_pallas: bool = False  # TPU target; CPU uses the jnp reference path
    max_seq_len: int = 131_072

    # ----------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kinds(self) -> Sequence[str]:
        """The per-layer kind sequence implied by the block pattern."""
        kinds = []
        if self.block_pattern == "xlstm":
            for i in range(self.n_layers):
                if (i + 1) % self.xlstm_slstm_every == 0:
                    kinds.append(LayerKind.SLSTM)
                else:
                    kinds.append(LayerKind.MLSTM)
        elif self.block_pattern == "jamba":
            for i in range(self.n_layers):
                # one attention layer per attn_every_k, placed mid-unit
                if i % self.attn_every_k == self.attn_every_k // 2:
                    kinds.append(LayerKind.ATTN)
                else:
                    kinds.append(LayerKind.MAMBA)
        elif self.local_global_ratio is not None:
            loc, glob = self.local_global_ratio
            unit = [LayerKind.LOCAL_ATTN] * loc + [LayerKind.ATTN] * glob
            for i in range(self.n_layers):
                kinds.append(unit[i % len(unit)])
        else:
            kinds = [LayerKind.ATTN] * self.n_layers
        return tuple(kinds)

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        k = self.moe.every_k_layers
        return (layer_idx % k) == (k - 1)

    def pattern_unit(self) -> Tuple[Tuple[str, bool], ...]:
        """The repeating (kind, is_moe) unit used for layer-stack scanning."""
        if self.n_layers == 0:  # cost-mode "mini0": embed + head only
            return ()
        kinds = self.layer_kinds()
        moes = [self.layer_is_moe(i) for i in range(self.n_layers)]
        pairs = tuple(zip(kinds, moes, strict=True))
        # find the smallest repeating unit
        for size in range(1, self.n_layers + 1):
            if self.n_layers % size:
                continue
            unit = pairs[:size]
            if all(
                pairs[i] == unit[i % size] for i in range(self.n_layers)
            ):
                return unit
        return pairs  # no repetition; treated as a single unit

    @property
    def num_pattern_repeats(self) -> int:
        unit = self.pattern_unit()
        return self.n_layers // len(unit) if unit else 0

    # parameter counting (used for MODEL_FLOPS = 6*N*D) -----------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            if kind in ("attn", "local"):
                total += d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            elif kind == "mamba":
                mc = self.mamba or MambaConfig()
                di = mc.expand * d
                dtr = mc.dt_rank or max(d // 16, 1)
                total += d * 2 * di  # in-proj
                total += di * mc.d_conv  # conv
                total += di * (dtr + 2 * mc.d_state)  # x -> dt, B, C
                total += dtr * di + di * mc.d_state  # dt proj + A
                total += di * d  # out-proj
            elif kind == "mlstm":
                di = 2 * d
                total += d * 2 * di + di * 4  # up-proj (x,z) + conv
                total += 3 * di * di // max(self.n_heads, 1) * self.n_heads  # qkv
                total += 3 * di  # gates (i,f,o) per-channel proj approx
                total += di * d  # down-proj
            elif kind == "slstm":
                total += 4 * d * d + int(d * 4 / 3 * d) * 2
            # MLP (attention/mamba layers carry an MLP; xlstm blocks do not)
            if kind in ("attn", "local", "mamba"):
                if self.layer_is_moe(i):
                    fe = self.moe.d_ff_expert  # type: ignore[union-attr]
                    n_mats = 3 if self.activation in ("swiglu", "geglu") else 2
                    per_expert = n_mats * d * fe
                    cnt = self.moe.top_k if active_only else self.moe.num_experts  # type: ignore[union-attr]
                    total += cnt * per_expert + d * self.moe.num_experts  # type: ignore[union-attr]
                elif f > 0:
                    n_mats = 3 if self.activation in ("swiglu", "geglu") else 2
                    total += n_mats * d * f
            # norms
            total += 2 * d
        if self.encoder is not None:
            enc = self.encoder
            # encoder layers: attn + mlp, plus cross-attention in decoder
            total += enc.n_layers * (4 * d * hd * nq // max(nq, 1) * 1)
            total += enc.n_layers * (2 * d * f if self.activation not in ("swiglu", "geglu") else 3 * d * f)
            total += enc.n_layers * (4 * d * d)
            total += self.n_layers * (4 * d * d)  # decoder cross-attn
        return int(total)
