"""Primitive layers: norms, rotary embeddings, MLP variants, embeddings.

Pure-functional: parameters are nested dicts of jnp arrays; every function
takes (params, inputs) and returns outputs.  Initialization mirrors the
structure so `jax.eval_shape(init, ...)` yields the abstract param tree used
by the multi-pod dry-run.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Params",
    "dense_init",
    "dense",
    "norm_init",
    "apply_norm",
    "rope_freqs",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
    "embed_init",
    "activation_fn",
]

Params = Dict[str, Any]


def _truncated_normal(key, shape, scale, dtype):
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return x.astype(dtype)


def dense_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    dtype: jnp.dtype,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return _truncated_normal(key, (in_dim, out_dim), scale, dtype)


def dense(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x @ w with fp32 accumulation on MXU."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


# ----------------------------- norms ------------------------------------


def norm_init(d: int, kind: str, dtype: jnp.dtype) -> Params:
    p: Params = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        return (
            y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        ).astype(x.dtype)
    raise ValueError(f"unknown norm kind {kind!r}")


# ----------------------------- rotary ------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jnp.ndarray,  # (..., seq, heads, head_dim)
    positions: jnp.ndarray,  # (..., seq)
    theta: float,
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------- MLPs --------------------------------------


def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":  # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise ValueError(f"not a plain activation: {name!r}")


def mlp_init(
    key: jax.Array, d: int, f: int, activation: str, dtype: jnp.dtype
) -> Params:
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, f, dtype),
        "w_down": dense_init(ks[1], f, d, dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "swiglu":
        return dense(p["w_down"], jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x))
    if activation == "geglu":
        return dense(p["w_down"], jax.nn.gelu(dense(p["w_gate"], x)) * dense(p["w_up"], x))
    act = activation_fn(activation)
    return dense(p["w_down"], act(dense(p["w_up"], x)))


# ----------------------------- embeddings --------------------------------


def embed_init(key: jax.Array, vocab: int, d: int, dtype: jnp.dtype) -> jnp.ndarray:
    return _truncated_normal(key, (vocab, d), 1.0, dtype)
