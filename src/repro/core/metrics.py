"""The ET multi-objective metric (paper §IV-A).

For ``n`` simulations with energy ``x_i`` (Wh here; any consistent unit) and
average tardiness ``y_i`` (minutes):

    ET = (1/n) * sum_i (a*x_i + y_i) / (a + 1)

The scaling factor ``a`` is fixed *across an experiment*: with ``s`` the
global mean energy and ``t`` the global mean average-tardiness over the
simulations of all algorithms in the experiment, ``a = t / (2 s)`` — i.e.
after normalization tardiness is penalized 2x relative to energy.
Lower ET is better.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

__all__ = [
    "SimResult",
    "TenantSLOStats",
    "merge_tenant_stats",
    "slo_attainment",
    "et_scale_factor",
    "et_metric",
    "et_table",
]


@dataclasses.dataclass(frozen=True)
class TenantSLOStats:
    """Per-tenant serving outcome: request count, SLO hits, latency mass.

    ``latency_sum_min`` is the sum of arrival-to-completion latencies so
    stats from different devices merge exactly (means do not).
    """

    jobs: int
    attained: int
    latency_sum_min: float

    @property
    def attainment(self) -> float:
        """Fraction of requests that met their SLO (1.0 for zero requests)."""
        if self.jobs == 0:
            return 1.0
        return self.attained / self.jobs

    @property
    def mean_latency_min(self) -> float:
        if self.jobs == 0:
            return 0.0
        return self.latency_sum_min / self.jobs


def merge_tenant_stats(
    parts: Iterable[Mapping[str, TenantSLOStats]],
) -> Dict[str, TenantSLOStats]:
    """Merge per-device tenant stats into fleet totals (exact, order-free)."""
    out: Dict[str, TenantSLOStats] = {}
    for part in parts:
        for tenant, st in part.items():
            prev = out.get(tenant)
            if prev is None:
                out[tenant] = st
            else:
                out[tenant] = TenantSLOStats(
                    jobs=prev.jobs + st.jobs,
                    attained=prev.attained + st.attained,
                    latency_sum_min=prev.latency_sum_min + st.latency_sum_min,
                )
    return out


def slo_attainment(tenants: Mapping[str, TenantSLOStats]) -> float:
    """Request-weighted SLO attainment across tenants (1.0 when empty)."""
    jobs = sum(st.jobs for st in tenants.values())
    if jobs == 0:
        return 1.0
    return sum(st.attained for st in tenants.values()) / jobs


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Outcome of one simulation run.

    ``tenants`` is populated only by serving workloads whose jobs carry a
    tenant id (DESIGN.md §9); batch simulations leave it empty, keeping
    their serialized result dicts byte-identical to pre-serving baselines.
    """

    energy_wh: float
    avg_tardiness: float
    num_jobs: int = 0
    total_tardiness: float = 0.0
    preemptions: int = 0
    repartitions: int = 0
    max_tardiness: float = 0.0
    deadline_misses: int = 0
    busy_slot_minutes: float = 0.0  # integral of busy slots over time
    extra: Mapping[str, float] = dataclasses.field(default_factory=dict)
    tenants: Mapping[str, TenantSLOStats] = dataclasses.field(default_factory=dict)

    @property
    def slo_attainment(self) -> float:
        """Request-weighted SLO attainment over all tenants (1.0 if none)."""
        return slo_attainment(self.tenants)


def et_scale_factor(results: Iterable[SimResult]) -> float:
    """``a = t / (2 s)`` over ALL provided simulations (all algorithms)."""
    results = list(results)
    if not results:
        raise ValueError("no results")
    s = sum(r.energy_wh for r in results) / len(results)
    t = sum(r.avg_tardiness for r in results) / len(results)
    if s <= 0.0:
        return 1.0
    return t / (2.0 * s)


def et_metric(results: Sequence[SimResult], a: float) -> float:
    """ET for one algorithm's simulations given the experiment-wide ``a``."""
    if not results:
        raise ValueError("no results")
    return sum((a * r.energy_wh + r.avg_tardiness) / (a + 1.0) for r in results) / len(
        results
    )


def et_table(
    per_algo_results: Mapping[str, Sequence[SimResult]],
) -> Tuple[Dict[str, float], float]:
    """ET per algorithm with a shared ``a`` (as in Tables II/III).

    Returns (``{algo: ET}``, ``a``).
    """
    all_results: List[SimResult] = []
    for rs in per_algo_results.values():
        all_results.extend(rs)
    a = et_scale_factor(all_results)
    table = {name: et_metric(rs, a) for name, rs in per_algo_results.items()}
    return table, a
