"""The ET multi-objective metric (paper §IV-A).

For ``n`` simulations with energy ``x_i`` (Wh here; any consistent unit) and
average tardiness ``y_i`` (minutes):

    ET = (1/n) * sum_i (a*x_i + y_i) / (a + 1)

The scaling factor ``a`` is fixed *across an experiment*: with ``s`` the
global mean energy and ``t`` the global mean average-tardiness over the
simulations of all algorithms in the experiment, ``a = t / (2 s)`` — i.e.
after normalization tardiness is penalized 2x relative to energy.
Lower ET is better.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

__all__ = ["SimResult", "et_scale_factor", "et_metric", "et_table"]


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Outcome of one simulation run."""

    energy_wh: float
    avg_tardiness: float
    num_jobs: int = 0
    total_tardiness: float = 0.0
    preemptions: int = 0
    repartitions: int = 0
    max_tardiness: float = 0.0
    deadline_misses: int = 0
    busy_slot_minutes: float = 0.0  # integral of busy slots over time
    extra: Mapping[str, float] = dataclasses.field(default_factory=dict)


def et_scale_factor(results: Iterable[SimResult]) -> float:
    """``a = t / (2 s)`` over ALL provided simulations (all algorithms)."""
    results = list(results)
    if not results:
        raise ValueError("no results")
    s = sum(r.energy_wh for r in results) / len(results)
    t = sum(r.avg_tardiness for r in results) / len(results)
    if s <= 0.0:
        return 1.0
    return t / (2.0 * s)


def et_metric(results: Sequence[SimResult], a: float) -> float:
    """ET for one algorithm's simulations given the experiment-wide ``a``."""
    if not results:
        raise ValueError("no results")
    return sum((a * r.energy_wh + r.avg_tardiness) / (a + 1.0) for r in results) / len(
        results
    )


def et_table(
    per_algo_results: Mapping[str, Sequence[SimResult]],
) -> Tuple[Dict[str, float], float]:
    """ET per algorithm with a shared ``a`` (as in Tables II/III).

    Returns (``{algo: ET}``, ``a``).
    """
    all_results: List[SimResult] = []
    for rs in per_algo_results.values():
        all_results.extend(rs)
    a = et_scale_factor(all_results)
    table = {name: et_metric(rs, a) for name, rs in per_algo_results.items()}
    return table, a
