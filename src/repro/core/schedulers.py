"""In-configuration preemptive scheduling algorithms (paper §IV-C).

All schedulers produce, at each preemption point (arrival / completion /
critical-laxity event), a full assignment of active jobs to slices of the
current partition.  The simulator diffs consecutive assignments to count
preemptions (a previously running job that is paused or moved).

Algorithms (paper numbering):
  1. EDF-FS  — Earliest Deadline First, Fastest Slice.
  2. EDF-SS  — Earliest Deadline First, Slowest Slice that meets the deadline
               (fastest slice if none does).  ``restricted=True`` gives the
               variant that only preempts to directly prevent deadline misses
               (Fig. 4); the paper proceeds with the restricted variant.
  3. LLF     — Least Laxity First (laxity vs fastest slice), fastest slice,
               with critical-laxity events.
  4. LALF    — Least *Average* Laxity First (laxity vs mean duration across
               slices), fastest slice, with critical-laxity events.
"""

from __future__ import annotations

import dataclasses
import math
from abc import ABC, abstractmethod
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.jobs import Job
from repro.core.slices import Partition

__all__ = [
    "Assignment",
    "Scheduler",
    "EDFFastestSlice",
    "EDFSlowestSlice",
    "LeastLaxityFirst",
    "LeastAverageLaxityFirst",
    "make_scheduler",
    "remap_assignment",
    "SCHEDULERS",
]

# job_id -> slice index within the current partition
Assignment = Dict[int, int]


def remap_assignment(
    current: Assignment, index_map: Mapping[int, int]
) -> Assignment:
    """Carry an assignment across a partition change, slice-identity-stable.

    ``index_map`` maps old slice indices to their new indices for slice
    instances that survive a partial repartition
    (:class:`repro.core.slices.TransitionPlan`).  Jobs on surviving slices
    keep their seat under the new numbering; jobs on non-surviving slices
    must already have been preempted (asserted here — a silent drop would
    hide a simulator accounting bug).  Preserves iteration order, so the
    preemption diff in ``MIGSimulator._apply_assignment`` stays stable.
    """
    out: Assignment = {}
    for jid, old_slice in current.items():
        if old_slice not in index_map:
            raise AssertionError(
                f"job {jid} still assigned to non-surviving slice {old_slice}"
            )
        out[jid] = index_map[old_slice]
    return out


def _edf_key(job: Job) -> Tuple[float, float, int]:
    return (job.deadline, job.arrival, job.job_id)


class Scheduler(ABC):
    """Base class. Subclasses implement :meth:`assign`."""

    name: str = "base"
    uses_critical_laxity: bool = False
    critical_laxity_threshold: float = 1.0  # paper §V-A
    max_critical_preemptions: int = 3  # paper §V-A

    @abstractmethod
    def assign(
        self,
        t: float,
        part: Partition,
        jobs: Sequence[Job],
        current: Assignment,
        mig_enabled: bool = True,
    ) -> Assignment:
        """Return the new assignment for all active (not done) jobs."""

    # -- critical-laxity support (LLF/LALF) ------------------------------
    def job_laxity(self, t: float, part: Partition, job: Job, mig: bool = True) -> float:
        return job.laxity_fastest(t, part, mig)

    def next_critical_time(
        self,
        t: float,
        part: Partition,
        jobs: Sequence[Job],
        current: Assignment,
        mig_enabled: bool = True,
    ) -> Optional[float]:
        """Earliest future time a WAITING job's laxity crosses the threshold.

        While waiting, remaining duration is constant, so laxity decreases at
        unit rate: crossing time = t + (laxity(t) - threshold).
        """
        if not self.uses_critical_laxity:
            return None
        best: Optional[float] = None
        for job in jobs:
            if job.done or job.job_id in current:
                continue
            if job.critical_events >= self.max_critical_preemptions:
                continue
            lax = self.job_laxity(t, part, job, mig_enabled)
            if not math.isfinite(lax):
                continue
            dt = lax - self.critical_laxity_threshold
            if dt <= 1e-9:
                continue  # already critical; handled at this event
            cand = t + dt
            if best is None or cand < best:
                best = cand
        return best


def _greedy_fastest(
    t: float, part: Partition, ordered_jobs: Sequence[Job], mig: bool
) -> Assignment:
    """Assign jobs (in priority order) each to the fastest free slice."""
    free = part.sorted_indices(descending=True)  # fastest first
    out: Assignment = {}
    for job in ordered_jobs:
        if not free:
            break
        out[job.job_id] = free.pop(0)
    return out


class EDFFastestSlice(Scheduler):
    name = "EDF-FS"

    def assign(self, t, part, jobs, current, mig_enabled=True):
        active = sorted((j for j in jobs if not j.done), key=_edf_key)
        return _greedy_fastest(t, part, active, mig_enabled)


class EDFSlowestSlice(Scheduler):
    """EDF-SS; ``restricted=True`` => deadline-critical preemptions only."""

    def __init__(self, restricted: bool = True) -> None:
        self.restricted = restricted
        self.name = "EDF-SS" if restricted else "EDF-SS-unrestricted"

    # -- helpers ----------------------------------------------------------
    @staticmethod
    def _slowest_feasible(
        t: float, part: Partition, job: Job, free: List[int], mig: bool
    ) -> Optional[int]:
        """Slowest free slice meeting the deadline; None if none does."""
        feasible = [
            i for i in free if job.meets_deadline_on(t, part.slices[i].slots, mig)
        ]
        if not feasible:
            return None
        return min(feasible, key=lambda i: (part.slices[i].slots, i))

    @staticmethod
    def _fastest(part: Partition, free: List[int]) -> Optional[int]:
        if not free:
            return None
        return max(free, key=lambda i: (part.slices[i].slots, -i))

    # -- unrestricted: full reassignment ----------------------------------
    def _assign_unrestricted(self, t, part, jobs, mig) -> Assignment:
        active = sorted((j for j in jobs if not j.done), key=_edf_key)
        free = list(range(part.num_slices))
        out: Assignment = {}
        for job in active:
            if not free:
                break
            pick = self._slowest_feasible(t, part, job, free, mig)
            if pick is None:
                pick = self._fastest(part, free)
            out[job.job_id] = pick  # type: ignore[assignment]
            free.remove(pick)  # type: ignore[arg-type]
        return out

    # -- restricted: keep running jobs unless a deadline is at stake ------
    def _assign_restricted(self, t, part, jobs, current, mig) -> Assignment:
        by_id = {j.job_id: j for j in jobs if not j.done}
        out: Assignment = {
            jid: s for jid, s in current.items() if jid in by_id
        }
        free = [i for i in range(part.num_slices) if i not in out.values()]

        # Step A: a running job that now misses its deadline on its own slice
        # may (i) migrate to a free slice that saves it (slowest such),
        # (ii) displace a later-deadline runner whose slice saves it (the
        # victim re-queues into Step B), or (iii) as a last resort take a
        # strictly faster free slice (the EDF-SS "fastest slice" rule for
        # infeasible jobs).  All three directly prevent/reduce deadline
        # misses — the Fig. 4 restriction criterion.
        displaced: List[int] = []
        for jid in sorted(out, key=lambda j: _edf_key(by_id[j])):
            if jid not in out:  # displaced by an earlier iteration
                continue
            job = by_id[jid]
            cur_slice = out[jid]
            if job.meets_deadline_on(t, part.slices[cur_slice].slots, mig):
                continue
            pick = self._slowest_feasible(t, part, job, free, mig)
            if pick is None:
                victims = [
                    (vj, s)
                    for vj, s in out.items()
                    if vj != jid
                    and by_id[vj].deadline > job.deadline
                    and job.meets_deadline_on(t, part.slices[s].slots, mig)
                ]
                if victims:
                    vjid, vslice = min(
                        victims,
                        key=lambda pr: (part.slices[pr[1]].slots, -by_id[pr[0]].deadline),
                    )
                    del out[vjid]
                    displaced.append(vjid)
                    free.append(cur_slice)
                    out[jid] = vslice
                    continue
            if pick is None:
                fastest_free = self._fastest(part, free)
                if (
                    fastest_free is not None
                    and part.slices[fastest_free].slots
                    > part.slices[cur_slice].slots
                ):
                    pick = fastest_free
            if pick is not None:
                free.append(cur_slice)
                free.remove(pick)
                out[jid] = pick

        # Step B: queued jobs in EDF order.
        queued = sorted(
            (j for j in by_id.values() if j.job_id not in out), key=_edf_key
        )
        pending = list(queued)
        while pending:
            job = pending.pop(0)
            pick = self._slowest_feasible(t, part, job, free, mig)
            if pick is not None:
                out[job.job_id] = pick
                free.remove(pick)
                continue
            # No free slice meets the deadline. Preempt a later-deadline
            # running job ONLY if that directly saves this job's deadline.
            victims = [
                (jid, s)
                for jid, s in out.items()
                if by_id[jid].deadline > job.deadline
                and job.meets_deadline_on(t, part.slices[s].slots, mig)
            ]
            if victims:
                # Prefer the slowest slice that still saves the job; break
                # ties by latest victim deadline (most laxity to give up).
                vjid, vslice = min(
                    victims,
                    key=lambda p: (part.slices[p[1]].slots, -by_id[p[0]].deadline),
                )
                del out[vjid]
                out[job.job_id] = vslice
                # victim re-queues and is reconsidered for remaining slices
                pending.append(by_id[vjid])
                pending.sort(key=_edf_key)
                continue
            # Deadline unsalvageable: take the fastest free slice if any.
            pick = self._fastest(part, free)
            if pick is not None:
                out[job.job_id] = pick
                free.remove(pick)
        return out

    def assign(self, t, part, jobs, current, mig_enabled=True):
        if self.restricted:
            return self._assign_restricted(t, part, jobs, current, mig_enabled)
        return self._assign_unrestricted(t, part, jobs, mig_enabled)


class LeastLaxityFirst(Scheduler):
    """LLF — laxity vs the fastest slice; jobs run on fastest slices."""

    name = "LLF"
    uses_critical_laxity = True

    def job_laxity(self, t, part, job, mig=True):
        return job.laxity_fastest(t, part, mig)

    def assign(self, t, part, jobs, current, mig_enabled=True):
        active = [j for j in jobs if not j.done]
        active.sort(
            key=lambda j: (self.job_laxity(t, part, j, mig_enabled), j.arrival, j.job_id)
        )
        return _greedy_fastest(t, part, active, mig_enabled)


class LeastAverageLaxityFirst(LeastLaxityFirst):
    """LALF — laxity vs mean duration across the partition's slices."""

    name = "LALF"
    uses_critical_laxity = True

    def job_laxity(self, t, part, job, mig=True):
        return job.laxity_average(t, part, mig)


SCHEDULERS = {
    "EDF-FS": lambda: EDFFastestSlice(),
    "EDF-SS": lambda: EDFSlowestSlice(restricted=True),
    "EDF-SS-unrestricted": lambda: EDFSlowestSlice(restricted=False),
    "LLF": lambda: LeastLaxityFirst(),
    "LALF": lambda: LeastAverageLaxityFirst(),
}


def make_scheduler(name: str) -> Scheduler:
    try:
        return SCHEDULERS[name]()
    except KeyError as e:
        raise KeyError(f"unknown scheduler {name!r}; options {sorted(SCHEDULERS)}") from e
