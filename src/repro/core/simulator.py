"""Event-driven MIG simulator with preemption and dynamic repartitioning.

Implements the paper's simulation setting (§IV, §V-A):

* events: job arrival, job completion, critical-laxity timer (LLF/LALF),
  repartition-complete, and policy timer (Day/Night benchmark boundaries);
* at arrival/completion the repartitioning policy may choose a new
  configuration (paper §IV-D-2 "event-based architecture"); repartitioning
  charges the 4-second §IV-D-3 stall.  Under the default
  ``repartition_mode="partial"`` only the slice instances that actually
  change are destroyed/created (:func:`repro.core.slices.transition`) —
  jobs on surviving instances keep running through the stall, exactly as a
  real MIG reconfiguration leaves untouched GPU instances operational.
  ``repartition_mode="drain"`` is the legacy full-drain model (every
  running job preempted, the whole GPU blocked), kept so pre-``mig-sim-4``
  numbers stay reproducible;
* between consecutive events the set of running jobs is constant, so energy
  (Fig. 3 power curve) and the tardiness integral are integrated exactly;
* preemptions are counted by diffing consecutive assignments (a running job
  that is paused or moved counts once).

The event loop itself lives in :mod:`repro.core.engine`
(:class:`SimulationEngine`): :meth:`MIGSimulator.run` is a thin one-shot
wrapper over it, and the step-wise path is bit-identical by construction.
This module keeps the numeric state — time advance, energy/tardiness
integration, assignments, preemption accounting — and the policy zoo.

The simulator is deterministic given the job list and policy.
"""

from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Protocol, Sequence, Tuple

from repro.core.engine import SimSnapshot, SimulationEngine, snapshot_of
from repro.core.jobs import Job
from repro.core.metrics import SimResult
from repro.core.power import A100_250W, PowerModel
from repro.core.schedulers import Assignment, Scheduler, remap_assignment
from repro.core.slices import MIG_CONFIGS, Partition, table_slice_sizes, transition

__all__ = [
    "RepartitionPolicy",
    "StaticPolicy",
    "NoMIGPolicy",
    "DayNightPolicy",
    "CallbackPolicy",
    "MIGSimulator",
    "REPARTITION_PENALTY_MIN",
    "REPARTITION_MODES",
    "SIM_VERSION",
]

# Version tag of the simulation semantics.  Bump whenever a change alters the
# numbers a run produces (event ordering, power model wiring, penalty, ...);
# the sweep cache (repro.sweep) keys cells on it so stale results never
# survive a semantics change.
#
# mig-sim-4: partitions are slot-placed and repartitioning is partial by
# default — only the slice instances that change are destroyed/created,
# jobs on surviving instances run through the 4 s stall, and the stall is
# charged against the affected slots only (repartition_mode="drain" restores
# the mig-sim-3 full-drain numbers bit-identically).
SIM_VERSION = "mig-sim-4"

# §IV-D-3: destroying/recreating MIG slices takes ~4 seconds.
REPARTITION_PENALTY_MIN = 4.0 / 60.0

#: valid ``MIGSimulator.repartition_mode`` values: ``"partial"`` (slot-placed
#: transition, survivors keep running) and ``"drain"`` (legacy full drain).
REPARTITION_MODES = ("partial", "drain")

_EPS = 1e-9


class RepartitionPolicy(Protocol):
    """Decides the MIG configuration at decision points."""

    initial_config: int

    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        """Return a config id to switch to, or None to stay."""
        ...

    def next_timer(self, t: float) -> Optional[float]:
        """Next time-triggered decision point strictly after ``t`` (or None)."""
        ...


class StaticPolicy:
    """Fixed configuration; never repartitions (Static MIG benchmark)."""

    def __init__(self, config_id: int) -> None:
        self.initial_config = config_id

    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        return None

    def next_timer(self, t: float) -> Optional[float]:
        return None


class NoMIGPolicy(StaticPolicy):
    """Full GPU, MIG disabled (No MIG benchmark).

    Config 1 (one 7g.40gb slice) with ``mig_enabled=False`` so that linear
    jobs get the §V-A 6 % full-GPU speedup.
    """

    def __init__(self) -> None:
        super().__init__(config_id=1)


class DayNightPolicy:
    """Twice-daily repartitioning benchmark (§V-A).

    Config ``day_config`` during 5:00-17:00, ``night_config`` otherwise.
    """

    def __init__(self, day_config: int = 6, night_config: int = 2) -> None:
        self.day_config = day_config
        self.night_config = night_config
        self.day_start = 5 * 60.0
        self.day_end = 17 * 60.0
        self.initial_config = self._target(0.0)

    def _target(self, t: float) -> int:
        tod = t % (24 * 60.0)
        return (
            self.day_config
            if self.day_start <= tod < self.day_end
            else self.night_config
        )

    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        tgt = self._target(t)
        return tgt if tgt != sim.partition.config_id else None

    def next_timer(self, t: float) -> Optional[float]:
        day = 24 * 60.0
        base = math.floor(t / day) * day
        for bound in (base + self.day_start, base + self.day_end,
                      base + day + self.day_start):
            if bound > t + _EPS:
                return bound
        return None  # pragma: no cover


class CallbackPolicy:
    """Adapter: wraps a ``(t, sim) -> Optional[int]`` callable (RL agent)."""

    def __init__(
        self,
        fn,
        initial_config: int = 2,
    ) -> None:
        self._fn = fn
        self.initial_config = initial_config

    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        return self._fn(t, sim)

    def next_timer(self, t: float) -> Optional[float]:
        return None


class MIGSimulator:
    """One GPU (or TPU-pod analogue), one scheduler, one repartition policy."""

    def __init__(
        self,
        scheduler: Scheduler,
        power_model: PowerModel = A100_250W,
        mig_enabled: bool = True,
        repartition_penalty_min: float = REPARTITION_PENALTY_MIN,
        max_events: int = 5_000_000,
        config_table: Optional[Mapping[int, Partition]] = None,
        repartition_mode: str = "partial",
    ) -> None:
        if repartition_mode not in REPARTITION_MODES:
            raise ValueError(
                f"unknown repartition_mode {repartition_mode!r}; "
                f"valid: {REPARTITION_MODES}"
            )
        self.scheduler = scheduler
        self.power = power_model
        self.mig_enabled = mig_enabled
        self.penalty = repartition_penalty_min
        self.max_events = max_events
        self.repartition_mode = repartition_mode
        # per-device partition table (fleet heterogeneity): defaults to the
        # paper's A100 Fig. 1 table, under which behavior is unchanged
        self.configs: Mapping[int, Partition] = (
            dict(config_table) if config_table is not None else MIG_CONFIGS
        )
        # device slot-grid geometry, cached for snapshot fragmentation:
        # the grid is as wide as the widest layout in the table, and the
        # placeable vocabulary is whatever slice widths the table uses
        self.grid_slots: int = max(p.total_slots for p in self.configs.values())
        self.slice_sizes: Tuple[int, ...] = table_slice_sizes(dict(self.configs))

        # runtime state (reset per run)
        self.reset(min(self.configs))

    def reset(self, config_id: int) -> None:
        """Clear all run state and install the initial configuration.

        :class:`~repro.core.engine.SimulationEngine` calls this when it is
        constructed; a simulator instance is reusable across runs.
        """
        self.t = 0.0
        self.partition: Partition = self._config(config_id)
        self.active: Dict[int, Job] = {}
        self.assignment: Assignment = {}
        self.completed: List[Job] = []
        # jobs removed by SimulationEngine.cancel(): out of the system, never
        # completed — they stop drawing energy/tardiness from the cancel
        # instant and are reported via SimResult.extra["cancelled_jobs"]
        self.cancelled: List[Job] = []
        self.energy_wh = 0.0
        self.tardiness_integral = 0.0
        self.preemptions = 0
        self.repartitions = 0
        self.busy_slot_minutes = 0.0
        self.util_histogram: Dict[int, float] = {}
        self.config_trace: List[Tuple[float, int]] = [(0.0, config_id)]
        self._repartitioning_until: Optional[float] = None
        self._pending_config: Optional[int] = None
        # partial-repartition state: surviving old->new slice index map and
        # the slot footprint of the in-flight rebuild (0 when idle)
        self._survivor_map: Dict[int, int] = {}
        self._stalled_slots: int = 0

    # ------------------------------------------------------------------
    def _config(self, config_id: int) -> Partition:
        try:
            return self.configs[config_id]
        except KeyError as e:
            raise KeyError(
                f"config {config_id} not in this device's table "
                f"(valid ids {sorted(self.configs)})"
            ) from e

    @property
    def busy_slots(self) -> float:
        """Compute slots currently doing work.

        During a repartition the assignment holds exactly the surviving
        jobs (all of them in drain mode: none), so summing the assignment
        is correct in every state — the stall is charged only against the
        affected slots, survivors keep drawing busy power.
        """
        return float(
            sum(self.partition.slices[s].slots for s in self.assignment.values())
        )

    @property
    def stalled_slots(self) -> int:
        """Slot footprint of the in-flight repartition (0 when idle)."""
        return self._stalled_slots if self._repartitioning_until is not None else 0

    def queue_snapshot(self) -> List[Job]:
        """Waiting (unassigned, incomplete) jobs sorted EDF-style."""
        waiting = [
            j for j in self.active.values() if not j.done and j.job_id not in self.assignment
        ]
        waiting.sort(key=lambda j: (j.deadline, j.arrival, j.job_id))
        return waiting

    def snapshot(self) -> SimSnapshot:
        """Structured read-only view of the current state.

        This is what repartitioning policies and fleet dispatchers observe
        (see :class:`repro.core.engine.SimSnapshot` for the field contract);
        everything in it is observable by a real MIG controller.
        """
        return snapshot_of(self)

    # ------------------------------------------------------------------
    def _advance(self, new_t: float) -> None:
        dt = new_t - self.t
        if dt < -1e-6:
            raise RuntimeError(f"time went backwards: {self.t} -> {new_t}")
        if dt <= 0.0:
            self.t = new_t
            return
        busy = self.busy_slots
        self.energy_wh += self.power.energy_wh(busy, dt)
        self.busy_slot_minutes += busy * dt
        self.util_histogram[int(round(busy))] = (
            self.util_histogram.get(int(round(busy)), 0.0) + dt
        )
        # exact tardiness integral: each incomplete job past its deadline
        # contributes the overlap of [t, new_t] with [deadline, inf)
        for job in self.active.values():
            if not job.done and job.deadline < new_t:
                self.tardiness_integral += new_t - max(job.deadline, self.t)
        # deplete running jobs
        for jid, sl in self.assignment.items():
            job = self.active[jid]
            rate = job.rate_on(self.partition.slices[sl].slots, self.mig_enabled)
            job.remaining = max(job.remaining - rate * dt, 0.0)
        self.t = new_t

    def _complete_finished(self) -> List[Job]:
        done = []
        for jid in list(self.assignment):
            job = self.active[jid]
            if job.remaining <= _EPS:
                job.remaining = 0.0
                job.completion = self.t
                done.append(job)
                del self.assignment[jid]
                del self.active[jid]
                self.completed.append(job)
        # zero-remaining jobs that never held a slice (e.g. an injected
        # zero-/epsilon-work arrival): schedulers skip done jobs, so without
        # this sweep they would sit in `active` forever and drain() on a
        # closed stream would never finish.  No job in the assignment-driven
        # path above ever reaches here, so legacy runs are bit-identical.
        for jid, job in list(self.active.items()):
            if job.remaining <= _EPS and jid not in self.assignment:
                job.remaining = 0.0
                job.completion = self.t
                done.append(job)
                del self.active[jid]
                self.completed.append(job)
        return done

    def _apply_assignment(self, new: Assignment) -> None:
        for jid, old_slice in self.assignment.items():
            if jid not in new or new[jid] != old_slice:
                self.preemptions += 1
                self.active[jid].preemptions += 1
        for jid, sl in new.items():
            self.active[jid].last_slice = sl
        self.assignment = dict(new)

    def _reschedule(self) -> None:
        if self._repartitioning_until is not None:
            return
        jobs = [j for j in self.active.values() if not j.done]
        new = self.scheduler.assign(
            self.t, self.partition, jobs, self.assignment, self.mig_enabled
        )
        # drop stale ids defensively
        new = {jid: s for jid, s in new.items() if jid in self.active}
        self._apply_assignment(new)

    def _start_repartition(self, config_id: int) -> None:
        new_part = self._config(config_id)
        if self.repartition_mode == "partial":
            plan = transition(self.partition, new_part)
            survivors = plan.survivor_map
            self._stalled_slots = plan.stalled_slots
        else:  # drain: every slice is torn down, the whole GPU stalls
            survivors = {}
            self._stalled_slots = self.partition.total_slots
        # only jobs on destroyed slices are preempted back to the queue;
        # jobs on surviving slice instances keep running through the stall
        for jid, sl in list(self.assignment.items()):
            if sl not in survivors:
                self.preemptions += 1
                self.active[jid].preemptions += 1
                del self.assignment[jid]
        self._survivor_map = survivors
        self._pending_config = config_id
        self._repartitioning_until = self.t + self.penalty
        self.repartitions += 1

    def _finish_repartition(self) -> None:
        assert self._pending_config is not None
        self.partition = self._config(self._pending_config)
        if self.assignment:
            # survivors keep their physical slice under the new numbering —
            # identity-stable, so the preemption diff sees no move
            self.assignment = remap_assignment(self.assignment, self._survivor_map)
            for jid, sl in self.assignment.items():
                self.active[jid].last_slice = sl
        self.config_trace.append((self.t, self.partition.config_id))
        self._pending_config = None
        self._repartitioning_until = None
        self._survivor_map = {}
        self._stalled_slots = 0

    # ------------------------------------------------------------------
    def run(
        self,
        jobs: Sequence[Job],
        policy: Optional[RepartitionPolicy] = None,
        initial_config: Optional[int] = None,
    ) -> SimResult:
        """Simulate to completion of all jobs; returns a :class:`SimResult`.

        One-shot wrapper over :class:`repro.core.engine.SimulationEngine`;
        build the engine directly for step-wise execution, online arrival
        injection, or a live trace sink.
        """
        engine = SimulationEngine(
            self, policy=policy, initial_config=initial_config, jobs=jobs
        )
        engine.drain()
        return engine.result()
