"""Job model: AI/ML workloads with linear / capped / sublinear elasticity.

A job ``j`` carries ``work`` — its processing requirement expressed as the
duration it would take on a 1g slice.  Its duration on a slice of compute
size ``k`` is ``dur_jk = work / throughput_j(k)`` where ``throughput_j`` is
determined by the job's elasticity class (paper §III-B, Fig. 2):

* linear:     tp(k) = k
* capped(c):  tp(k) = min(k, c)         with c in {2, 3, 4}
* sublinear:  tp(k) one of four normalized concave curves (two exponential-
              saturating, two logarithmic), tp(1) = 1, monotone nondecreasing.

Durations/throughputs are independent of what runs on other slices
(paper §III-B citing [18], [19]).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Dict, Optional, Tuple

from repro.core.slices import ALL_SLICE_SIZES, Partition

__all__ = [
    "JobKind",
    "Elasticity",
    "ElasticityClass",
    "Job",
    "LINEAR",
    "capped",
    "SUBLINEAR_CURVES",
    "sublinear",
    "elasticity_from_label",
]


class JobKind(enum.Enum):
    INFERENCE = "inference"
    TRAINING = "training"  # includes LLM fine-tuning (paper §III-B)


class ElasticityClass(enum.Enum):
    LINEAR = "linear"
    CAPPED = "capped"
    SUBLINEAR = "sublinear"


@dataclasses.dataclass(frozen=True)
class Elasticity:
    """Throughput-vs-slice-size profile. tp(1) == 1 by construction."""

    klass: ElasticityClass
    label: str
    _tp: Callable[[float], float]
    cap: Optional[int] = None  # for CAPPED

    def throughput(self, slots: float) -> float:
        if slots <= 0:
            return 0.0
        return self._tp(float(slots))

    def duration(self, work: float, slots: float) -> float:
        tp = self.throughput(slots)
        if tp <= 0.0:
            return math.inf
        return work / tp

    def __reduce__(self):
        # the label is a complete description (every curve in the canonical
        # vocabulary is label-addressable): pickling by label keeps Job —
        # and hence the whole SimulationEngine — picklable for service
        # checkpoints and WAL job records, despite the lambda in ``_tp``.
        # Validate resolvability NOW so a custom curve (e.g. the cluster
        # roofline elasticities) fails at dump time with a clear message,
        # not at restore time with a corrupt checkpoint.
        elasticity_from_label(self.label)
        return (elasticity_from_label, (self.label,))


LINEAR = Elasticity(ElasticityClass.LINEAR, "linear", lambda k: k)


def capped(cap: int) -> Elasticity:
    if cap not in (2, 3, 4):
        raise ValueError(f"paper caps jobs at 2g/3g/4g, got {cap}")
    return Elasticity(
        ElasticityClass.CAPPED, f"capped@{cap}g", lambda k, c=cap: min(k, float(c)), cap=cap
    )


def _exp_curve(a: float) -> Callable[[float], float]:
    # tp(k) = (1 - exp(-a k)) / (1 - exp(-a)); tp(1)=1, concave, saturating.
    denom = 1.0 - math.exp(-a)
    return lambda k: (1.0 - math.exp(-a * k)) / denom


def _log_curve(b: float) -> Callable[[float], float]:
    # tp(k) = 1 + b log2(k); tp(1)=1, concave increasing.
    return lambda k: 1.0 + b * math.log2(k) if k >= 1.0 else k


# Four equally likely sublinear curves (paper §V-A: "four different sublinear
# functions simulated as exponential and logarithmic functions").
# log slope b must be <= ln2 ~ 0.693 or tp(k) > k just above k=1 (superlinear,
# contradicting the class definition) — caught by the hypothesis sweep.
SUBLINEAR_CURVES: Dict[str, Elasticity] = {
    "exp-0.35": Elasticity(ElasticityClass.SUBLINEAR, "exp-0.35", _exp_curve(0.35)),
    "exp-0.60": Elasticity(ElasticityClass.SUBLINEAR, "exp-0.60", _exp_curve(0.60)),
    "log-0.65": Elasticity(ElasticityClass.SUBLINEAR, "log-0.65", _log_curve(0.65)),
    "log-0.45": Elasticity(ElasticityClass.SUBLINEAR, "log-0.45", _log_curve(0.45)),
}


def sublinear(label: str) -> Elasticity:
    return SUBLINEAR_CURVES[label]


def elasticity_from_label(label: str) -> Elasticity:
    """Resolve any canonical elasticity label back to its profile.

    The inverse of ``Elasticity.label`` over the paper's whole vocabulary —
    ``"linear"``, ``"capped@{2,3,4}g"``, and the four sublinear curve names.
    This is the codec the pickle reduction and the service WAL job records
    share: a label round-trips to an object with the identical throughput
    function, so restored jobs deplete bit-identically.
    """
    if label == "linear":
        return LINEAR
    if label in SUBLINEAR_CURVES:
        return SUBLINEAR_CURVES[label]
    if label.startswith("capped@") and label.endswith("g"):
        cap = int(label[len("capped@"):-1])
        if cap in (2, 3, 4):
            return capped(cap)
        if cap >= 1:
            # serving slice classes cap at 1 and 7 too (DESIGN.md §9) —
            # same construction as repro.core.serving.class_elasticity
            return Elasticity(
                ElasticityClass.CAPPED,
                f"capped@{cap}g",
                lambda k, c=cap: min(k, float(c)),
                cap=cap,
            )
    raise ValueError(
        f"unknown elasticity label {label!r}; valid: 'linear', 'capped@<n>g' "
        f"(n >= 1), or one of {sorted(SUBLINEAR_CURVES)}"
    )


@dataclasses.dataclass
class Job:
    """A single AI/ML job with mutable scheduling state.

    ``work`` is in 1g-slice minutes.  ``remaining`` depletes at rate
    ``elasticity.throughput(slice_slots)`` while running.
    """

    job_id: int
    kind: JobKind
    arrival: float  # minutes
    work: float  # 1g-minutes
    deadline: float  # absolute minutes
    elasticity: Elasticity
    speedup_no_mig: float = 1.0  # NoMIG benchmark: 1.06 for linear jobs

    # --- serving metadata (multi-tenant SLO workloads; DESIGN.md §9) ----
    # Batch jobs leave both None.  A serving request carries its tenant id
    # and a latency SLO in minutes; the generator also sets
    # ``deadline = arrival + slo_min`` so EDF-family schedulers order
    # requests by SLO urgency without modification.
    tenant: Optional[str] = None
    slo_min: Optional[float] = None

    # --- mutable scheduling state -------------------------------------
    remaining: float = dataclasses.field(default=-1.0)
    completion: Optional[float] = None
    preemptions: int = 0
    critical_events: int = 0  # LLF/LALF critical-laxity triggers used
    last_slice: Optional[int] = None  # slice index job last ran on

    def __post_init__(self) -> None:
        if self.remaining < 0.0:
            self.remaining = self.work

    # --- durations ------------------------------------------------------
    def rate_on(self, slots: float, mig_enabled: bool = True) -> float:
        """Work-deplete rate on a slice of given compute size."""
        r = self.elasticity.throughput(slots)
        if not mig_enabled:
            r *= self.speedup_no_mig
        return r

    def duration_on(self, slots: float, mig_enabled: bool = True) -> float:
        r = self.rate_on(slots, mig_enabled)
        return math.inf if r <= 0 else self.remaining / r

    def finish_time_on(self, t: float, slots: float, mig_enabled: bool = True) -> float:
        return t + self.duration_on(slots, mig_enabled)

    def meets_deadline_on(self, t: float, slots: float, mig_enabled: bool = True) -> bool:
        return self.finish_time_on(t, slots, mig_enabled) <= self.deadline + 1e-9

    def laxity_fastest(self, t: float, part: Partition, mig_enabled: bool = True) -> float:
        """Laxity vs the fastest slice of the partition (LLF, paper §IV-C)."""
        fastest = part.slices[part.fastest_slice_index()].slots
        return (self.deadline - t) - self.duration_on(fastest, mig_enabled)

    def laxity_average(self, t: float, part: Partition, mig_enabled: bool = True) -> float:
        """Laxity vs mean duration across the partition's slices (LALF)."""
        durs = [self.duration_on(s.slots, mig_enabled) for s in part.slices]
        return (self.deadline - t) - (sum(durs) / len(durs))

    @property
    def done(self) -> bool:
        return self.remaining <= 1e-9

    def tardiness(self) -> float:
        if self.completion is None:
            return 0.0
        return max(self.completion - self.deadline, 0.0)

    def latency(self) -> float:
        """Arrival-to-completion latency in minutes (0 while incomplete)."""
        if self.completion is None:
            return 0.0
        return max(self.completion - self.arrival, 0.0)

    def slo_attained(self) -> bool:
        """Whether a completed request met its latency SLO.

        Jobs without an SLO trivially attain it; incomplete jobs do not.
        """
        if self.completion is None:
            return False
        if self.slo_min is None:
            return True
        return self.latency() <= self.slo_min + 1e-9

    def mean_duration_all_sizes(self) -> float:
        """Average remaining duration over the canonical slice sizes.

        Used by the DQN state representation ("average duration of the first
        m jobs", paper §IV-D-1) — averaged over slice sizes so it is
        configuration-independent.
        """
        durs = [self.duration_on(k) for k in ALL_SLICE_SIZES]
        return sum(durs) / len(durs)
