"""Steppable event engine behind the MIG simulator (paper §IV-D-2).

The paper's event-based architecture used to live inside the monolithic
``MIGSimulator.run()`` closure: the only way to observe a simulation was to
let it run to completion.  This module extracts the loop into a
:class:`SimulationEngine` you can pause, observe, and resume:

* ``step()`` processes exactly one event (arrival, completion,
  critical-laxity timer, repartition-complete, policy timer) and returns an
  :class:`EngineEvent` record, or ``None`` when the event queue is empty;
* ``run_until(t)`` processes every pending event up to a time bound —
  the fleet layer co-advances N engines on a merged arrival clock this way;
* ``inject(job)`` feeds an arrival into a *running* engine (online
  streaming; the engine is constructed with ``stream_open=True`` and the
  producer calls ``close_stream()`` when the stream ends);
* ``snapshot()`` returns the read-only :class:`EngineSnapshot` view that
  dispatchers, policies, and telemetry consume;
* in *interactive* mode the engine stops at each §IV-D decision point and
  waits for :meth:`provide_decision` instead of consulting a policy — the
  incremental RL environment (:class:`repro.core.rl.env.RepartitionEnv`)
  is built on exactly this;
* a ``trace_sink`` callable receives every :class:`EngineEvent` as it is
  processed (live telemetry; see ``examples/streaming_day.py``).

``MIGSimulator.run()`` is now a thin wrapper — one-shot execution and
step-wise execution share this code path and are bit-identical by
construction (property-tested in ``tests/test_engine.py``).

This engine is also the **bit-exact oracle** of the repo's two-backend
contract (docs/BATCHED_SIM.md, DESIGN.md §8): the batched fixed-timestep
backend (``repro.core.batched``) reproduces its aggregates within
documented tolerances, and every semantics question — and every checked-in
baseline — is settled here, never there.

All numeric state (time advance, energy/tardiness integration, preemption
accounting) stays on the :class:`~repro.core.simulator.MIGSimulator`; the
engine owns only the event queue, the event versioning, and decision-point
sequencing.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.jobs import Job, JobKind
from repro.core.metrics import SimResult, TenantSLOStats
from repro.core.slices import free_slot_geometry

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.simulator import MIGSimulator, RepartitionPolicy

__all__ = [
    "EventKind",
    "EngineEvent",
    "SimSnapshot",
    "EngineSnapshot",
    "TraceSink",
    "SimulationEngine",
]

_EPS = 1e-9


class EventKind(enum.IntEnum):
    """Event types, in heap tie-break priority order (lower pops first)."""

    ARRIVAL = 0
    COMPLETION = 1
    CRITICAL = 2
    REPART_DONE = 3
    TIMER = 4


@dataclasses.dataclass(frozen=True)
class EngineEvent:
    """One processed event — what ``step()`` returns and trace sinks see."""

    t: float
    kind: EventKind
    job_id: int  # -1 when the event carries no job payload
    decision: bool  # True when this event opened a §IV-D decision point
    config_id: int
    queue_depth: int


@dataclasses.dataclass(frozen=True)
class SimSnapshot:
    """Read-only observable state of one device at a point in time.

    Everything here is observable by a real MIG controller (job counts and
    outstanding work by class, current partition, an in-flight repartition)
    plus the run accumulators the reward/telemetry layers read.  Policies
    and dispatchers consume this instead of groping simulator internals.
    """

    # lint: waive[VG001] schema/version class attrs only; no event-loop semantics changed
    SCHEMA_VERSION = 1  # bump when the field set below changes (repro.lint SD001/SD002)
    _schema_digest = "608ee2dd"  # pinned by repro.lint; regenerate via `python -m repro.lint`

    t: float
    config_id: int
    num_slices: int
    mig_enabled: bool
    repartitioning: bool
    repartition_remaining_min: float
    #: slot footprint of the in-flight repartition (0 when idle; the whole
    #: partition in drain mode).  The state-aware fleet dispatcher weights
    #: the repartition stall by this instead of writing off the device.
    stalled_slots: int
    #: slice indices of the current partition with a job running on them —
    #: what an opportunistic repartitioner checks before tearing an
    #: instance down (MIG-Serving-style displacement-free reconfiguration)
    occupied_slices: Tuple[int, ...]
    jobs_in_system: int
    active_jobs: int  # incl. depleted jobs not yet swept by completion
    queue_depth: int
    running: int
    completed_jobs: int
    busy_slots: float
    backlog_1g_min: float
    #: total depletion rate of the running set (1g-work/min): between events
    #: the backlog drains linearly at exactly this rate, so observers can
    #: project state to any instant before the next event without touching
    #: the simulation (repro.fleet.EngineDeviceState does)
    service_rate_1g_per_min: float
    inference_jobs: int
    inference_backlog_1g_min: float
    training_jobs: int
    training_backlog_1g_min: float
    energy_wh: float
    tardiness_integral: float
    preemptions: int
    repartitions: int
    #: free-slot geometry of the current partition (DESIGN.md §9): grid
    #: cells no occupied slice covers, the widest instance the device's
    #: table could still place there, and the fragmentation ratio
    #: ``1 - max_placeable/free`` (0 when nothing is free).  Forecast-style
    #: policies and the fragmentation-aware dispatcher read these instead
    #: of recomputing placement from ``occupied_slices``.
    free_slots: int = 0
    max_placeable_slots: int = 0
    fragmentation: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineSnapshot:
    """:class:`SimSnapshot` plus the engine-level queue state."""

    SCHEMA_VERSION = 1  # bump when the field set below changes (repro.lint SD001/SD002)
    _schema_digest = "12097506"

    sim: SimSnapshot
    next_event_time: Optional[float]
    pending_arrivals: int
    events_processed: int
    stream_open: bool
    awaiting_decision: bool


#: live telemetry consumer: called with every processed event
TraceSink = Callable[[EngineEvent], None]


class SimulationEngine:
    """The event loop of one :class:`MIGSimulator`, exposed step-wise.

    Parameters
    ----------
    sim:
        The simulator whose state this engine drives.  Constructing the
        engine **resets** the simulator's run state.
    policy:
        A :class:`RepartitionPolicy` consulted at decision points.  ``None``
        with ``interactive=False`` falls back to a static policy (config
        ``initial_config`` or 3, matching the historical ``run()`` default);
        ``None`` with ``interactive=True`` means the caller supplies every
        decision via :meth:`provide_decision`.
    jobs:
        Arrivals known up front (the one-shot path).  More can be fed later
        with :meth:`inject` while ``stream_open`` is True.
    stream_open:
        Declare that arrivals will be injected online.  Policy timers keep
        firing while the stream is open even if the system is momentarily
        empty; call :meth:`close_stream` when the producer is done.
    decision_hook:
        Fires ``(t, sim)`` at every decision point *before* the policy —
        observation-only (the EXPERIMENTS.md calibration analysis uses it).
    trace_sink:
        Receives every processed :class:`EngineEvent` (live telemetry).
    """

    def __init__(
        self,
        sim: "MIGSimulator",
        policy: Optional["RepartitionPolicy"] = None,
        *,
        initial_config: Optional[int] = None,
        jobs: Sequence[Job] = (),
        stream_open: bool = False,
        interactive: bool = False,
        decision_hook: Optional[Callable[[float, "MIGSimulator"], None]] = None,
        trace_sink: Optional[TraceSink] = None,
    ) -> None:
        if policy is None and not interactive:
            from repro.core.simulator import StaticPolicy

            policy = StaticPolicy(config_id=initial_config or 3)
        self.sim = sim
        self.policy = policy
        self.interactive = interactive
        self.decision_hook = decision_hook
        self.trace_sink = trace_sink
        self.stream_open = stream_open

        if initial_config is not None:
            cfg0, cfg0_src = initial_config, "initial_config override"
        elif policy is not None:
            cfg0 = policy.initial_config
            cfg0_src = f"policy {type(policy).__name__}.initial_config"
        else:
            cfg0, cfg0_src = 3, "engine default"
        # validate against the device's table up front: an A100-space
        # initial config (e.g. CallbackPolicy's default 2) on a smaller
        # device must fail here with a clear message, not as a bare
        # KeyError deep inside the first _config() lookup mid-run
        if cfg0 not in sim.configs:
            raise ValueError(
                f"initial config {cfg0} (from {cfg0_src}) is not in this "
                f"device's partition table (valid ids {sorted(sim.configs)}); "
                "pass a valid initial_config or wrap the policy in "
                "repro.fleet.DeviceAdaptedPolicy"
            )
        sim.reset(cfg0)

        self._seq = itertools.count()
        # (t, kind, seq, payload, version)
        self._heap: List[Tuple[float, int, int, int, int]] = []
        self._version = 0
        # pending policy-timer times; pruned on TIMER pop so multi-day
        # streaming runs don't grow memory with every timer ever scheduled
        self._timer_scheduled: set = set()
        self.events_processed = 0
        self._awaiting: Optional[Tuple[EventKind, int, bool]] = None
        # cancellation bookkeeping: ids cancelled before their arrival event
        # popped (the pop loop skips those), and every id ever cancelled
        self._cancelled_pending: set = set()
        self._cancelled_ids: set = set()
        # completed/cancelled jobs folded out by harvest_completed() — a
        # long-running service keeps memory bounded this way; result() is
        # the harvester's job once any jobs were folded out
        self._harvested = 0

        self._jobs_by_id: Dict[int, Job] = {}
        self.arrivals_pending = 0
        for job in jobs:
            self._register(job)
        self._schedule_policy_timer()
        self._push_followups()

    # ------------------------------------------------------------------
    # event queue primitives

    def _push(self, t: float, kind: EventKind, payload: int = -1, ver: int = -1) -> None:
        heapq.heappush(self._heap, (t, int(kind), next(self._seq), payload, ver))

    def _register(self, job: Job) -> None:
        if job.job_id in self._jobs_by_id:
            raise ValueError(
                f"cannot inject job {job.job_id} at sim time t={self.sim.t}: "
                f"that job id was already injected; submit each job under a "
                f"unique id (resubmissions after a crash must reuse the old "
                f"id only if the original was never acknowledged)"
            )
        self._jobs_by_id[job.job_id] = job
        self.arrivals_pending += 1
        self._push(job.arrival, EventKind.ARRIVAL, job.job_id)

    def inject(self, job: Job) -> None:
        """Feed one arrival into a running engine (online streaming).

        The arrival may not lie in the engine's past: events up to
        ``job.arrival`` must not have been processed yet.  Requires an open
        stream — one-shot engines (constructed with a preloaded job list and
        ``stream_open=False``) and engines whose producer already called
        :meth:`close_stream` refuse injections.
        """
        if not self.stream_open:
            raise RuntimeError(
                f"cannot inject job {job.job_id} at sim time t={self.sim.t}: "
                f"the arrival stream is closed; construct the engine with "
                f"stream_open=True and inject before close_stream()"
            )
        if job.arrival < self.sim.t - 1e-6:
            raise ValueError(
                f"cannot inject job {job.job_id} with arrival t={job.arrival} "
                f"into an engine already at sim time t={self.sim.t}: events up "
                f"to its arrival were already processed; re-stamp the arrival "
                f"to >= {self.sim.t} (a live service should stamp arrivals "
                f"with max(client time, last advance bound))"
            )
        self._register(job)

    def close_stream(self) -> None:
        """Declare the online arrival stream finished (see ``stream_open``)."""
        self.stream_open = False

    # ------------------------------------------------------------------
    # cancellation and manual reconfiguration (the service layer's ops)

    def cancel(self, job_id: int) -> str:
        """Remove a job from the system (service ``cancel`` op).

        Returns the disposition:

        * ``"unarrived"`` — the arrival was still pending; it will never
          enter the system (the queued ARRIVAL event is skipped on pop);
        * ``"dequeued"`` — the job was waiting unassigned; removed;
        * ``"preempted"`` — the job was running; it is preempted exactly like
          any other preemption (device and job preemption counters charged)
          and removed.  Energy/tardiness stop accruing from the current sim
          time: energy because the slice leaves the busy set, tardiness
          because the job leaves ``active`` (integration is exact up to
          ``sim.t`` already — event pops advance time before mutations).

        Unknown, completed, or already-cancelled job ids raise
        :class:`ValueError` naming the sim time, the job id, and the remedy.
        """
        if self._awaiting is not None:
            raise RuntimeError(
                f"cannot cancel job {job_id} at t={self.sim.t}: an interactive "
                "decision is pending; call provide_decision() first"
            )
        sim = self.sim
        job = self._jobs_by_id.get(job_id)
        if job is None or job_id in self._cancelled_ids:
            state = "already cancelled" if job is not None else "never injected"
            raise ValueError(
                f"cannot cancel job {job_id} at sim time t={sim.t}: "
                f"it was {state}; check `status` for the job's disposition "
                f"before cancelling"
            )
        if job_id in sim.active:
            was_running = job_id in sim.assignment
            if was_running:
                # the existing preemption path: a running job leaving the
                # assignment counts once on the device and on the job
                del sim.assignment[job_id]
                sim.preemptions += 1
                job.preemptions += 1
            del sim.active[job_id]
            disposition = "preempted" if was_running else "dequeued"
        elif job.completion is not None:
            raise ValueError(
                f"cannot cancel job {job_id} at sim time t={sim.t}: it "
                f"already completed at t={job.completion}; completed jobs "
                f"cannot be cancelled"
            )
        else:
            # arrival event still pending in the heap: mark it so the pop
            # loop skips it without opening a decision point
            self._cancelled_pending.add(job_id)
            self.arrivals_pending -= 1
            disposition = "unarrived"
        self._cancelled_ids.add(job_id)
        sim.cancelled.append(job)
        if sim._repartitioning_until is None:
            sim._reschedule()
            sim._complete_finished()
        # version-bump: a live completion/critical prediction may reference
        # the cancelled job (or a seat freed by it)
        self._push_followups()
        return disposition

    def reconfigure(self, config_id: int) -> bool:
        """Start a repartition to ``config_id`` now (service ``reconfigure``).

        The manual analogue of a policy decision: charges the same stall,
        follows the active ``repartition_mode``.  Returns False (no-op) when
        the device is already in that configuration.  Refuses while another
        repartition is in flight.
        """
        if self._awaiting is not None:
            raise RuntimeError(
                f"cannot reconfigure at t={self.sim.t}: an interactive "
                "decision is pending; call provide_decision() first"
            )
        sim = self.sim
        if sim._repartitioning_until is not None:
            raise RuntimeError(
                f"cannot reconfigure to {config_id} at sim time t={sim.t}: a "
                f"repartition to {sim._pending_config} is in flight until "
                f"t={sim._repartitioning_until}; retry after it completes"
            )
        if config_id == sim.partition.config_id:
            return False
        if config_id not in sim.configs:
            raise KeyError(
                f"cannot reconfigure to config {config_id}: not in this "
                f"device's table (valid ids {sorted(sim.configs)})"
            )
        sim._start_repartition(config_id)
        self._push(sim._repartitioning_until, EventKind.REPART_DONE)
        sim._reschedule()
        sim._complete_finished()
        self._push_followups()
        return True

    # ------------------------------------------------------------------
    # follow-up event scheduling (identical semantics to the old run() loop)

    def _push_followups(self) -> None:
        """Version-bump, then (re)schedule the earliest completion and the
        next critical-laxity crossing.  The bump invalidates every
        previously pushed completion/critical event, so only the newest
        prediction is ever acted on."""
        sim = self.sim
        self._version += 1
        if sim._repartitioning_until is not None:
            # mid-repartition: under partial mode jobs on surviving slices
            # keep running and may complete inside the 4 s window, so their
            # completion predictions must stay live.  No critical-laxity
            # follow-up: rescheduling is frozen until REPART_DONE (in drain
            # mode the assignment is empty and nothing is pushed — the
            # legacy event sequence, bit for bit).
            if sim.assignment:
                self._push_completion_followup()
            return
        self._push_completion_followup()
        crit = sim.scheduler.next_critical_time(
            sim.t, sim.partition, list(sim.active.values()), sim.assignment,
            sim.mig_enabled,
        )
        if crit is not None:
            self._push(crit, EventKind.CRITICAL, -1, self._version)

    def _push_completion_followup(self) -> None:
        """Push the earliest completion among running jobs (current version).

        Also the recovery path for a completion that fired early due to
        float accumulation: recomputing from current assignments converges
        to the true finish time instead of blindly re-pushing ``t + 1e-6``
        (which could burn the whole event budget on float-heavy workloads).
        """
        sim = self.sim
        best_t, best_id = math.inf, -1
        for jid, sl in sim.assignment.items():
            job = sim.active[jid]
            ft = job.finish_time_on(
                sim.t, sim.partition.slices[sl].slots, sim.mig_enabled
            )
            if ft < best_t:
                best_t, best_id = ft, jid
        if best_id >= 0 and math.isfinite(best_t):
            self._push(max(best_t, sim.t), EventKind.COMPLETION, best_id, self._version)

    def _schedule_policy_timer(self) -> None:
        # no more timers once the stream is closed, all arrivals are in,
        # and the queue is drained (a perpetual Day/Night boundary chain
        # would never terminate)
        if not self.stream_open and self.arrivals_pending == 0 and not self.sim.active:
            return
        if self.policy is None:
            return
        nt = self.policy.next_timer(self.sim.t)
        if nt is not None and nt > self.sim.t + _EPS and nt not in self._timer_scheduled:
            self._timer_scheduled.add(nt)
            self._push(nt, EventKind.TIMER)

    # ------------------------------------------------------------------
    # stepping

    @property
    def awaiting_decision(self) -> bool:
        """True when an interactive engine is paused at a decision point."""
        return self._awaiting is not None

    @property
    def awaiting_timer(self) -> bool:
        """True when the pending interactive decision point is a TIMER.

        Cadence-driven callers (``RepartitionEnv(decision_interval_min=...)``)
        use this to distinguish the policy-clock pauses they act on from the
        arrival/completion decision points they pass through.
        """
        return self._awaiting is not None and bool(self._awaiting[2])

    @property
    def finished(self) -> bool:
        """True when no events remain, none are pending, and none can come.

        A stream-open engine is never finished — it may merely be idle
        between injections; the producer must :meth:`close_stream` first.
        """
        return (
            not self._heap and self._awaiting is None and not self.stream_open
        )

    def next_event_time(self) -> Optional[float]:
        """Time of the earliest pending event (None when drained)."""
        return self._heap[0][0] if self._heap else None

    def step(self) -> Optional[EngineEvent]:
        """Process the next event; returns its record, or None when drained.

        In interactive mode the returned event has ``decision=True`` when
        the engine paused at a decision point — call
        :meth:`provide_decision` before stepping again.
        """
        return self._process_next(bound=None, inclusive=True)

    def run_until(self, t: float, *, inclusive: bool = True) -> int:
        """Process pending events up to ``t``; returns how many were run.

        ``inclusive=False`` stops *before* events at exactly ``t`` — the
        fleet dispatcher uses this to observe device state at ``t⁻``, the
        instant an arrival is about to be routed.  Stops early at a pending
        interactive decision.
        """
        n = 0
        while self._awaiting is None:
            if self._process_next(bound=t, inclusive=inclusive) is None:
                break
            n += 1
        return n

    def run_to_decision(self) -> bool:
        """Step until a decision point (True) or the queue drains (False)."""
        while self._awaiting is None:
            if self._process_next(bound=None, inclusive=True) is None:
                return False
        return True

    def drain(self) -> int:
        """Process every remaining event; returns how many were run."""
        n = 0
        while self._process_next(bound=None, inclusive=True) is not None:
            n += 1
        return n

    def _process_next(
        self, bound: Optional[float], inclusive: bool
    ) -> Optional[EngineEvent]:
        if self._awaiting is not None:
            raise RuntimeError(
                "decision pending at t="
                f"{self.sim.t}; call provide_decision() before stepping"
            )
        sim = self.sim
        while True:
            if not self._heap:
                return None
            t0 = self._heap[0][0]
            if bound is not None and (t0 > bound if inclusive else t0 >= bound):
                return None
            self.events_processed += 1
            if self.events_processed > sim.max_events:
                raise RuntimeError(
                    "event budget exceeded — likely a scheduling livelock"
                )
            ev_t, kind, _, payload, ver = heapq.heappop(self._heap)
            kind = EventKind(kind)
            if kind in (EventKind.COMPLETION, EventKind.CRITICAL) and ver != self._version:
                continue  # stale prediction, superseded by a later version
            if kind == EventKind.ARRIVAL and payload in self._cancelled_pending:
                # cancelled before arrival: the event is dead — skip it
                # without advancing time or opening a decision point
                self._cancelled_pending.discard(payload)
                continue
            break

        sim._advance(ev_t)
        if kind == EventKind.ARRIVAL:
            job = self._jobs_by_id[payload]
            sim.active[job.job_id] = job
            self.arrivals_pending -= 1
            return self._open_decision(kind, payload, timer=False)
        if kind == EventKind.COMPLETION:
            finished = sim._complete_finished()
            if not finished:
                # numerical race: the predicted finish undershot the float
                # depletion — recompute from current assignments rather
                # than re-pushing t + 1e-6 forever
                self._push_completion_followup()
                return self._emit(kind, payload, decision=False)
            return self._open_decision(kind, payload, timer=False)
        if kind == EventKind.CRITICAL:
            for job in sim.queue_snapshot():
                lax = sim.scheduler.job_laxity(sim.t, sim.partition, job, sim.mig_enabled)
                if (
                    lax <= sim.scheduler.critical_laxity_threshold + 1e-6
                    and job.critical_events < sim.scheduler.max_critical_preemptions
                ):
                    job.critical_events += 1
            sim._reschedule()
            sim._complete_finished()
            self._push_followups()
            return self._emit(kind, payload, decision=False)
        if kind == EventKind.REPART_DONE:
            sim._finish_repartition()
            sim._reschedule()
            sim._complete_finished()
            self._push_followups()
            return self._emit(kind, payload, decision=False)
        # TIMER
        self._timer_scheduled = {x for x in self._timer_scheduled if x > ev_t}
        return self._open_decision(kind, payload, timer=True)

    # ------------------------------------------------------------------
    # decision points

    def _open_decision(self, kind: EventKind, payload: int, timer: bool) -> EngineEvent:
        sim = self.sim
        if sim._repartitioning_until is not None:
            # the GPU is blocked mid-repartition: no decision point, but the
            # event still reschedules state exactly as the old loop did
            return self._finish_event(kind, payload, timer, decision=False)
        if self.decision_hook is not None:
            self.decision_hook(sim.t, sim)
        if self.interactive:
            self._awaiting = (kind, payload, timer)
            return self._emit(kind, payload, decision=True)
        choice = self.policy.decide(sim.t, sim) if self.policy is not None else None
        return self._apply_decision(kind, payload, timer, choice)

    def provide_decision(self, choice: Optional[int]) -> EngineEvent:
        """Supply the pending interactive decision and resume the event.

        ``choice`` is a config id to repartition to, or ``None`` to stay —
        the same contract as :meth:`RepartitionPolicy.decide`.
        """
        if self._awaiting is None:
            raise RuntimeError("no decision pending")
        kind, payload, timer = self._awaiting
        self._awaiting = None
        return self._apply_decision(kind, payload, timer, choice)

    def _apply_decision(
        self, kind: EventKind, payload: int, timer: bool, choice: Optional[int]
    ) -> EngineEvent:
        sim = self.sim
        if choice is not None and choice != sim.partition.config_id:
            if choice not in sim.configs:
                raise KeyError(
                    f"policy chose config {choice}, not in this device's "
                    f"table (valid ids {sorted(sim.configs)})"
                )
            sim._start_repartition(choice)
            self._push(sim._repartitioning_until, EventKind.REPART_DONE)
        return self._finish_event(kind, payload, timer, decision=True)

    def _finish_event(
        self, kind: EventKind, payload: int, timer: bool, decision: bool
    ) -> EngineEvent:
        sim = self.sim
        sim._reschedule()
        sim._complete_finished()
        if timer:
            self._schedule_policy_timer()
        self._push_followups()
        return self._emit(kind, payload, decision=decision)

    def _emit(self, kind: EventKind, payload: int, decision: bool) -> EngineEvent:
        sim = self.sim
        ev = EngineEvent(
            t=sim.t,
            kind=kind,
            job_id=payload,
            decision=decision,
            config_id=sim.partition.config_id,
            queue_depth=max(len(sim.active) - len(sim.assignment), 0),
        )
        if self.trace_sink is not None:
            self.trace_sink(ev)
        return ev

    # ------------------------------------------------------------------
    # state capture / restore (service checkpoints; docs/SERVICE.md)

    def __getstate__(self) -> dict:
        """Pickle support: the full engine state minus the live callables.

        ``trace_sink`` and ``decision_hook`` are process-local observers, not
        simulation state — they are dropped and must be reattached after
        restore.  Everything else (heap, versions, the ``itertools.count``
        sequence, simulator numerics, policy state) round-trips exactly:
        a restored engine continues bit-identically to the original
        (pinned by tests/test_service.py).
        """
        state = self.__dict__.copy()
        state["trace_sink"] = None
        state["decision_hook"] = None
        return state

    def to_snapshot_bytes(self) -> bytes:
        """Serialize the engine (and its simulator/policy) for checkpointing.

        Raises a clear error for unpicklable policies (e.g. a
        :class:`CallbackPolicy` wrapping a closure): the service layer only
        supports registry policies, which are all picklable.
        """
        import pickle

        try:
            return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            raise ValueError(
                f"engine state is not picklable ({e}); checkpointing "
                "requires a picklable policy/scheduler — CallbackPolicy "
                "closures are not; use a registry policy "
                "(repro.service.make_policy)"
            ) from e

    @classmethod
    def from_snapshot_bytes(
        cls,
        blob: bytes,
        *,
        trace_sink: Optional[TraceSink] = None,
        decision_hook: Optional[Callable[[float, "MIGSimulator"], None]] = None,
    ) -> "SimulationEngine":
        """Restore an engine from :meth:`to_snapshot_bytes` output.

        The restored engine resumes mid-run, bit-identically — the recovery
        contract the service's crash tests pin.  Observer callables are not
        part of the snapshot; pass them here to reattach.
        """
        import pickle

        engine = pickle.loads(blob)
        if not isinstance(engine, cls):
            raise ValueError(
                f"snapshot blob holds a {type(engine).__name__}, "
                f"not a {cls.__name__}"
            )
        engine.trace_sink = trace_sink
        engine.decision_hook = decision_hook
        return engine

    def harvest_completed(self) -> Tuple[List[Job], List[Job]]:
        """Remove and return (completed, cancelled) jobs accumulated so far.

        A long-running service folds these into running aggregates
        (:class:`repro.service.ServiceStats`) so memory stays bounded over
        multi-day streams; the engine's own :meth:`result` becomes
        unavailable once any jobs were folded out (it would silently
        under-count) — the harvester owns the final result from then on.
        """
        sim = self.sim
        done, cancelled = sim.completed, sim.cancelled
        sim.completed, sim.cancelled = [], []
        for job in done:
            del self._jobs_by_id[job.job_id]
        for job in cancelled:
            del self._jobs_by_id[job.job_id]
            self._cancelled_ids.discard(job.job_id)
        self._harvested += len(done) + len(cancelled)
        return done, cancelled

    # ------------------------------------------------------------------
    # observation / results

    def job_disposition(self, job_id: int) -> Optional[str]:
        """Where a job currently is, or None if unknown (or harvested).

        One of ``"pending"`` (arrival event still queued), ``"queued"``
        (arrived, unassigned), ``"running"``, ``"completed"``, or
        ``"cancelled"`` — the service's ``status`` op reads this.
        """
        job = self._jobs_by_id.get(job_id)
        if job is None:
            return None
        if job_id in self._cancelled_ids:
            return "cancelled"
        if job_id in self.sim.assignment:
            return "running"
        if job_id in self.sim.active:
            return "queued"
        if job.completion is not None:
            return "completed"
        return "pending"

    def snapshot(self) -> EngineSnapshot:
        """Read-only view of device + queue state (see :class:`EngineSnapshot`)."""
        return EngineSnapshot(
            sim=self.sim.snapshot(),
            next_event_time=self.next_event_time(),
            pending_arrivals=self.arrivals_pending,
            events_processed=self.events_processed,
            stream_open=self.stream_open,
            awaiting_decision=self.awaiting_decision,
        )

    def result(self) -> SimResult:
        """The run's :class:`SimResult`; only valid once :attr:`finished`."""
        if not self.finished:
            raise RuntimeError(
                "simulation still has pending events (or an open stream); "
                "close_stream() and drain() it first"
            )
        if self._harvested:
            raise RuntimeError(
                f"{self._harvested} jobs were folded out by "
                "harvest_completed(); the harvester owns the final result "
                "(repro.service.ServiceStats.result)"
            )
        sim = self.sim
        if sim.active:
            raise RuntimeError(
                f"simulation ended with {len(sim.active)} unfinished jobs"
            )
        m = max(len(sim.completed), 1)
        total_tard = sum(j.tardiness() for j in sim.completed)
        tenant_acc: Dict[str, List[float]] = {}
        for j in sim.completed:
            if j.tenant is None:
                continue
            acc = tenant_acc.setdefault(j.tenant, [0, 0, 0.0])
            acc[0] += 1
            acc[1] += 1 if j.slo_attained() else 0
            acc[2] += j.latency()
        tenants = {
            name: TenantSLOStats(
                jobs=int(acc[0]), attained=int(acc[1]), latency_sum_min=acc[2]
            )
            for name, acc in sorted(tenant_acc.items())
        }
        extra = {
            "makespan_min": sim.t,
            "tardiness_integral": sim.tardiness_integral,
        }
        # only runs with cancellations report them: batch baselines stay
        # byte-identical (the key is absent, not zero)
        if sim.cancelled:
            extra["cancelled_jobs"] = float(len(sim.cancelled))
        return SimResult(
            energy_wh=sim.energy_wh,
            avg_tardiness=total_tard / m,
            num_jobs=len(sim.completed),
            total_tardiness=total_tard,
            preemptions=sim.preemptions,
            repartitions=sim.repartitions,
            max_tardiness=max((j.tardiness() for j in sim.completed), default=0.0),
            deadline_misses=sum(1 for j in sim.completed if j.tardiness() > 1e-9),
            busy_slot_minutes=sim.busy_slot_minutes,
            extra=extra,
            tenants=tenants,
        )


def snapshot_of(sim: "MIGSimulator") -> SimSnapshot:
    """Build the :class:`SimSnapshot` for a simulator's current state."""
    n_inf = n_trn = 0
    w_inf = w_trn = 0.0
    for j in sim.active.values():
        if j.done:
            continue
        if j.kind == JobKind.TRAINING:
            n_trn += 1
            w_trn += j.remaining
        else:
            n_inf += 1
            w_inf += j.remaining
    service_rate = sum(
        sim.active[jid].rate_on(sim.partition.slices[sl].slots, sim.mig_enabled)
        for jid, sl in sim.assignment.items()
    )
    repart_until = sim._repartitioning_until
    occupied = tuple(sorted(set(sim.assignment.values())))
    geometry = free_slot_geometry(
        sim.partition,
        occupied,
        total_slots=sim.grid_slots,
        slice_sizes=sim.slice_sizes,
    )
    return SimSnapshot(
        t=sim.t,
        config_id=sim.partition.config_id,
        num_slices=sim.partition.num_slices,
        mig_enabled=sim.mig_enabled,
        repartitioning=repart_until is not None,
        repartition_remaining_min=(
            max(repart_until - sim.t, 0.0) if repart_until is not None else 0.0
        ),
        stalled_slots=sim.stalled_slots,
        occupied_slices=occupied,
        jobs_in_system=n_inf + n_trn,
        active_jobs=len(sim.active),
        queue_depth=max(len(sim.active) - len(sim.assignment), 0),
        running=len(sim.assignment),
        completed_jobs=len(sim.completed),
        busy_slots=sim.busy_slots,
        backlog_1g_min=w_inf + w_trn,
        service_rate_1g_per_min=service_rate,
        inference_jobs=n_inf,
        inference_backlog_1g_min=w_inf,
        training_jobs=n_trn,
        training_backlog_1g_min=w_trn,
        energy_wh=sim.energy_wh,
        tardiness_integral=sim.tardiness_integral,
        preemptions=sim.preemptions,
        repartitions=sim.repartitions,
        free_slots=geometry.free_slots,
        max_placeable_slots=geometry.max_placeable_slots,
        fragmentation=geometry.fragmentation,
    )
