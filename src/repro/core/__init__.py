"""The paper's primary contribution: energy-efficient MIG scheduling with
dynamic repartitioning (Lipe et al., CCGrid 2025), reproduced in JAX.

Layers:
* :mod:`repro.core.slices`     — Fig. 1 slice/partition model (12 configs)
* :mod:`repro.core.power`      — Fig. 3 saturating power curves
* :mod:`repro.core.jobs`       — jobs with linear/capped/sublinear elasticity
* :mod:`repro.core.workload`   — §V-A diurnal Poisson workload generator
* :mod:`repro.core.scenarios`  — named workload scenario registry
* :mod:`repro.core.metrics`    — §IV-A ET multi-objective metric
* :mod:`repro.core.schedulers` — §IV-C EDF-FS / EDF-SS / LLF / LALF
* :mod:`repro.core.engine`     — steppable event engine (step/inject/snapshot)
* :mod:`repro.core.simulator`  — event-driven preemptive simulator (numeric state + policies)
* :mod:`repro.core.rl`         — §IV-D DQN dynamic repartitioning (pure JAX)
"""

from repro.core.engine import (
    EngineEvent,
    EngineSnapshot,
    EventKind,
    SimSnapshot,
    SimulationEngine,
)

from repro.core.slices import MIG_CONFIGS, NUM_CONFIGS, Partition, SliceType, config
from repro.core.power import A100_250W, TPU_V5E_POD, PowerModel
from repro.core.jobs import Elasticity, ElasticityClass, Job, JobKind
from repro.core.workload import WorkloadSpec, generate_jobs, arrival_rate
from repro.core.scenarios import SCENARIOS, generate_scenario, scenario_names
from repro.core.metrics import SimResult, et_metric, et_scale_factor, et_table
from repro.core.schedulers import (
    SCHEDULERS,
    EDFFastestSlice,
    EDFSlowestSlice,
    LeastAverageLaxityFirst,
    LeastLaxityFirst,
    Scheduler,
    make_scheduler,
)
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    StaticPolicy,
    REPARTITION_PENALTY_MIN,
)

__all__ = [
    "EngineEvent",
    "EngineSnapshot",
    "EventKind",
    "SimSnapshot",
    "SimulationEngine",
    "MIG_CONFIGS",
    "NUM_CONFIGS",
    "Partition",
    "SliceType",
    "config",
    "A100_250W",
    "TPU_V5E_POD",
    "PowerModel",
    "Elasticity",
    "ElasticityClass",
    "Job",
    "JobKind",
    "WorkloadSpec",
    "generate_jobs",
    "arrival_rate",
    "SCENARIOS",
    "generate_scenario",
    "scenario_names",
    "SimResult",
    "et_metric",
    "et_scale_factor",
    "et_table",
    "SCHEDULERS",
    "EDFFastestSlice",
    "EDFSlowestSlice",
    "LeastAverageLaxityFirst",
    "LeastLaxityFirst",
    "Scheduler",
    "make_scheduler",
    "DayNightPolicy",
    "MIGSimulator",
    "NoMIGPolicy",
    "StaticPolicy",
    "REPARTITION_PENALTY_MIN",
]
