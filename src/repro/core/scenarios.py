"""Named workload scenarios: a registry of job-stream generators.

The paper evaluates a single diurnal Alibaba-derived trace (§V-A, Fig. 5).
Production fleets see far more shapes; this module names each shape, gives it
a deterministic generator, and registers it so the simulator, the RL
environment, and the sweep grids (``scenario_matrix``, ``fleet_scaling``) can
all request "a day of traffic" by name:

* ``paper-diurnal``         — the §V-A non-homogeneous Poisson workload;
  at ``load_scale=1.0`` it is bit-identical to
  ``generate_jobs(WorkloadSpec(), seed)`` (pinned by tests);
* ``trace-scaled``          — the diurnal trace with its rate multiplied by
  ``load_scale`` (capacity-planning sweeps);
* ``bursty-mmpp``           — a two-state Markov-modulated Poisson process on
  top of the diurnal envelope: exponential sojourns in burst/quiet states
  multiply the rate by ``burst_mult``/``quiet_mult``;
* ``heavy-tail-lognormal``  — diurnal arrivals with lognormal durations
  (matched means, heavier right tail than Exp/Uniform);
* ``heavy-tail-pareto``     — diurnal arrivals with Pareto(Lomax) durations,
  capped at ``cap_min`` minutes so a single draw cannot dominate a day;
* ``weekend-flat``          — a flat low-rate day (no diurnal ramp).

Every generator is a pure function of ``(seed, **kwargs)``; defaults are
recorded on the registry entry so sweep cells can resolve them into the cell
dict (the content hash must capture the values the simulation saw).  Scenario
*semantics* changes are simulator-semantics changes: bump ``SIM_VERSION``
(see CONTRIBUTING.md).
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Tuple

import numpy as np

from repro.core.jobs import Job, JobKind
from repro.core.workload import (
    DIURNAL_RATE_PER_MIN,
    MINUTES_PER_DAY,
    WorkloadSpec,
    arrival_rate,
    generate_jobs,
    jobs_from_arrivals,
    sample_poisson_arrivals,
)

__all__ = [
    "Scenario",
    "SCENARIOS",
    "register_scenario",
    "scenario_names",
    "resolve_scenario_kwargs",
    "generate_scenario",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A registered workload generator with its documented knob defaults."""

    name: str
    doc: str
    defaults: Mapping[str, Any]
    generate: Callable[..., List[Job]]


SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(name: str, doc: str, **defaults: Any):
    """Decorator registering ``fn(seed, **kwargs) -> List[Job]`` under ``name``."""

    def deco(fn: Callable[..., List[Job]]) -> Callable[..., List[Job]]:
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = Scenario(name=name, doc=doc, defaults=dict(defaults), generate=fn)
        return fn

    return deco


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def resolve_scenario_kwargs(name: str, kwargs: Mapping[str, Any] | None = None) -> Dict[str, Any]:
    """Merge ``kwargs`` over the scenario's defaults; reject unknown knobs.

    Sweep cells store the *resolved* kwargs so a changed default can never
    alias a stale cache entry (same convention as ``workload_to_dict``).
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; registered: {list(scenario_names())}")
    sc = SCENARIOS[name]
    merged = dict(sc.defaults)
    for k, v in dict(kwargs or {}).items():
        if k not in merged:
            raise KeyError(
                f"scenario {name!r} has no knob {k!r}; knobs: {sorted(merged)}"
            )
        merged[k] = v
    return merged


def generate_scenario(name: str, seed: int, **kwargs: Any) -> List[Job]:
    """Generate the named scenario's job stream (sorted by arrival)."""
    resolved = resolve_scenario_kwargs(name, kwargs)
    return SCENARIOS[name].generate(seed=seed, **resolved)


# ----------------------------------------------------------------------
# generators


def _diurnal_jobs(
    seed: int,
    load_scale: float,
    horizon_min: float,
    duration_sampler=None,
) -> List[Job]:
    """Diurnal arrivals at ``load_scale`` x the Fig. 5 rate.

    At ``load_scale == 1.0`` with default samplers the RNG draw sequence
    equals :func:`generate_jobs` exactly (rate*1.0 and lam_max*1.0 are
    float-identical), preserving bit-identity with the paper path.
    """
    spec = WorkloadSpec(horizon_min=horizon_min)
    rng = np.random.default_rng(seed)
    lam_max = max(DIURNAL_RATE_PER_MIN) * load_scale
    arrivals = sample_poisson_arrivals(
        horizon_min, lambda t: arrival_rate(t) * load_scale, lam_max, rng
    )
    return jobs_from_arrivals(spec, arrivals, rng, duration_sampler)


@register_scenario(
    "paper-diurnal",
    "§V-A diurnal Alibaba-derived trace (Fig. 5); the paper's workload",
    load_scale=1.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _paper_diurnal(seed: int, load_scale: float, horizon_min: float) -> List[Job]:
    if load_scale == 1.0:
        # the exact legacy path — shared cache entries, shared baselines
        return generate_jobs(WorkloadSpec(horizon_min=horizon_min), seed)
    return _diurnal_jobs(seed, load_scale, horizon_min)


@register_scenario(
    "trace-scaled",
    "diurnal trace with the arrival rate multiplied by load_scale",
    load_scale=2.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _trace_scaled(seed: int, load_scale: float, horizon_min: float) -> List[Job]:
    return _diurnal_jobs(seed, load_scale, horizon_min)


@register_scenario(
    "bursty-mmpp",
    "two-state Markov-modulated Poisson bursts over the diurnal envelope",
    burst_mult=3.0,
    quiet_mult=0.5,
    mean_burst_min=20.0,
    mean_quiet_min=120.0,
    load_scale=1.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _bursty_mmpp(
    seed: int,
    burst_mult: float,
    quiet_mult: float,
    mean_burst_min: float,
    mean_quiet_min: float,
    load_scale: float,
    horizon_min: float,
) -> List[Job]:
    spec = WorkloadSpec(horizon_min=horizon_min)
    rng = np.random.default_rng(seed)
    # sample the modulating chain first (alternating quiet/burst sojourns) so
    # the thinning pass sees a fixed rate trajectory
    boundaries: List[float] = [0.0]
    mults: List[float] = []
    in_burst = False
    t = 0.0
    while t < horizon_min:
        mean = mean_burst_min if in_burst else mean_quiet_min
        mults.append(burst_mult if in_burst else quiet_mult)
        t += rng.exponential(mean)
        boundaries.append(t)
        in_burst = not in_burst

    def rate(at: float) -> float:
        i = bisect.bisect_right(boundaries, at) - 1
        return arrival_rate(at) * mults[min(i, len(mults) - 1)] * load_scale

    lam_max = max(DIURNAL_RATE_PER_MIN) * max(burst_mult, quiet_mult) * load_scale
    arrivals = sample_poisson_arrivals(horizon_min, rate, lam_max, rng)
    return jobs_from_arrivals(spec, arrivals, rng)


def _lognormal_sampler(
    inf_mean: float, inf_sigma: float, train_mean: float, train_sigma: float, cap_min: float
):
    # mu chosen so E[lognormal] matches the target mean: mean = exp(mu + s^2/2)
    mu_inf = math.log(inf_mean) - inf_sigma**2 / 2.0
    mu_train = math.log(train_mean) - train_sigma**2 / 2.0

    def sample(kind: JobKind, rng: np.random.Generator) -> float:
        if kind is JobKind.INFERENCE:
            d = rng.lognormal(mu_inf, inf_sigma)
        else:
            d = rng.lognormal(mu_train, train_sigma)
        return min(max(d, 1.0 / 60.0), cap_min)

    return sample


@register_scenario(
    "heavy-tail-lognormal",
    "diurnal arrivals; lognormal durations with matched means, heavy tail",
    inf_mean=3.0,
    inf_sigma=1.2,
    train_mean=25.0,
    train_sigma=0.8,
    cap_min=480.0,
    load_scale=1.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _heavy_lognormal(
    seed: int,
    inf_mean: float,
    inf_sigma: float,
    train_mean: float,
    train_sigma: float,
    cap_min: float,
    load_scale: float,
    horizon_min: float,
) -> List[Job]:
    sampler = _lognormal_sampler(inf_mean, inf_sigma, train_mean, train_sigma, cap_min)
    return _diurnal_jobs(seed, load_scale, horizon_min, duration_sampler=sampler)


def _pareto_sampler(
    inf_xm: float, inf_alpha: float, train_xm: float, train_alpha: float, cap_min: float
):
    # Lomax + shift: d = xm * (1 + Pareto(alpha)); mean = xm * alpha/(alpha-1)
    def sample(kind: JobKind, rng: np.random.Generator) -> float:
        if kind is JobKind.INFERENCE:
            d = inf_xm * (1.0 + rng.pareto(inf_alpha))
        else:
            d = train_xm * (1.0 + rng.pareto(train_alpha))
        return min(max(d, 1.0 / 60.0), cap_min)

    return sample


@register_scenario(
    "heavy-tail-pareto",
    "diurnal arrivals; Pareto durations (capped) — the heaviest tail",
    inf_xm=1.0,
    inf_alpha=1.5,
    train_xm=10.0,
    train_alpha=1.8,
    cap_min=480.0,
    load_scale=1.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _heavy_pareto(
    seed: int,
    inf_xm: float,
    inf_alpha: float,
    train_xm: float,
    train_alpha: float,
    cap_min: float,
    load_scale: float,
    horizon_min: float,
) -> List[Job]:
    sampler = _pareto_sampler(inf_xm, inf_alpha, train_xm, train_alpha, cap_min)
    return _diurnal_jobs(seed, load_scale, horizon_min, duration_sampler=sampler)


@register_scenario(
    "weekend-flat",
    "flat low-rate day: no diurnal ramp (weekend/maintenance traffic)",
    rate_per_min=0.15,
    load_scale=1.0,
    horizon_min=float(MINUTES_PER_DAY),
)
def _weekend_flat(
    seed: int, rate_per_min: float, load_scale: float, horizon_min: float
) -> List[Job]:
    spec = WorkloadSpec(horizon_min=horizon_min, constant_rate=rate_per_min * load_scale)
    return generate_jobs(spec, seed)


# registers "multi-tenant-serving" (latency-SLO tenant streams over the
# model configs); imported last so the registry above exists when it runs
import repro.core.serving  # noqa: E402,F401  (registration side effect)
