"""Training / evaluation loops for the repartitioning DQN (paper §IV-D, §V-C).

Each episode is one simulated 24-hour day of the diurnal workload (Fig. 5)
scheduled by (restricted) EDF-SS inside the currently selected configuration.
Training drives the incremental :class:`~repro.core.rl.env.RepartitionEnv`
(``reset()`` / ``step(action)`` over the steppable simulation engine) — the
old pattern of threading a live agent through a full simulator run as a
policy is gone, and with it the full-run ``decision_hook`` plumbing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.core.metrics import SimResult
from repro.core.rl.agent import NStepAccumulator
from repro.core.rl.dqn import DQNConfig, DQNLearner
from repro.core.rl.env import FEATURE_DIM, RepartitionEnv, RewardWeights
from repro.core.workload import WorkloadSpec

__all__ = ["TrainStats", "train_dqn", "evaluate_policy", "evaluate_policy_fleet"]


@dataclasses.dataclass
class TrainStats:
    episode_rewards: List[float]
    episode_et_proxy: List[float]
    losses: List[float]
    episodes: int
    wall_seconds: float
    env_steps: int = 0  # total decisions taken (the bench_rl.py currency)


def train_dqn(
    num_episodes: int = 200,
    spec: Optional[WorkloadSpec] = None,
    scheduler_name: str = "EDF-SS",
    dqn_config: Optional[DQNConfig] = None,
    rewards: RewardWeights = RewardWeights(),
    seed: int = 0,
    verbose: bool = False,
    guide=None,
    guide_episodes: int = 0,
    scenario: Optional[str] = None,
    scenario_kwargs: Optional[Dict] = None,
    backend: str = "host",
    train_config=None,
    decision_interval_min: Optional[float] = None,
) -> tuple:
    """Train the repartitioning DQN; returns (learner, TrainStats).

    ``decision_interval_min`` puts the host env on a fixed decision cadence
    (decisions at multiples of the interval, configuration held in
    between) — the same decision distribution the batched backend uses,
    so host-vs-batched comparisons (scripts/bench_rl.py, the parity tests)
    run equal semantics.  Default ``None`` keeps the native event cadence.

    ``guide``/``guide_episodes``: optional demonstration warm-start — the
    first episodes act with the guide policy while the learner trains on the
    resulting transitions (beyond-paper; cuts random-exploration burn-in).

    ``scenario`` draws episode workloads from the named registry entry
    (:mod:`repro.core.scenarios`) instead of ``spec`` — training against
    bursty or heavy-tailed days uses the same loop.

    ``backend="batched"`` dispatches to the fused on-device trainer
    (:func:`repro.core.rl.batched_train.train_dqn_batched`): B rollouts and
    the learner update advance inside one jitted scan, decisions happen on
    a fixed cadence, and only EDF-FS is available.  ``train_config`` (a
    :class:`~repro.core.rl.batched_train.BatchedTrainConfig`) carries the
    batch-shape knobs; ``guide`` is host-only.
    """
    if backend == "batched":
        from repro.core.rl.batched_train import train_dqn_batched

        if guide is not None:
            raise ValueError("guide warm-start is host-backend only")
        if scheduler_name != "EDF-FS":
            raise ValueError(
                "the batched backend schedules with EDF-FS only; pass "
                "scheduler_name='EDF-FS' explicitly (host default is EDF-SS)"
            )
        from repro.core.rl.batched_train import BatchedTrainConfig

        tcfg = train_config or BatchedTrainConfig()
        if scenario is not None:
            merged = dict(tcfg.scenario_kwargs or {})
            merged.update(scenario_kwargs or {})
            tcfg = dataclasses.replace(
                tcfg, scenarios=(scenario,), scenario_kwargs=merged or None
            )
        if decision_interval_min is not None:
            tcfg = dataclasses.replace(
                tcfg, decision_interval_min=decision_interval_min
            )
        return train_dqn_batched(
            num_episodes=num_episodes,
            dqn_config=dqn_config,
            train_config=tcfg,
            rewards=rewards,
            seed=seed,
            verbose=verbose,
        )
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r} (host | batched)")
    spec = spec or WorkloadSpec()
    cfg = dqn_config or DQNConfig(state_dim=FEATURE_DIM, seed=seed)
    learner = DQNLearner(cfg)
    env = RepartitionEnv(
        scheduler_name=scheduler_name,
        spec=spec,
        scenario=scenario,
        scenario_kwargs=scenario_kwargs,
        rewards=rewards,
        decision_interval_min=decision_interval_min,
    )
    nstep = NStepAccumulator(cfg.n_step, cfg.gamma)

    t0 = time.time()  # lint: waive[DT002] wall-seconds telemetry only
    ep_rewards: List[float] = []
    ep_proxy: List[float] = []
    all_losses: List[float] = []
    env_steps = 0
    for ep in range(num_episodes):
        ep_seed = seed * 100_003 + ep
        epsilon = learner.epsilon(ep)
        use_guide = guide is not None and ep < guide_episodes
        if use_guide and hasattr(guide, "reset"):
            # stateful demonstration policies (e.g. the predictive
            # ForecastPolicy: EWMA bias, dwell clocks) start each episode
            # clean, exactly as a fresh simulated day would see them
            guide.reset()
        obs = env.reset(seed=ep_seed)
        nstep.clear()
        ep_reward = 0.0
        ep_losses: List[float] = []
        over = env.done  # degenerate empty episode (no decision points)
        while not over:
            if use_guide:
                choice = guide.decide(env.sim.t, env.sim)
                action = (
                    (choice - 1)
                    if choice is not None
                    else (env.sim.partition.config_id - 1)
                )
            else:
                action = learner.act(obs, epsilon)
            next_obs, r, terminated, truncated, _ = env.step(action)
            ep_reward += r
            env_steps += 1
            nstep.push(learner, obs, action, r, next_obs, terminated or truncated)
            loss = learner.maybe_train(1)
            if loss == loss:  # not NaN (returned before the buffer warms up)
                ep_losses.append(loss)
            obs = next_obs
            over = terminated or truncated
        result = env.result()
        ep_rewards.append(ep_reward)
        proxy = rewards.a * result.energy_wh + result.avg_tardiness
        ep_proxy.append(proxy)
        all_losses.extend(ep_losses)
        if verbose and (ep + 1) % 10 == 0:  # pragma: no cover
            print(
                f"episode {ep + 1}/{num_episodes} eps={epsilon:.2f} "
                f"reward={ep_reward:.2f} proxy={proxy:.2f} "
                f"repart={result.repartitions}"
            )
    stats = TrainStats(
        episode_rewards=ep_rewards,
        episode_et_proxy=ep_proxy,
        losses=all_losses,
        episodes=num_episodes,
        wall_seconds=time.time() - t0,  # lint: waive[DT002] wall telemetry only
        env_steps=env_steps,
    )
    return learner, stats


def evaluate_policy(
    policy_factory,
    num_iterations: int = 50,
    spec: Optional[WorkloadSpec] = None,
    scheduler_name: str = "EDF-SS",
    seed: int = 10_000,
    mig_enabled: bool = True,
    workers: int = 0,
    scenario: Optional[str] = None,
    scenario_kwargs: Optional[Dict] = None,
) -> List[SimResult]:
    """Run ``num_iterations`` independent day simulations under a policy.

    ``policy_factory`` is either a zero-arg callable returning a
    RepartitionPolicy (fresh DQN greedy agents keep per-episode state), or a
    registered sweep policy — a name like ``"heuristic"`` or a
    ``(name, kwargs)`` tuple, e.g. ``("dqn", {"params_path": ...})``.

    The runs go through the sweep engine (:mod:`repro.sweep`): registered
    policies are memoized on disk and fan out over ``workers`` processes;
    ad-hoc callables run inline and uncached (a closure over live learner
    state is neither picklable nor content-addressable).  ``scenario``
    swaps the workload for a registered scenario (bursty, heavy-tailed, ...).
    """
    from repro.sweep import make_cell, make_scenario_cell, result_to_sim_result, run_cells

    spec = spec or WorkloadSpec()
    policy_name, policy_kwargs, factory = _resolve_policy(policy_factory)
    cells = []
    for it in range(num_iterations):
        if scenario is not None:
            cells.append(
                make_scenario_cell(
                    experiment="evaluate_policy",
                    group=policy_name,
                    scheduler=scheduler_name,
                    scenario=scenario,
                    scenario_kwargs=scenario_kwargs,
                    seed=seed + it,
                    policy=policy_name,
                    policy_kwargs=policy_kwargs,
                    mig_enabled=mig_enabled,
                )
            )
        else:
            cells.append(
                make_cell(
                    experiment="evaluate_policy",
                    group=policy_name,
                    scheduler=scheduler_name,
                    workload=spec,
                    seed=seed + it,
                    policy=policy_name,
                    policy_kwargs=policy_kwargs,
                    mig_enabled=mig_enabled,
                )
            )
    outcome = run_cells(
        "evaluate_policy",
        cells,
        workers=workers,
        cache=factory is None,
        artifacts_dir=None,
        policy_factory=factory,
    )
    return [result_to_sim_result(r) for r in outcome.results]


def _resolve_policy(policy_factory):
    """(name, kwargs, ad_hoc_factory) from the evaluate_policy spec forms."""
    if isinstance(policy_factory, str):
        return policy_factory, {}, None
    if isinstance(policy_factory, tuple):
        name, kwargs = policy_factory
        return name, kwargs, None
    return "static", {}, policy_factory  # placeholder name; factory wins


def evaluate_policy_fleet(
    policy_factory,
    profiles: Sequence[str] = ("a100-250w",),
    dispatcher: str = "round-robin",
    num_iterations: int = 20,
    scheduler_name: str = "EDF-SS",
    scenario: str = "paper-diurnal",
    scenario_kwargs: Optional[Dict] = None,
    seed: int = 20_000,
    mig_enabled: bool = True,
    workers: int = 0,
) -> List[SimResult]:
    """Evaluate a repartitioning policy per-device inside a fleet.

    Each iteration dispatches one scenario day across ``profiles`` and runs
    an *independent instance* of the policy on every device (policies carry
    run state); returns the fleet-aggregate :class:`SimResult` per
    iteration.  Registered policies go through the sweep engine (cached,
    parallel); ad-hoc factories run inline and uncached, exactly as in
    :func:`evaluate_policy`.
    """
    from repro.sweep import make_fleet_cell, result_to_sim_result, run_cells

    policy_name, policy_kwargs, factory = _resolve_policy(policy_factory)
    cells = [
        make_fleet_cell(
            experiment="evaluate_policy_fleet",
            group=policy_name,
            profiles=profiles,
            dispatcher=dispatcher,
            scheduler=scheduler_name,
            scenario=scenario,
            scenario_kwargs=scenario_kwargs,
            seed=seed + it,
            policy=policy_name,
            policy_kwargs=policy_kwargs,
            mig_enabled=mig_enabled,
        )
        for it in range(num_iterations)
    ]
    outcome = run_cells(
        "evaluate_policy_fleet",
        cells,
        workers=workers,
        cache=factory is None,
        artifacts_dir=None,
        policy_factory=factory,
    )
    return [result_to_sim_result(r) for r in outcome.results]
