"""Fused on-device DQN training: B rollouts + the learner in one jitted scan.

This is the RL analogue of the batched simulation backend
(docs/BATCHED_SIM.md): instead of stepping one host
:class:`~repro.core.rl.env.RepartitionEnv` episode at a time and shuttling
every transition through numpy, a *round* of ``B`` episodes advances
lock-step inside a single ``lax.scan`` over decision steps.  Each scan step

1. computes the §IV-D-1 observations on device (a JAX mirror of
   ``BatchedRepartitionEnv._obs``),
2. acts epsilon-greedily with the *global env-step* schedule
   (:func:`repro.core.rl.dqn.epsilon_by_step` — B rollouts advance B env
   steps per decision, so an episode-indexed schedule would decay B× fast),
3. advances every rollout one decision interval by vmapping exactly the
   physics function the simulation backend runs
   (:func:`repro.core.batched.backend.make_step_fn`),
4. emits n-step transitions into an on-device ring replay buffer (masked
   scatters — terminating rollouts flush their pending tail with shortened
   returns, mirroring :class:`repro.core.rl.agent.NStepAccumulator`),
5. runs one TD update sampled from that buffer via the *shared* update step
   (:func:`repro.core.rl.dqn.make_td_update` — the same function the host
   :class:`~repro.core.rl.dqn.DQNLearner` jits, so one training step here
   agrees with the host learner on an identical batch to float tolerance
   by construction; DESIGN.md §11 states the contract), and
6. syncs the target network by update count, exactly like the host loop.

The host stays the orchestrator: an outer Python loop generates each
round's workloads (seed × scenario × load-scale randomized per episode),
pads them to one global shape so every round reuses one compiled program,
and finally installs the trained parameters into a plain
:class:`DQNLearner` — downstream evaluation/persistence is unchanged.

Rollout-batched arrays are sharded across available devices with
``jax.sharding`` (:func:`shard_rollouts`); on the single-device CPU cell
this degrades to a no-op placement.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batched.backend import (
    DEFAULT_DT_MIN,
    device_constants,
    init_state,
    make_step_fn,
    result_of,
)
from repro.core.batched.state import BatchedJobs
from repro.core.batched.tables import DeviceTables, build_tables
from repro.core.jobs import ALL_SLICE_SIZES
from repro.core.rl.dqn import (
    DQNConfig,
    DQNLearner,
    epsilon_by_step,
    make_td_update,
    q_forward,
)
from repro.core.rl.env import (
    _BIN_EDGES,
    _NUM_BINS,
    _TIME_BINS,
    FEATURE_DIM,
    M_JOBS,
    RewardWeights,
)

__all__ = [
    "BatchedTrainConfig",
    "BatchedTrainStats",
    "device_observations",
    "shard_rollouts",
    "train_dqn_batched",
]

_EPS = 1e-6
# held_policy() defaults — reusing them keys make_step_fn's cache to the
# exact entry BatchedRepartitionEnv already compiled
_DAY_START = 5 * 60.0
_DAY_END = 17 * 60.0


@dataclasses.dataclass(frozen=True)
class BatchedTrainConfig:
    """Knobs of the fused trainer (everything episode-shaped lives here).

    ``horizon_decisions`` is the fixed scan length per round; rollouts that
    terminate earlier are masked out (no actions, no transitions, no env
    steps), rollouts still live at the horizon are truncated — their pending
    n-step tail is dropped (a bootstrapped continuation, the standard
    truncation treatment).  ``load_scale_range`` draws one uniform load
    scale per episode; ``scenarios`` round-robins per episode.
    """

    batch: int = 32
    scenarios: Tuple[str, ...] = ("paper-diurnal",)
    scenario_kwargs: Optional[Dict[str, Any]] = None
    load_scale_range: Tuple[float, float] = (1.0, 1.0)
    decision_interval_min: float = 15.0
    dt_min: float = DEFAULT_DT_MIN
    horizon_decisions: int = 104  # a 24h day at 15-min cadence + drain tail
    replay_capacity: int = 16_384
    repartition_mode: str = "partial"
    initial_config: int = 2
    lr_schedule: str = "constant"  # "constant" | "cosine"


@dataclasses.dataclass
class BatchedTrainStats:
    """Mirrors :class:`~repro.core.rl.train.TrainStats` plus throughput.

    ``episode_rewards`` holds exact per-episode cumulative rewards (summed
    host-side from the per-step scan outputs); ``env_steps`` counts live
    decisions across all rollouts — the currency ``scripts/bench_rl.py``
    compares against the host loop.  ``round_wall_seconds[0]`` includes
    compilation; steady-state throughput should be read from later rounds.
    """

    episode_rewards: List[float]
    episode_et_proxy: List[float]
    losses: List[float]
    episodes: int
    wall_seconds: float
    env_steps: int = 0
    env_steps_per_sec: float = 0.0
    updates: int = 0
    final_epsilon: float = 0.0
    rounds: int = 0
    batch: int = 0
    truncated_episodes: int = 0
    round_wall_seconds: List[float] = dataclasses.field(default_factory=list)
    round_env_steps: List[int] = dataclasses.field(default_factory=list)


# ---------------------------- device observations --------------------------


def device_observations(
    state, arrival, deadline, valid, dorder, inv_mean_dur, config_ids,
    t, m: int = M_JOBS,
):
    """§IV-D-1 features for every rollout, on device: ``(B, 2+2m)`` float32.

    Jit-compatible mirror of ``BatchedRepartitionEnv._obs`` (the host
    reference; tests/test_batched_train.py pins the parity): same bin
    edges, same sentinels, same EDF-stable ordering via the precomputed
    ``dorder`` permutation.  The only divergence is float32 arithmetic in
    the bin inputs, which can flip a binned feature on exact bin edges.
    """
    import jax
    import jax.numpy as jnp

    B, J = arrival.shape
    i32 = jnp.int32
    edges = jnp.asarray(_BIN_EDGES, jnp.float32)

    # running mask from the slice->job lanes: scatter-max so the clipped
    # padding lanes (-1 -> 0) can never set a spurious True on job 0
    sj = state.slice_job
    bidx = jnp.arange(B, dtype=i32)[:, None]
    running = jnp.zeros((B, J), bool).at[
        bidx, jnp.clip(sj, 0, J - 1)
    ].max(sj >= 0)

    queued = (
        (arrival <= t + _EPS) & (state.remaining > _EPS) & (~running) & valid
    )
    # first-m selection in EDF order: permute the queued mask by the static
    # deadline order, then find the i-th set bit with a per-row searchsorted
    # over the running count (J if fewer than i jobs are queued)
    mq = jnp.take_along_axis(queued, dorder, axis=1).astype(i32)
    cs = jnp.cumsum(mq, axis=1)
    ranks = jnp.arange(1, m + 1, dtype=i32)
    sel = jax.vmap(lambda c: jnp.searchsorted(c, ranks))(cs)  # (B, m)
    has = sel < J
    jobsel = jnp.take_along_axis(dorder, jnp.clip(sel, 0, J - 1), axis=1)

    dl = jnp.take_along_axis(deadline, jobsel, axis=1)
    rem = jnp.take_along_axis(state.remaining, jobsel, axis=1)
    inv = jnp.take_along_axis(inv_mean_dur, jobsel, axis=1)
    slack = jnp.maximum(dl - t, 0.0)
    mean_dur = rem * inv
    sbin = jnp.searchsorted(edges, slack, side="right") / (_NUM_BINS - 1)
    dbin = jnp.searchsorted(edges, mean_dur, side="right") / (_NUM_BINS - 1)
    sfeat = jnp.where(has, sbin, 1.0)  # "no job" sentinel: max slack
    dfeat = jnp.where(has, dbin, 0.0)
    jobfeat = jnp.stack([sfeat, dfeat], axis=2).reshape(B, 2 * m)

    cfg_col = (config_ids[state.cfg].astype(jnp.float32) - 1.0) / 11.0
    tod = jnp.mod(t / 60.0, 24.0)
    tod_col = jnp.mod(jnp.floor(tod * 2.0), _TIME_BINS) / (_TIME_BINS - 1)
    tod_col = jnp.broadcast_to(tod_col, (B,))
    return jnp.concatenate(
        [cfg_col[:, None], tod_col[:, None], jobfeat], axis=1
    ).astype(jnp.float32)


# ------------------------------- sharding ----------------------------------


def shard_rollouts(tree, devices=None):
    """Place rollout-batched arrays across devices on a 1-D ``rollout`` mesh.

    Leaves whose leading axis equals the batch size get a
    ``NamedSharding(P("rollout"))``; everything else is left replicated.
    Degrades to the identity when only one device is visible or the batch
    does not divide the device count, so the single-CPU cell and tests are
    unaffected (the multi-device path is exercised via the subprocess
    pattern of tests/helpers/sharded_smoke.py).
    """
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    devices = list(jax.devices()) if devices is None else list(devices)
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves or len(devices) <= 1:
        return tree
    B = int(leaves[0].shape[0])
    if B % len(devices) != 0:
        return tree
    mesh = Mesh(np.asarray(devices), ("rollout",))
    sharding = NamedSharding(mesh, PartitionSpec("rollout"))
    return jax.tree_util.tree_map(
        lambda x: (
            jax.device_put(x, sharding)
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == B
            else x
        ),
        tree,
    )


# ----------------------------- the fused round -----------------------------


def _make_round_fn(
    cfg: DQNConfig,
    tcfg: BatchedTrainConfig,
    rewards: RewardWeights,
    tables: DeviceTables,
    consts: Dict[str, Any],
    lr=None,
):
    """Build the jitted round program: scan over ``horizon_decisions``.

    Carry = (env RolloutState, params, target, opt state, replay ring,
    n-step recency rings, global env-step count, update count, PRNG key).
    The per-step physics is exactly the simulation backend's
    :func:`make_step_fn` under the ``held_policy`` cache key, so training
    rollouts obey the very dynamics evaluation runs.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    if cfg.num_actions != tables.num_configs:
        raise ValueError(
            f"num_actions={cfg.num_actions} != {tables.num_configs} device "
            "configs; the action space is the dense config index"
        )
    if cfg.state_dim != 2 + 2 * M_JOBS:
        raise ValueError(
            f"state_dim={cfg.state_dim} != feature dim {2 + 2 * M_JOBS}"
        )
    interval = float(tcfg.decision_interval_min)
    spd = int(round(interval / tcfg.dt_min))
    if abs(spd * tcfg.dt_min - interval) > 1e-9 or spd < 1:
        raise ValueError(
            f"decision_interval_min={interval} must be a positive multiple "
            f"of dt_min={tcfg.dt_min}"
        )
    dt = float(tcfg.dt_min)
    step_one = make_step_fn(
        "static", dt, float(tables.penalty_min), _DAY_START, _DAY_END
    )
    step_b = jax.vmap(
        step_one,
        in_axes=(0, None, 0, 0, 0, 0, 0, 0, 0, None, None, None, None, None),
    )
    _, td_update = make_td_update(cfg, lr=lr)

    n = int(cfg.n_step)
    gamma = float(cfg.gamma)
    cap = int(tcfg.replay_capacity)
    H = int(tcfg.horizon_decisions)
    B = int(tcfg.batch)
    A = int(cfg.num_actions)
    D = int(cfg.state_dim)
    bs = int(cfg.batch_size)
    min_buffer = int(cfg.min_buffer)
    sync_every = int(cfg.target_sync_every)
    w_a, w_norm = float(rewards.a), float(rewards.tardiness_norm)
    w_scale = float(rewards.scale)
    w_switch = float(rewards.switch_penalty_min)
    cfg_ids = jnp.asarray(tables.config_ids)
    i32 = jnp.int32
    f32 = jnp.float32

    def dec_step(carry, k, arrival, deadline, rates, valid, dorder, inv_md):
        (env, obs, params, target, opt_state, replay, rings,
         gstep, updates, key) = carry
        rs, ra, rr, rs2, rdone, rg, pos, size = replay
        obs_h, act_h, rew_h = rings
        t = k.astype(f32) * interval

        # `obs` (the pre-step observation) rides the carry: obs(k) is
        # exactly obs2(k-1) — same state, same time — so each decision
        # computes the feature pass once, not twice
        live = env.stop_time > t + _EPS
        key, k_expl, k_act, k_samp = jax.random.split(key, 4)
        eps = epsilon_by_step(cfg, gstep)
        greedy = jnp.argmax(q_forward(params, obs), axis=1).astype(i32)
        randa = jax.random.randint(k_act, (B,), 0, A, dtype=i32)
        explore = jax.random.uniform(k_expl, (B,)) < eps
        # dense config index == action id (asserted against the tables);
        # halted rollouts hold their configuration and emit nothing
        action = jnp.where(live, jnp.where(explore, randa, greedy), env.cfg)

        # §IV-D-3 switch penalty, priced on jobs currently in system
        in_sys = jnp.sum(
            (arrival <= t + _EPS) & (env.remaining > _EPS) & valid, axis=1
        )
        pen_y = w_switch * jnp.maximum(in_sys, 1) / w_norm
        penalty = jnp.where(
            (action != env.cfg) & live, (pen_y / (w_a + 1.0)) / w_scale, 0.0
        )

        e0, td0 = env.energy_wh, env.tardiness_integral

        def inner(c, i):
            ti = t + i.astype(f32) * f32(dt)
            return (
                step_b(c, ti, arrival, deadline, rates, valid, dorder,
                       action, action,
                       consts["slice_slots"], consts["slice_rank"],
                       consts["num_slices"], consts["old_to_new"],
                       consts["watts"]),
                None,
            )

        env2, _ = lax.scan(inner, env, jnp.arange(spd, dtype=i32))
        d_e = env2.energy_wh - e0
        d_t = env2.tardiness_integral - td0
        reward = -((w_a * d_e + d_t / w_norm) / (w_a + 1.0)) / w_scale - penalty
        reward = jnp.where(live, reward, 0.0).astype(f32)

        t_next = t + interval
        obs2 = device_observations(
            env2, arrival, deadline, valid, dorder, inv_md, cfg_ids, t_next
        )
        done_next = env2.stop_time <= t_next + _EPS

        # -- n-step recency rings: newest at index 0 --------------------
        obs_h = jnp.roll(obs_h, 1, axis=1).at[:, 0].set(obs)
        act_h = jnp.roll(act_h, 1, axis=1).at[:, 0].set(action)
        rew_h = jnp.roll(rew_h, 1, axis=1).at[:, 0].set(reward)

        # candidate transitions: recency o originated at step k-o.  Normal
        # maturation emits only o = n-1 (done flag = done_next); a rollout
        # terminating this step flushes o = 0..n-2 too, with shortened
        # returns — exactly NStepAccumulator's flush-on-done.  A rollout is
        # live at k-o whenever it is live at k (liveness is monotone), so
        # one mask covers the whole ring.
        flush = live & done_next
        s_c, a_c, r_c, g_c, v_c = [], [], [], [], []
        for o in range(n):
            ret = rew_h[:, 0] * (gamma ** o)
            for d in range(1, o + 1):
                ret = ret + rew_h[:, d] * (gamma ** (o - d))
            s_c.append(obs_h[:, o])
            a_c.append(act_h[:, o])
            r_c.append(ret)
            g_c.append(jnp.full((B,), gamma ** (o + 1), f32))
            ok = live & (k >= o) if o == n - 1 else flush & (k >= o)
            v_c.append(ok)
        s_flat = jnp.concatenate(s_c, axis=0)  # (n*B, D)
        a_flat = jnp.concatenate(a_c, axis=0)
        r_flat = jnp.concatenate(r_c, axis=0)
        g_flat = jnp.concatenate(g_c, axis=0)
        v_flat = jnp.concatenate(v_c, axis=0)
        s2_flat = jnp.tile(obs2, (n, 1))
        d_flat = jnp.tile(done_next.astype(f32), (n,))

        rank = jnp.cumsum(v_flat.astype(i32)) - 1
        widx = jnp.where(v_flat, jnp.mod(pos + rank, cap), cap)  # cap = drop
        rs = rs.at[widx].set(s_flat, mode="drop")
        ra = ra.at[widx].set(a_flat, mode="drop")
        rr = rr.at[widx].set(r_flat, mode="drop")
        rs2 = rs2.at[widx].set(s2_flat, mode="drop")
        rdone = rdone.at[widx].set(d_flat, mode="drop")
        rg = rg.at[widx].set(g_flat, mode="drop")
        emitted = jnp.sum(v_flat.astype(i32))
        pos = jnp.mod(pos + emitted, cap)
        size = jnp.minimum(size + emitted, cap)

        # -- one TD update per decision step (the host loop's cadence) --
        can_train = size >= min_buffer

        def _do(op):
            p, o_s = op
            idx = jax.random.randint(
                k_samp, (bs,), 0, jnp.maximum(size, 1)
            )
            return td_update(
                p, target, o_s,
                rs[idx], ra[idx], rr[idx], rs2[idx], rdone[idx], rg[idx],
            )

        def _skip(op):
            p, o_s = op
            return p, o_s, jnp.float32(jnp.nan)

        params, opt_state, loss = lax.cond(
            can_train, _do, _skip, (params, opt_state)
        )
        updates = updates + can_train.astype(i32)
        sync = can_train & (jnp.mod(updates, sync_every) == 0)
        target = jax.tree_util.tree_map(
            lambda tp, pp: jnp.where(sync, pp, tp), target, params
        )
        gstep = gstep + jnp.sum(live.astype(i32))

        carry = (
            env2, obs2, params, target, opt_state,
            (rs, ra, rr, rs2, rdone, rg, pos, size),
            (obs_h, act_h, rew_h), gstep, updates, key,
        )
        return carry, (reward, live, loss, eps)

    def round_fn(env0, params, target, opt_state, replay, gstep, updates,
                 key, arrival, deadline, rates, valid, dorder, inv_md):
        rings = (
            jnp.zeros((B, n, D), f32),
            jnp.zeros((B, n), i32),
            jnp.zeros((B, n), f32),
        )
        obs0 = device_observations(
            env0, arrival, deadline, valid, dorder, inv_md, cfg_ids,
            jnp.float32(0.0),
        )
        carry0 = (env0, obs0, params, target, opt_state, replay, rings,
                  gstep, updates, key)

        def body(carry, k):
            return dec_step(
                carry, k, arrival, deadline, rates, valid, dorder, inv_md
            )

        carry, outs = lax.scan(body, carry0, jnp.arange(H, dtype=i32))
        (env, _obs, params, target, opt_state, replay, _rings,
         gstep, updates, key) = carry
        return (env, params, target, opt_state, replay, gstep, updates,
                key, outs)

    import jax as _jax

    return _jax.jit(round_fn)


# ------------------------------ the outer loop -----------------------------


def train_dqn_batched(
    num_episodes: int = 128,
    dqn_config: Optional[DQNConfig] = None,
    train_config: Optional[BatchedTrainConfig] = None,
    rewards: RewardWeights = RewardWeights(),
    seed: int = 0,
    verbose: bool = False,
    tables: Optional[DeviceTables] = None,
) -> tuple:
    """Train the repartitioning DQN on device; returns (learner, stats).

    Episodes are grouped into rounds of ``train_config.batch`` rollouts;
    episode ``i`` draws seed ``seed * 100_003 + i`` (the host loop's seed
    line), scenario ``scenarios[i % len]`` and a uniform load scale from
    ``load_scale_range``.  All rounds are padded to one global job-axis
    shape so the scan compiles once.  The returned learner is a regular
    :class:`DQNLearner` with the trained parameters, target network,
    optimizer state and update count installed — save/eval paths are
    identical to host training (the on-device replay ring is not carried
    over).
    """
    import jax
    import jax.numpy as jnp

    tcfg = train_config or BatchedTrainConfig()
    B = int(tcfg.batch)
    rounds = max(1, -(-int(num_episodes) // B))
    cfg = dqn_config or DQNConfig(state_dim=FEATURE_DIM, seed=seed)
    if cfg.eps_decay_steps is None:
        # default the step schedule to the same exploration budget the host
        # schedule spends: eps_decay_episodes × the per-episode horizon
        cfg = dataclasses.replace(
            cfg,
            eps_decay_steps=cfg.eps_decay_episodes * tcfg.horizon_decisions,
        )
    if tables is None:
        tables = build_tables()
    consts = device_constants(tables, tcfg.repartition_mode)

    lr = None
    if tcfg.lr_schedule == "cosine":
        from repro.optim.schedule import cosine_schedule

        lr = cosine_schedule(
            cfg.lr, total_steps=rounds * tcfg.horizon_decisions,
            final_frac=0.1,
        )
    elif tcfg.lr_schedule != "constant":
        raise ValueError(f"unknown lr_schedule {tcfg.lr_schedule!r}")

    # -- generate every episode's workload up front (one padded shape) ----
    from repro.core.scenarios import generate_scenario

    rng = np.random.default_rng(seed)
    skw = dict(tcfg.scenario_kwargs or {})
    episodes: List[List[Any]] = []
    for i in range(rounds * B):
        scen = tcfg.scenarios[i % len(tcfg.scenarios)]
        lo, hi = tcfg.load_scale_range
        kw = dict(skw)
        if (lo, hi) != (1.0, 1.0) or "load_scale" not in kw:
            scale = float(rng.uniform(lo, hi))
            kw.setdefault("load_scale", scale)
        episodes.append(
            generate_scenario(scen, seed=seed * 100_003 + i, **kw)
        )
    max_jobs = max((len(js) for js in episodes), default=1)

    round_jobs: List[BatchedJobs] = []
    round_inv: List[np.ndarray] = []
    for r in range(rounds):
        chunk = episodes[r * B:(r + 1) * B]
        jobs = BatchedJobs.from_job_lists(
            chunk, max_slots=tables.max_slots, min_jobs=max_jobs
        )
        inv = np.zeros(jobs.arrival.shape, dtype=np.float32)
        for b, js in enumerate(chunk):
            for j, job in enumerate(js):
                inv[b, j] = sum(
                    1.0 / job.rate_on(float(k), True) for k in ALL_SLICE_SIZES
                ) / len(ALL_SLICE_SIZES)
        round_jobs.append(jobs)
        round_inv.append(inv)

    round_fn = _make_round_fn(cfg, tcfg, rewards, tables, consts, lr=lr)

    # learner-side carry: init through DQNLearner so host/batched training
    # start from the identical network for a given DQNConfig
    learner = DQNLearner(cfg)
    params, target = learner.params, learner.target
    opt_state = learner.opt_state
    D, capacity = cfg.state_dim, int(tcfg.replay_capacity)
    f32, i32 = jnp.float32, jnp.int32
    replay = (
        jnp.zeros((capacity, D), f32), jnp.zeros((capacity,), i32),
        jnp.zeros((capacity,), f32), jnp.zeros((capacity, D), f32),
        jnp.zeros((capacity,), f32), jnp.zeros((capacity,), f32),
        jnp.zeros((), i32), jnp.zeros((), i32),
    )
    gstep = jnp.zeros((), i32)
    updates = jnp.zeros((), i32)
    key = jax.random.PRNGKey(seed + 17)

    t_start = time.time()  # lint: waive[DT002] wall-seconds telemetry only
    ep_rewards: List[float] = []
    ep_proxy: List[float] = []
    all_losses: List[float] = []
    round_walls: List[float] = []
    round_steps: List[int] = []
    truncated = 0
    init_idx = np.full(
        (B,), tables.index_of(tcfg.initial_config), dtype=np.int32
    )
    for r in range(rounds):
        jobs = round_jobs[r]
        env0 = shard_rollouts(init_state(jobs, init_idx))
        batch_arrays = shard_rollouts(
            tuple(
                jnp.asarray(a)
                for a in (jobs.arrival, jobs.deadline, jobs.rate_by_slots,
                          jobs.valid, jobs.edf_order, round_inv[r])
            )
        )
        t_r = time.time()  # lint: waive[DT002] per-round wall telemetry only
        (env, params, target, opt_state, replay, gstep, updates, key,
         outs) = round_fn(
            env0, params, target, opt_state, replay, gstep, updates, key,
            *batch_arrays,
        )
        rew_hb = np.asarray(outs[0])  # (H, B)
        live_hb = np.asarray(outs[1])
        loss_h = np.asarray(outs[2])
        round_walls.append(time.time() - t_r)  # lint: waive[DT002] wall telemetry only
        round_steps.append(int(live_hb.sum()))

        ep_rewards.extend(rew_hb.sum(axis=0).tolist())
        # ET proxy from the rollout accumulators, like the host loop's
        # per-episode `a * energy + avg_tardiness`
        for res in result_of(env, jobs, tables).to_sim_results():
            ep_proxy.append(rewards.a * res.energy_wh + res.avg_tardiness)
        all_losses.extend(loss_h[~np.isnan(loss_h)].tolist())
        truncated += int(live_hb[-1].sum())
        if verbose:  # pragma: no cover
            print(
                f"round {r + 1}/{rounds} episodes={B} "
                f"mean_reward={rew_hb.sum(axis=0).mean():.2f} "
                f"env_steps={int(gstep)} updates={int(updates)} "
                f"wall={round_walls[-1]:.1f}s"
            )

    # install the trained state into the host learner (same OptState type)
    learner.params = params
    learner.target = target
    learner.opt_state = opt_state
    learner.updates = int(updates)

    wall = time.time() - t_start  # lint: waive[DT002] wall telemetry only
    env_steps = int(gstep)
    stats = BatchedTrainStats(
        episode_rewards=ep_rewards,
        episode_et_proxy=ep_proxy,
        losses=all_losses,
        episodes=rounds * B,
        wall_seconds=wall,
        env_steps=env_steps,
        env_steps_per_sec=env_steps / wall if wall > 0 else 0.0,
        updates=int(updates),
        final_epsilon=float(epsilon_by_step(cfg, env_steps)),
        rounds=rounds,
        batch=B,
        truncated_episodes=truncated,
        round_wall_seconds=round_walls,
        round_env_steps=round_steps,
    )
    return learner, stats
