"""Reinforcement-learning repartitioning (paper §IV-D): DQN in pure JAX."""

from repro.core.rl.dqn import DQNConfig, DQNLearner, ReplayBuffer
from repro.core.rl.env import (
    FEATURE_DIM,
    RepartitionEnv,
    RewardWeights,
    state_features,
)
from repro.core.rl.agent import DQNAgent, NStepAccumulator, greedy_policy
from repro.core.rl.train import train_dqn, evaluate_policy
from repro.core.rl.batched_train import (
    BatchedTrainConfig,
    BatchedTrainStats,
    train_dqn_batched,
)

__all__ = [
    "DQNConfig",
    "DQNLearner",
    "ReplayBuffer",
    "state_features",
    "FEATURE_DIM",
    "RepartitionEnv",
    "RewardWeights",
    "DQNAgent",
    "NStepAccumulator",
    "greedy_policy",
    "train_dqn",
    "evaluate_policy",
    "BatchedTrainConfig",
    "BatchedTrainStats",
    "train_dqn_batched",
]
