"""Reinforcement-learning repartitioning (paper §IV-D): DQN in pure JAX."""

from repro.core.rl.dqn import DQNConfig, DQNLearner, ReplayBuffer
from repro.core.rl.env import state_features, FEATURE_DIM, RewardWeights
from repro.core.rl.agent import DQNAgent, greedy_policy
from repro.core.rl.train import train_dqn, evaluate_policy

__all__ = [
    "DQNConfig",
    "DQNLearner",
    "ReplayBuffer",
    "state_features",
    "FEATURE_DIM",
    "RewardWeights",
    "DQNAgent",
    "greedy_policy",
    "train_dqn",
    "evaluate_policy",
]
