"""Deep Q-Network in pure JAX (paper §IV-D).

Epsilon-greedy exploration, experience-replay buffer, target network, Huber
TD loss — no external NN library.  The Q-network is a small MLP over the
``2+2m`` binned state features; the action space is the 12 MIG
configurations of Fig. 1.  The optimizer is the repo's own
:class:`repro.optim.adamw.AdamW` configured down to classic Adam
(``weight_decay=0``, no clipping, ``b2=0.999``) so the host loop and the
fused on-device trainer (:mod:`repro.core.rl.batched_train`) share one
update rule — :func:`make_td_update` is that shared jit-compatible step.

Epsilon has two equivalent parameterizations: the host loop's per-episode
linear decay (``eps_decay_episodes``, unchanged semantics) and the
global-env-step decay (``eps_decay_steps``) that vectorized training needs —
B parallel rollouts advance B env steps per decision, so an episode-indexed
schedule would decay B× too fast.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slices import NUM_CONFIGS
from repro.optim.adamw import AdamW, AdamWConfig

__all__ = [
    "DQNConfig",
    "ReplayBuffer",
    "DQNLearner",
    "make_td_update",
    "epsilon_by_step",
]

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    state_dim: int = 8
    num_actions: int = NUM_CONFIGS
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    n_step: int = 8  # n-step TD targets (credit over event chains)
    lr: float = 5e-4
    batch_size: int = 128
    buffer_capacity: int = 200_000
    min_buffer: int = 2_000
    target_sync_every: int = 1_000
    huber_delta: float = 1.0
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_episodes: int = 150
    # global-env-step epsilon decay for vectorized training (None = unset;
    # the host loop keeps its per-episode schedule either way)
    eps_decay_steps: Optional[int] = None
    seed: int = 0


def init_mlp(key: jax.Array, sizes: Tuple[int, ...]) -> Params:
    params: Params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        fan_in = sizes[i]
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((sizes[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def q_forward(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return h @ w + b


class ReplayBuffer:
    """Circular numpy replay buffer."""

    def __init__(self, capacity: int, state_dim: int) -> None:
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.g = np.zeros((capacity,), np.float32)  # bootstrap discount gamma^k
        self.size = 0
        self.pos = 0

    def add(self, s, a, r, s2, done, g) -> None:
        i = self.pos
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s2[i] = s2
        self.done[i] = float(done)
        self.g[i] = g
        self.pos = (self.pos + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.size, size=batch)
        return (
            self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
            self.done[idx], self.g[idx],
        )


# ------------------------ shared TD update step ----------------------------


def make_optimizer(cfg: DQNConfig, lr=None) -> AdamW:
    """The DQN optimizer: :class:`repro.optim.adamw.AdamW` as classic Adam.

    ``weight_decay=0`` / no clipping / ``b2=0.999`` reproduce the previous
    hand-rolled Adam bit-for-bit (same bias-corrected update); ``lr`` may be
    a schedule callable (step -> lr), defaulting to the constant
    ``cfg.lr`` the host loop uses.
    """
    return AdamW(AdamWConfig(
        lr=cfg.lr if lr is None else lr,
        b1=0.9, b2=0.999, eps=1e-8,
        weight_decay=0.0, grad_clip_norm=None,
    ))


def make_td_update(cfg: DQNConfig, lr=None):
    """Build ``(optimizer, update_fn)`` — the one double-DQN training step.

    ``update_fn(params, target, opt_state, s, a, r, s2, done, g)`` returns
    ``(new_params, new_opt_state, loss)`` and is pure/jit-compatible: the
    host :class:`DQNLearner` jits it directly and the fused batched trainer
    calls it inside its rollout scan, so the two loops agree on an identical
    replay batch to float tolerance by construction (the contract
    DESIGN.md §11 states and tests/test_batched_train.py pins).
    """
    delta = cfg.huber_delta
    opt = make_optimizer(cfg, lr)

    def update(params, target, opt_state, s, a, r, s2, done, g):
        def loss_fn(p):
            q = q_forward(p, s)
            q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
            # Double DQN: online net picks the argmax, target net evaluates
            a2 = jnp.argmax(q_forward(p, s2), axis=1)
            q_next = jnp.take_along_axis(
                q_forward(target, s2), a2[:, None], axis=1
            )[:, 0]
            # n-step target: r is the discounted n-step sum, g = gamma^k
            tgt = r + g * (1.0 - done) * q_next
            td = q_sa - jax.lax.stop_gradient(tgt)
            # Huber
            abs_td = jnp.abs(td)
            quad = jnp.minimum(abs_td, delta)
            lin = abs_td - quad
            return jnp.mean(0.5 * quad**2 + delta * lin)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return opt, update


def epsilon_by_step(cfg: DQNConfig, env_step):
    """Linear ``eps_start -> eps_end`` over ``cfg.eps_decay_steps`` env steps.

    Works on Python scalars and jnp arrays alike (the batched trainer calls
    it inside the scan); invariant to how many rollouts advance in parallel,
    because the clock is *global* env steps, not episodes.
    """
    decay = max(int(cfg.eps_decay_steps or 1), 1)
    frac = jnp.minimum(jnp.asarray(env_step, jnp.float32) / decay, 1.0)
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


# ------------------------------- learner ----------------------------------


class DQNLearner:
    """Holds online/target params + optimizer state; jitted TD update."""

    def __init__(self, cfg: DQNConfig) -> None:
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        sizes = (cfg.state_dim, *cfg.hidden, cfg.num_actions)
        self.params = init_mlp(key, sizes)
        self.target = jax.tree_util.tree_map(jnp.copy, self.params)
        self._opt, update = make_td_update(cfg)
        self.opt_state = self._opt.init(self.params)
        self.updates = 0
        self.buffer = ReplayBuffer(cfg.buffer_capacity, cfg.state_dim)
        self._rng = np.random.default_rng(cfg.seed + 1)

        @jax.jit
        def q_values(params, s):
            return q_forward(params, s)

        self._update = jax.jit(update)
        self._q_values = q_values

    # -- acting ----------------------------------------------------------
    def q(self, state: np.ndarray) -> np.ndarray:
        out = self._q_values(self.params, jnp.asarray(state[None, :]))
        return np.asarray(out)[0]

    def act(self, state: np.ndarray, epsilon: float) -> int:
        if self._rng.uniform() < epsilon:
            return int(self._rng.integers(0, self.cfg.num_actions))
        return int(np.argmax(self.q(state)))

    def greedy_action(self, state: np.ndarray) -> int:
        return int(np.argmax(self.q(state)))

    # -- learning ---------------------------------------------------------
    def observe(self, s, a, r, s2, done, g=None) -> None:
        self.buffer.add(s, a, r, s2, done, self.cfg.gamma if g is None else g)

    def maybe_train(self, steps: int = 1) -> float:
        if self.buffer.size < self.cfg.min_buffer:
            return float("nan")
        loss = float("nan")
        for _ in range(steps):
            batch = self.buffer.sample(self._rng, self.cfg.batch_size)
            self.params, self.opt_state, loss_j = self._update(
                self.params, self.target, self.opt_state, *map(jnp.asarray, batch)
            )
            loss = float(loss_j)
            self.updates += 1
            if self.updates % self.cfg.target_sync_every == 0:
                self.target = jax.tree_util.tree_map(jnp.copy, self.params)
        return loss

    def epsilon(self, episode: int) -> float:
        """Host-loop schedule: linear decay over ``eps_decay_episodes``."""
        c = self.cfg
        frac = min(episode / max(c.eps_decay_episodes, 1), 1.0)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    def epsilon_at_step(self, env_step: int) -> float:
        """Vectorized-training schedule: decay in *global* env steps."""
        return float(epsilon_by_step(self.cfg, env_step))

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        arrays: Dict[str, np.ndarray] = {}
        for i, (w, b) in enumerate(self.params):
            arrays[f"w{i}"] = np.asarray(w)
            arrays[f"b{i}"] = np.asarray(b)
        arrays["n_layers"] = np.asarray(len(self.params))
        np.savez(path, **arrays)

    def load(self, path: str) -> None:
        data = np.load(path)
        n = int(data["n_layers"])
        self.params = [
            (jnp.asarray(data[f"w{i}"]), jnp.asarray(data[f"b{i}"])) for i in range(n)
        ]
        self.target = jax.tree_util.tree_map(jnp.copy, self.params)
