"""Deep Q-Network in pure JAX (paper §IV-D).

Epsilon-greedy exploration, experience-replay buffer, target network, Huber
TD loss, Adam — no external NN library.  The Q-network is a small MLP over
the ``2+2m`` binned state features; the action space is the 12 MIG
configurations of Fig. 1.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slices import NUM_CONFIGS

__all__ = ["DQNConfig", "ReplayBuffer", "DQNLearner"]

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    state_dim: int = 8
    num_actions: int = NUM_CONFIGS
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    n_step: int = 8  # n-step TD targets (credit over event chains)
    lr: float = 5e-4
    batch_size: int = 128
    buffer_capacity: int = 200_000
    min_buffer: int = 2_000
    target_sync_every: int = 1_000
    huber_delta: float = 1.0
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_episodes: int = 150
    seed: int = 0


def init_mlp(key: jax.Array, sizes: Tuple[int, ...]) -> Params:
    params: Params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        fan_in = sizes[i]
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((sizes[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def q_forward(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return h @ w + b


class ReplayBuffer:
    """Circular numpy replay buffer."""

    def __init__(self, capacity: int, state_dim: int) -> None:
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.g = np.zeros((capacity,), np.float32)  # bootstrap discount gamma^k
        self.size = 0
        self.pos = 0

    def add(self, s, a, r, s2, done, g) -> None:
        i = self.pos
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s2[i] = s2
        self.done[i] = float(done)
        self.g[i] = g
        self.pos = (self.pos + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.size, size=batch)
        return (
            self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
            self.done[idx], self.g[idx],
        )


# --------------------------- Adam (self-contained) -------------------------


def _adam_init(params: Params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def _adam_update(params: Params, grads: Params, state: Dict[str, Any], lr: float,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new_params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new_params, {"m": m, "v": v, "t": t}


# ------------------------------- learner ----------------------------------


class DQNLearner:
    """Holds online/target params + optimizer state; jitted TD update."""

    def __init__(self, cfg: DQNConfig) -> None:
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        sizes = (cfg.state_dim, *cfg.hidden, cfg.num_actions)
        self.params = init_mlp(key, sizes)
        self.target = jax.tree_util.tree_map(jnp.copy, self.params)
        self.opt_state = _adam_init(self.params)
        self.updates = 0
        self.buffer = ReplayBuffer(cfg.buffer_capacity, cfg.state_dim)
        self._rng = np.random.default_rng(cfg.seed + 1)

        gamma, delta, lr = cfg.gamma, cfg.huber_delta, cfg.lr

        @jax.jit
        def update(params, target, opt_state, s, a, r, s2, done, g):
            def loss_fn(p):
                q = q_forward(p, s)
                q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
                # Double DQN: online net picks the argmax, target net evaluates
                a2 = jnp.argmax(q_forward(p, s2), axis=1)
                q_next = jnp.take_along_axis(
                    q_forward(target, s2), a2[:, None], axis=1
                )[:, 0]
                # n-step target: r is the discounted n-step sum, g = gamma^k
                tgt = r + g * (1.0 - done) * q_next
                td = q_sa - jax.lax.stop_gradient(tgt)
                # Huber
                abs_td = jnp.abs(td)
                quad = jnp.minimum(abs_td, delta)
                lin = abs_td - quad
                return jnp.mean(0.5 * quad**2 + delta * lin)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_opt = _adam_update(params, grads, opt_state, lr)
            return new_params, new_opt, loss

        @jax.jit
        def q_values(params, s):
            return q_forward(params, s)

        self._update = update
        self._q_values = q_values

    # -- acting ----------------------------------------------------------
    def q(self, state: np.ndarray) -> np.ndarray:
        out = self._q_values(self.params, jnp.asarray(state[None, :]))
        return np.asarray(out)[0]

    def act(self, state: np.ndarray, epsilon: float) -> int:
        if self._rng.uniform() < epsilon:
            return int(self._rng.integers(0, self.cfg.num_actions))
        return int(np.argmax(self.q(state)))

    def greedy_action(self, state: np.ndarray) -> int:
        return int(np.argmax(self.q(state)))

    # -- learning ---------------------------------------------------------
    def observe(self, s, a, r, s2, done, g=None) -> None:
        self.buffer.add(s, a, r, s2, done, self.cfg.gamma if g is None else g)

    def maybe_train(self, steps: int = 1) -> float:
        if self.buffer.size < self.cfg.min_buffer:
            return float("nan")
        loss = float("nan")
        for _ in range(steps):
            batch = self.buffer.sample(self._rng, self.cfg.batch_size)
            self.params, self.opt_state, loss_j = self._update(
                self.params, self.target, self.opt_state, *map(jnp.asarray, batch)
            )
            loss = float(loss_j)
            self.updates += 1
            if self.updates % self.cfg.target_sync_every == 0:
                self.target = jax.tree_util.tree_map(jnp.copy, self.params)
        return loss

    def epsilon(self, episode: int) -> float:
        c = self.cfg
        frac = min(episode / max(c.eps_decay_episodes, 1), 1.0)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        arrays: Dict[str, np.ndarray] = {}
        for i, (w, b) in enumerate(self.params):
            arrays[f"w{i}"] = np.asarray(w)
            arrays[f"b{i}"] = np.asarray(b)
        arrays["n_layers"] = np.asarray(len(self.params))
        np.savez(path, **arrays)

    def load(self, path: str) -> None:
        data = np.load(path)
        n = int(data["n_layers"])
        self.params = [
            (jnp.asarray(data[f"w{i}"]), jnp.asarray(data[f"b{i}"])) for i in range(n)
        ]
        self.target = jax.tree_util.tree_map(jnp.copy, self.params)
