"""State representation and reward shaping for the repartitioning DQN.

Paper §IV-D-1: the state concatenates ``2 + 2m`` features — the current MIG
configuration, the time, and the (deadline, average duration) of the first
``m`` jobs in the queue (m = 3, from Alibaba-trace load analysis).  The
naturally continuous features are *binned* to discretize the state space; we
feed the normalized bin indices to the Q-network.

Reward (§IV-D-3): scalarization of energy and tardiness following the ET
metric, accumulated between decision events; the repartitioning cost enters
implicitly through the 4 s blocked-GPU penalty in the simulator.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, List

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.simulator import MIGSimulator
    from repro.fleet.simulator import FleetView

__all__ = [
    "M_JOBS",
    "FEATURE_DIM",
    "FLEET_EXTRA_FEATURES",
    "FLEET_FEATURE_DIM",
    "state_features",
    "fleet_state_features",
    "RewardWeights",
]

# The paper uses m=3, chosen "based on an analysis of typical GPU loads in
# Alibaba's data center traces" (§IV-D-1).  Our §V-A calibration produces
# deeper peak queues (see EXPERIMENTS.md), so the same load-driven analysis
# selects m=8; the representation stays exactly the paper's 2+2m layout.
M_JOBS = 8
FEATURE_DIM = 2 + 2 * M_JOBS

# Bin edges (minutes) for deadline slack and average duration.
_BIN_EDGES = np.array([0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0, 80.0, 160.0])
_NUM_BINS = len(_BIN_EDGES) + 1  # 10 bins
_TIME_BINS = 48  # half-hour bins over the day


def _bin(v: float) -> int:
    return int(np.searchsorted(_BIN_EDGES, v, side="right"))


def state_features(t: float, sim: "MIGSimulator", m: int = M_JOBS) -> np.ndarray:
    """Normalized feature vector in [0, 1]^(2+2m); missing jobs -> 1.0/0.0."""
    feats: List[float] = []
    feats.append((sim.partition.config_id - 1) / 11.0)
    tod = (t / 60.0) % 24.0
    feats.append(int(tod * 2) % _TIME_BINS / (_TIME_BINS - 1))
    # first m jobs of the QUEUE in EDF order (paper §IV-D-1).  Padding with
    # running jobs would hide queue pressure — the "no job" sentinel pattern
    # is what lets the agent distinguish empty/loaded queues.
    jobs = sim.queue_snapshot()
    for i in range(m):
        if i < len(jobs):
            slack = max(jobs[i].deadline - t, 0.0)
            feats.append(_bin(slack) / (_NUM_BINS - 1))
            feats.append(_bin(jobs[i].mean_duration_all_sizes()) / (_NUM_BINS - 1))
        else:
            feats.append(1.0)  # "no job" sentinel: max slack
            feats.append(0.0)  # zero duration
    return np.asarray(feats, dtype=np.float32)


# Fleet-aware observation: the per-device features above plus two fleet
# signals read off the dispatch-time load trace (repro.fleet.FleetView) —
# this device's share of the fleet backlog, and the normalized fleet-wide
# backlog.  The 2+2m core layout is unchanged, so a single-GPU policy can be
# warm-started by zero-padding and a fleet policy degrades gracefully when
# the fleet context is absent (both extras read 0.0).
FLEET_EXTRA_FEATURES = 2
FLEET_FEATURE_DIM = FEATURE_DIM + FLEET_EXTRA_FEATURES


def fleet_state_features(
    t: float,
    sim: "MIGSimulator",
    device_index: int,
    view: "FleetView | None",
    m: int = M_JOBS,
) -> np.ndarray:
    """Per-device observation inside a fleet, in [0, 1]^FLEET_FEATURE_DIM."""
    base = state_features(t, sim, m)
    if view is None:
        share, pressure = 0.0, 0.0
    else:
        share = view.load_share(device_index, t)
        pressure = view.total_load_norm(t)
    return np.concatenate(
        [base, np.asarray([share, pressure], dtype=np.float32)]
    )


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    """ET-scalarized reward: r = -(a*dE + dTard/m) / (a+1) / scale.

    ``a`` ~ t/(2s) calibrated on the diurnal workload (mean energy s ~ 4.1 kWh
    per day, mean avg-tardiness t ~ 1.2 min).  The tardiness integral is
    normalized by the expected jobs/episode so the summed episode reward
    approximates -ET of the episode (§IV-A uses *average* tardiness).
    """

    a: float = 5e-5
    tardiness_norm: float = 600.0  # ~ expected jobs per diurnal day
    scale: float = 0.01  # keeps |r| O(1) for stable TD learning
    # §IV-D-3: "changing configurations incurs a performance penalty
    # equivalent to the time required for the repartitioning process" (4 s).
    # The stall also occurs physically in the simulator; the explicit term
    # de-noises credit assignment for the switch decision itself.
    switch_penalty_min: float = 4.0 / 60.0

    def interval_reward(self, d_energy_wh: float, d_tardiness: float) -> float:
        y = d_tardiness / self.tardiness_norm
        return -((self.a * d_energy_wh + y) / (self.a + 1.0)) / self.scale

    def switch_penalty(self, jobs_in_system: int) -> float:
        """Reward cost of a repartition: ~4 s of lost service for the whole
        system, expressed in the same normalized-tardiness units."""
        y = self.switch_penalty_min * max(jobs_in_system, 1) / self.tardiness_norm
        return (y / (self.a + 1.0)) / self.scale
